#!/usr/bin/env python
"""Regenerate the frozen Stage-I golden-trace fixtures.

    PYTHONPATH=src python scripts/regen_golden.py [out.json]

Writes `tests/golden/stage1_golden.json`: exact-DES occupancy segments and
access statistics for the mini gpt2-xl / dsr1d-qwen-1.5b prefill and decode
cases defined in `tests/golden_util.py`. Run this ONLY when a simulator
change intentionally alters Stage-I output, and review the diff — these
fixtures are the regression lock for the DES, the layer-memoization fast
path and PSS probe equivalence (`tests/test_golden_traces.py`)."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

import golden_util  # noqa: E402


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else golden_util.GOLDEN_PATH
    payload = golden_util.build_golden()
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, case in payload.items():
        segs = sum(len(m["durations"]) for m in case["mems"].values())
        print(f"{name}: {segs} segments, "
              f"t={case['total_time']*1e6:.1f} us, "
              f"macs={case['total_macs']}")
    print(f"wrote {out}")

    # shared-prefix serving occupancy fixtures (dual logical/physical traces)
    pout = golden_util.PREFIX_GOLDEN_PATH if len(sys.argv) <= 1 else \
        os.path.join(os.path.dirname(out), "prefix_golden.json")
    ppayload = golden_util.build_prefix_golden()
    with open(pout, "w") as f:
        json.dump(ppayload, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, case in ppayload.items():
        st = case["stats"]
        print(f"{name}: {case['n_requests']} reqs, "
              f"hits={st['prefix_hits']}/{st['admitted']}, "
              f"cow={st['cow_splits']}, "
              f"phys_peak={case['mems']['kv']['peak_needed']} B, "
              f"logical_peak={case['mems']['kv_logical']['peak_needed']} B")
    print(f"wrote {pout}")

    # quantized-ledger fixtures: the prefix scenarios at 1 payload byte/el
    qout = golden_util.QUANT_GOLDEN_PATH if len(sys.argv) <= 1 else \
        os.path.join(os.path.dirname(out), "quant_golden.json")
    qpayload = golden_util.build_quant_golden()
    with open(qout, "w") as f:
        json.dump(qpayload, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, case in qpayload.items():
        print(f"{name}: {case['kv_dtype_bytes']} B/el, "
              f"phys_peak={case['mems']['kv']['peak_needed']} B "
              f"(base {case['base_case']})")
    print(f"wrote {qout}")

    # speculative-decoding fixtures: burst/rollback occupancy, both KV lanes
    sout = golden_util.SPEC_GOLDEN_PATH if len(sys.argv) <= 1 else \
        os.path.join(os.path.dirname(out), "spec_golden.json")
    spayload = golden_util.build_spec_golden()
    with open(sout, "w") as f:
        json.dump(spayload, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, case in spayload.items():
        st = case["stats"]
        print(f"{name}: {case['n_requests']} reqs, "
              f"rounds={st['spec_rounds']}, "
              f"accepted={st['accepted_tokens']}/{st['drafted_tokens']} "
              f"drafted, rolled_back={st['rolled_back_pages']} pages, "
              f"peak={case['mems']['kv']['peak_needed']} B")
    print(f"wrote {sout}")

    # energy-observability fixtures: Perfetto bank-state export schema +
    # exact streamed-meter energy totals over a deterministic sim
    eout = golden_util.ENERGY_GOLDEN_PATH if len(sys.argv) <= 1 else \
        os.path.join(os.path.dirname(out), "energy_golden.json")
    epayload = golden_util.build_energy_golden()
    with open(eout, "w") as f:
        json.dump(epayload, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, case in epayload.items():
        print(f"{name}: {case['n_span_events']} bank-state spans "
              f"{case['state_counts']}, E={case['live_e_j']*1e3:.4g} mJ, "
              f"transitions={case['n_transitions']}")
    print(f"wrote {eout}")


if __name__ == "__main__":
    main()
