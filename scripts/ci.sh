#!/usr/bin/env bash
# CI entrypoint: deps + tier-1 suite + a <60 s traffic-campaign smoke run.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -e ".[test]"

# tier-1 (ROADMAP.md)
PYTHONPATH=src python -m pytest -x -q

# traffic-campaign smoke: small grid, CPU jnp backend, must stay under a minute
PYTHONPATH=src timeout 60 python -m repro.launch.traffic \
    --model dsr1d_qwen_1_5b --arrival poisson --rate 2 --seed 0 \
    --horizon 6 --slots 4 --max-len 512 --banks 1 8 --fast-backend ref \
    > /tmp/traffic_smoke.out
grep -q "online controller vs offline oracle" /tmp/traffic_smoke.out
grep -q "dsr1d-qwen-1.5b" /tmp/traffic_smoke.out
grep -q "gpt2-xl" /tmp/traffic_smoke.out
echo "ci: OK"
