#!/usr/bin/env bash
# CI entrypoint: deps + tier-1 suite + a <60 s traffic-campaign smoke run.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -e ".[test]"

# tier-1 (ROADMAP.md)
PYTHONPATH=src python -m pytest -x -q

# traffic-campaign smoke: small grid, CPU jnp backend, must stay under a minute
PYTHONPATH=src timeout 60 python -m repro.launch.traffic \
    --model dsr1d_qwen_1_5b --arrival poisson --rate 2 --seed 0 \
    --horizon 6 --slots 4 --max-len 512 --banks 1 8 --fast-backend ref \
    > /tmp/traffic_smoke.out
grep -q "online controller vs offline oracle" /tmp/traffic_smoke.out
grep -q "dsr1d-qwen-1.5b" /tmp/traffic_smoke.out
grep -q "gpt2-xl" /tmp/traffic_smoke.out

# batched-sweep smoke: prune-then-exact Stage-II engine through the paper CLI
PYTHONPATH=src timeout 120 python -m repro.launch.trapti \
    --arch dsr1d-qwen-1.5b --seq 512 --prune --backend numpy \
    > /tmp/trapti_smoke.out
grep -q "Stage II" /tmp/trapti_smoke.out
grep -q -- "-->" /tmp/trapti_smoke.out

# Stage-II engine benchmark: exactness vs the scalar reference is asserted
# inside; BENCH_stage2.json records the throughput trajectory
PYTHONPATH=src timeout 300 python -m benchmarks.stage2_bench \
    /tmp/BENCH_stage2.json | tail -1
echo "ci: OK"
