#!/usr/bin/env bash
# CI entrypoint: deps + tier-1 suite + a <60 s traffic-campaign smoke run.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -e ".[test]"

# tier-1 (ROADMAP.md)
PYTHONPATH=src python -m pytest -x -q

# traffic-campaign smoke: small grid, CPU jnp backend, must stay under a minute
PYTHONPATH=src timeout 60 python -m repro.launch.traffic \
    --model dsr1d_qwen_1_5b --arrival poisson --rate 2 --seed 0 \
    --horizon 6 --slots 4 --max-len 512 --banks 1 8 --fast-backend ref \
    > /tmp/traffic_smoke.out
grep -q "online controller vs offline oracle" /tmp/traffic_smoke.out
grep -q "dsr1d-qwen-1.5b" /tmp/traffic_smoke.out
grep -q "gpt2-xl" /tmp/traffic_smoke.out

# batched-sweep smoke: prune-then-exact Stage-II engine through the paper CLI
PYTHONPATH=src timeout 120 python -m repro.launch.trapti \
    --arch dsr1d-qwen-1.5b --seq 512 --prune --backend numpy \
    > /tmp/trapti_smoke.out
grep -q "Stage II" /tmp/trapti_smoke.out
grep -q -- "-->" /tmp/trapti_smoke.out

# golden-trace conformance + full PSS equivalence (includes slow-marked
# cross-config sweeps that tier-1 skips via addopts), with a coverage
# floor on the Stage-I simulator package when pytest-cov is available
if python -c "import pytest_cov" 2>/dev/null; then
    PYTHONPATH=src python -m pytest -q -m "slow or not slow" \
        tests/test_golden_traces.py tests/test_pss.py \
        tests/test_sim_engine.py tests/test_trace_props.py \
        --cov=repro.sim --cov-report=term --cov-fail-under=80
else
    echo "ci: pytest-cov unavailable, skipping sim coverage floor"
    PYTHONPATH=src python -m pytest -q -m "slow or not slow" \
        tests/test_golden_traces.py tests/test_pss.py
fi

# PSS smoke through the paper CLI: probe-and-tile decode horizon + Stage II
PYTHONPATH=src timeout 120 python -m repro.launch.trapti \
    --arch dsr1d-qwen-1.5b --fidelity pss --seq 1024 --decode-steps 128 \
    --decode-batch 4 --backend numpy > /tmp/pss_smoke.out
grep -q "fidelity=pss" /tmp/pss_smoke.out
grep -q "Stage II" /tmp/pss_smoke.out

# Stage-I PSS benchmark: asserts the >=50x speedup bar internally
PYTHONPATH=src timeout 300 python -m benchmarks.stage1_bench \
    /tmp/BENCH_stage1.json | tail -1

# Stage-II engine benchmark: exactness vs the scalar reference is asserted
# inside; BENCH_stage2.json records the throughput trajectory
PYTHONPATH=src timeout 300 python -m benchmarks.stage2_bench \
    /tmp/BENCH_stage2.json | tail -1

# paged-serving smoke: continuous batching over the paged KV cache, ending
# in a Stage-II sweep over the emitted page-granular trace
PYTHONPATH=src timeout 120 python examples/paged_serving.py \
    --requests 6 --new-tokens 8 > /tmp/paged_smoke.out
grep -q "paged-serve" /tmp/paged_smoke.out
grep -q "pages" /tmp/paged_smoke.out

# serving benchmark: paged kernel-vs-reference exactness bound and the
# >=5x decode-throughput bar are asserted inside
PYTHONPATH=src timeout 600 python -m benchmarks.serve_bench \
    /tmp/BENCH_serve.json | tail -1

# prefix-sharing smoke: shared-prefix traffic through the radix-index/COW
# batcher, dual logical-vs-physical traces into a Stage-II sweep
PYTHONPATH=src timeout 120 python examples/prefix_serving.py \
    --requests 6 --new-tokens 6 > /tmp/prefix_smoke.out
grep -q "prefix" /tmp/prefix_smoke.out
grep -q "physical" /tmp/prefix_smoke.out

# observability smoke: telemetry-enabled paged serve exported as a
# Perfetto-loadable Chrome trace with SLO percentiles in otherData
PYTHONPATH=src timeout 120 python -m repro.launch.obs export \
    --arch dsr1d_qwen_1_5b --requests 4 --new-tokens 8 --slots 2 \
    --out /tmp/obs_trace.json > /tmp/obs_smoke.out
grep -q "ui.perfetto.dev" /tmp/obs_smoke.out
python - <<'EOF'
import json, math
obj = json.load(open("/tmp/obs_trace.json"))
evs = obj["traceEvents"]
assert evs, "empty traceEvents"
assert any(e["ph"] == "C" for e in evs), "no counter track"
assert any(e["ph"] == "X" and e["name"] == "request" for e in evs)
slo = obj["otherData"]["slo"]
assert math.isfinite(slo["ttft_p99_s"]) and slo["ttft_p99_s"] > 0
EOF

# energy-observability smoke: streamed per-bank meter over a seeded
# mixed-tenant sim — the CLI prints (and exits nonzero without) the
# bit-identical-f64 receipt vs offline gating.evaluate — then the
# attribution walkthrough, then the exported bank-state timeline
PYTHONPATH=src timeout 120 python -m repro.launch.obs energy \
    --workload chat_sysprompt --rate 4 --horizon 4 --slots 4 \
    --out /tmp/energy_trace.json > /tmp/energy_smoke.out
grep -q "MATCH (bit-identical f64)" /tmp/energy_smoke.out
grep -q "bank-state lanes" /tmp/energy_smoke.out
PYTHONPATH=src timeout 120 python examples/energy_attribution.py \
    --rate 4 --horizon 4 --out /tmp/energy_timeline.json \
    > /tmp/energy_example.out
grep -q "MATCH (bit-identical f64)" /tmp/energy_example.out
grep -q "conserves energy" /tmp/energy_example.out
python - <<'EOF'
import json
evs = json.load(open("/tmp/energy_trace.json"))["traceEvents"]
assert any(e.get("ph") == "C" and e["name"] == "bank energy [J]" for e in evs)
assert any(e.get("ph") == "C" and e["name"] == "active banks" for e in evs)
assert any(e.get("ph") == "X" and e.get("cat") == "bank" for e in evs)
EOF

# shared-prefix workload campaign through the traffic CLI (host-only sim;
# fan-out = concurrent copies of one prefix, the strongest sharing signal)
PYTHONPATH=src timeout 120 python -m repro.launch.traffic \
    --model dsr1d_qwen_1_5b --workload agentic_fanout --rate 2 --horizon 6 \
    --slots 4 --max-len 512 --banks 1 8 --fast-backend ref --no-mha-ref \
    --meter 32,8,0.9,conservative > /tmp/prefix_campaign.out
grep -q "prefix sharing" /tmp/prefix_campaign.out
grep -q "logical vs physical" /tmp/prefix_campaign.out
grep -q "bank energy meter" /tmp/prefix_campaign.out

# prefix benchmark: >=2x physical peak-page reduction at sharing factor 8
# (512-token shared prefix) and decode-throughput parity asserted inside
PYTHONPATH=src timeout 600 python -m benchmarks.prefix_bench \
    /tmp/BENCH_prefix.json | tail -1

# quantized-KV smoke: fp32/int8/fp8 batchers on one request stream, greedy
# tokens must agree, byte-accurate traces priced through Stage II
PYTHONPATH=src timeout 300 python examples/quant_serving.py \
    --requests 4 --new-tokens 8 > /tmp/quant_smoke.out
grep -q "quant-serve" /tmp/quant_smoke.out
grep -q "exact" /tmp/quant_smoke.out

# quantized-KV benchmark: kernel-vs-reference exactness, the pinned
# quantization-error bound vs fp32, >=2x (int8) / >=4x (fp8) bytes/page and
# >=0.9x decode-throughput parity are all asserted inside
PYTHONPATH=src timeout 600 python -m benchmarks.quant_bench \
    /tmp/BENCH_quant.json | tail -1

# SLA smoke: chunked prefill + priority preemption + forecast pre-wake in
# one walkthrough (tokens-bit-identical assertion runs inside)
PYTHONPATH=src timeout 300 python examples/sla_serving.py \
    --new-tokens 16 > /tmp/sla_smoke.out
grep -q "bit-identical to monolithic: True" /tmp/sla_smoke.out
grep -q "preemption" /tmp/sla_smoke.out
grep -q "forecast" /tmp/sla_smoke.out

# forecast-controller campaign through the traffic CLI: the fourth leg's
# columns must land in the report next to reactive/oracle/none
PYTHONPATH=src timeout 120 python -m repro.launch.traffic \
    --model tinyllama-1.1b --arrival diurnal --rate 4 --horizon 8 \
    --slots 4 --max-len 512 --banks 8 --fast-backend ref --no-mha-ref \
    --controller forecast > /tmp/forecast_smoke.out
grep -q "reactive+forecast" /tmp/forecast_smoke.out
grep -q "E_fcast" /tmp/forecast_smoke.out

# SLA benchmark: chunked p99-TBT <= 0.5x monolithic (bit-identical greedy
# tokens) and forecast-vs-reactive wake-violation/energy bars are asserted
# inside; BENCH_sla.json records both legs
PYTHONPATH=src timeout 600 python -m benchmarks.sla_bench \
    /tmp/BENCH_sla.json | tail -1

# speculative-decoding smoke: draft/target on one page pool, batched
# verification, rollback-by-truncation (bit-identity assertion runs inside)
PYTHONPATH=src timeout 300 python examples/spec_serving.py \
    --new-tokens 10 > /tmp/spec_smoke.out
grep -q "bit-identical to non-speculative loop: True" /tmp/spec_smoke.out
grep -q "rolled back" /tmp/spec_smoke.out

# spec occupancy channel through the traffic CLI: burst/rollback sawtooth
# report next to the controller legs
PYTHONPATH=src timeout 120 python -m repro.launch.traffic \
    --model tinyllama-1.1b --rate 2 --horizon 6 --slots 4 --max-len 512 \
    --banks 8 --fast-backend ref --no-mha-ref --speculate 4 \
    > /tmp/spec_campaign.out
grep -q "speculative decoding" /tmp/spec_campaign.out
grep -q "rolled back" /tmp/spec_campaign.out

# speculative benchmark: verify-kernel exactness, bit-identity and the
# >=1.5x accepted-tokens/s bar are asserted inside
PYTHONPATH=src timeout 600 python -m benchmarks.spec_bench \
    /tmp/BENCH_spec.json | tail -1

# benchmark-history regression gate: flatten every BENCH_*.json from this
# run into BENCH_history.jsonl and fail when any guarded wall-time /
# throughput metric degrades >10% vs the previous recorded run (the first
# run just records the baseline)
python scripts/bench_gate.py --history BENCH_history.jsonl \
    /tmp/BENCH_stage1.json /tmp/BENCH_stage2.json /tmp/BENCH_serve.json \
    /tmp/BENCH_prefix.json /tmp/BENCH_quant.json /tmp/BENCH_sla.json \
    /tmp/BENCH_spec.json
echo "ci: OK"
