#!/usr/bin/env python
"""Benchmark regression gate over BENCH_history.jsonl.

Flattens the numeric leaves of one or more ``BENCH_*.json`` reports into a
single metrics dict, appends it as a new history entry, then compares every
*guarded* metric against the most recent previous entry that carries it:

  * lower-is-better  — keys ending in ``_s``, ``_us`` or ``us_per_call``
    (wall times); degradation = new > old * (1 + bar)
  * higher-is-better — keys containing ``speedup``, ``throughput`` or
    ``tok_s``; degradation = new < old / (1 + bar)

Anything else is recorded but not gated. A missing history file (or one
with no prior entry for a key) records only — the first run can never
fail. Exit status 1 when any guarded metric degrades past the bar.

Usage:
    python scripts/bench_gate.py --history BENCH_history.jsonl \
        /tmp/BENCH_stage1.json /tmp/BENCH_serve.json ...
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

HIGHER_BETTER = ("speedup", "throughput", "tok_s")
LOWER_BETTER_SUFFIXES = ("_s", "_us", "us_per_call")


def guard_direction(key: str):
    """'up' (higher better), 'down' (lower better) or None (unguarded)."""
    leaf = key.rsplit(".", 1)[-1]
    if any(h in leaf for h in HIGHER_BETTER):
        return "up"
    if leaf.endswith(LOWER_BETTER_SUFFIXES):
        return "down"
    return None


def flatten(prefix: str, obj, out: dict) -> None:
    if isinstance(obj, dict):
        for k, v in obj.items():
            flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)


def load_metrics(paths) -> dict:
    metrics: dict = {}
    for p in paths:
        stem = os.path.splitext(os.path.basename(p))[0]
        with open(p) as f:
            flatten(stem, json.load(f), metrics)
    return metrics


def previous_values(history_path: str) -> dict:
    """Most recent prior value per key across all history entries."""
    prev: dict = {}
    if not os.path.exists(history_path):
        return prev
    with open(history_path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entry = json.loads(line)
            except json.JSONDecodeError:
                continue
            prev.update(entry.get("metrics", {}))
    return prev


def append_entry(history_path: str, metrics: dict, source: str) -> None:
    entry = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S", time.gmtime()),
             "source": source, "metrics": metrics}
    with open(history_path, "a") as f:
        f.write(json.dumps(entry) + "\n")


def gate(metrics: dict, prev: dict, bar: float):
    """(regressions, improvements, unguarded_count) vs previous values."""
    regressions, improvements, unguarded = [], [], 0
    for key in sorted(metrics):
        new = metrics[key]
        direction = guard_direction(key)
        if direction is None:
            unguarded += 1
            continue
        old = prev.get(key)
        if old is None or old <= 0 or new <= 0:
            continue
        ratio = new / old
        if direction == "down" and ratio > 1.0 + bar:
            regressions.append((key, old, new, ratio))
        elif direction == "up" and ratio < 1.0 / (1.0 + bar):
            regressions.append((key, old, new, ratio))
        elif (direction == "down" and ratio < 1.0 / (1.0 + bar)) or \
                (direction == "up" and ratio > 1.0 + bar):
            improvements.append((key, old, new, ratio))
    return regressions, improvements, unguarded


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("reports", nargs="+", help="BENCH_*.json report files")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--bar", type=float, default=10.0,
                    help="allowed degradation [%%] on guarded metrics")
    ap.add_argument("--source", default="ci")
    args = ap.parse_args()

    metrics = load_metrics(args.reports)
    if not metrics:
        print("bench_gate: no numeric metrics found", file=sys.stderr)
        return 1
    prev = previous_values(args.history)
    regressions, improvements, unguarded = gate(metrics, prev,
                                                args.bar / 100.0)
    append_entry(args.history, metrics, args.source)

    guarded = sum(1 for k in metrics if guard_direction(k))
    compared = sum(1 for k in metrics if guard_direction(k) and k in prev)
    print(f"bench_gate: {len(metrics)} metrics ({guarded} guarded, "
          f"{compared} compared vs history, {unguarded} record-only) "
          f"-> {args.history}")
    for key, old, new, ratio in improvements:
        print(f"  improved  {key}: {old:.6g} -> {new:.6g} ({ratio:.2f}x)")
    if not compared:
        print("bench_gate: no previous entry; recorded baseline")
        return 0
    if regressions:
        for key, old, new, ratio in regressions:
            print(f"  REGRESSED {key}: {old:.6g} -> {new:.6g} "
                  f"({ratio:.2f}x, bar {args.bar:.0f}%)", file=sys.stderr)
        return 1
    print(f"bench_gate: OK (no guarded metric degraded >{args.bar:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
