"""Per-architecture reduced-config smoke tests: one forward/train step on CPU,
shape and finiteness checks, and prefill+decode == teacher-forced consistency.
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_arch, reduced
from repro.models import build_model, concrete_batch

ALL = list(ASSIGNED_ARCHS) + list(PAPER_ARCHS)


def _model_and_params(name, no_drop_moe=False):
    cfg = reduced(get_arch(name))
    if no_drop_moe and cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe,
                                       capacity_factor=float(cfg.moe.num_experts)))
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    return cfg, m, m.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("name", ALL)
def test_train_step_shapes_and_finite(name):
    cfg, m, params = _model_and_params(name)
    seq = 64 if cfg.local_window else 32
    batch = concrete_batch(cfg, "train", 2, seq)
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert jnp.isfinite(loss), name
    # every gradient leaf finite and shape-matched
    for g, p in zip(jax.tree.leaves(grads), jax.tree.leaves(params)):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g))), name


@pytest.mark.parametrize("name", ALL)
def test_prefill_then_decode_matches_full_forward(name):
    cfg, m, params = _model_and_params(name, no_drop_moe=True)
    seq = 64 if cfg.local_window else 32
    cache_len = cfg.local_window if cfg.local_window else seq + 8
    batch = concrete_batch(cfg, "prefill", 2, seq)
    toks = batch["tokens"]

    b1 = dict(batch)
    b1["tokens"] = toks[:, :-1]
    _, cache = m.prefill(params, b1, cache_len=cache_len)
    logits_dec, _ = m.decode_step(params, cache, toks[:, -1:])
    logits_full, _ = m.prefill(params, batch, cache_len=cache_len)
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err < 5e-3, (name, err)


@pytest.mark.parametrize("name", ALL)
def test_decode_two_steps_advance_cache(name):
    cfg, m, params = _model_and_params(name, no_drop_moe=True)
    seq = 64 if cfg.local_window else 16
    cache_len = cfg.local_window if cfg.local_window else seq + 8
    batch = concrete_batch(cfg, "prefill", 1, seq)
    logits, cache = m.prefill(params, batch, cache_len=cache_len)
    assert int(cache["pos"]) > 0
    t1 = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    logits2, cache2 = m.decode_step(params, cache, t1)
    assert int(cache2["pos"]) == int(cache["pos"]) + 1
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_moe_capacity_dropping_occurs():
    """With a tight capacity factor, some tokens must be dropped (their
    combine output is zero) — the dropping path is exercised."""
    from repro.models.moe import apply_moe, capacity
    cfg = reduced(get_arch("olmoe-1b-7b"))
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=0.25, top_k=2))
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    # find the MoE block params (pattern slot 0, first rep)
    slot = params["blocks"][0]["ffn"]
    p = jax.tree.map(lambda a: a[0], slot)
    out, aux = apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert float(aux) > 0.0


def test_ssm_chunked_equals_recurrent():
    """SSD chunked scan must equal the token-by-token recurrence."""
    import numpy as np
    from repro.models.ssm import ssd_chunked, ssd_step
    rng = np.random.default_rng(0)
    b, S, H, P, N = 2, 32, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(b, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, size=(H,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, S, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, S, N)), jnp.float32)
    y_chunk, state_chunk = ssd_chunked(x, dt, A, B, C, chunk=8)
    state = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        y, state = ssd_step(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    y_rec = jnp.stack(ys, axis=1)
    assert float(jnp.max(jnp.abs(y_chunk - y_rec))) < 1e-4
    assert float(jnp.max(jnp.abs(state_chunk - state))) < 1e-4


def test_rglru_scan_equals_stepwise():
    import numpy as np
    from repro.models.rglru import rglru_scan
    rng = np.random.default_rng(1)
    b, S, w = 2, 24, 8
    a_log = jnp.asarray(-rng.uniform(0.01, 1.0, size=(b, S, w)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, S, w)), jnp.float32)
    h_scan, h_last = rglru_scan(x, a_log)
    h = jnp.zeros((b, w))
    a = jnp.exp(a_log)
    for t in range(S):
        h = a[:, t] * h + x[:, t]
        assert float(jnp.max(jnp.abs(h - h_scan[:, t]))) < 1e-5
    assert float(jnp.max(jnp.abs(h - h_last))) < 1e-5


def test_local_attention_window_semantics():
    """A token beyond the window must have zero influence."""
    from repro.models.attention import local_attention
    rng = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(rng, 3)
    B, S, H, h, W = 1, 64, 2, 8, 16
    q = jax.random.normal(k1, (B, S, H, h))
    k = jax.random.normal(k2, (B, S, H, h))
    v = jax.random.normal(k3, (B, S, H, h))
    out1 = local_attention(q, k, v, W)
    # perturb a key/value far outside every later query's window
    k2v = k.at[:, 0].add(10.0)
    v2v = v.at[:, 0].add(10.0)
    out2 = local_attention(q, k2v, v2v, W)
    # queries at position >= 2W can never see position 0
    assert float(jnp.max(jnp.abs(out1[:, 2 * W:] - out2[:, 2 * W:]))) < 1e-5
    # position 0 itself must change
    assert float(jnp.max(jnp.abs(out1[:, 0] - out2[:, 0]))) > 1e-3
