"""PSS decode fast path: probe bit-exactness, synthesis equivalence across
configs, adaptive refinement on full-size models, DES layer memoization,
and the bit-identical traffic fast-forward."""
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.workload import build_decode_graph, decode_probe_contexts
from repro.sim.accelerator import baseline_accelerator
from repro.sim.engine import Engine, simulate
from repro.sim.pss import simulate_decode
from repro.traffic.generators import LengthModel, generate
from repro.traffic.occupancy import simulate_traffic

MIB = 2**20


def _mini(arch):
    return reduced(get_arch(arch), layers=2)


def _assert_equivalent(ex, ps, time_rtol=5e-3):
    """The PSS exactness contract against a step-by-step reference
    (`time_rtol` mirrors simulate_decode's documented timing bound)."""
    assert ps.fidelity == "pss"
    assert ex.total_macs == ps.total_macs
    assert ex.total_vector_ops == ps.total_vector_ops
    assert ex.access.reads_bytes == ps.access.reads_bytes
    assert ex.access.writes_bytes == ps.access.writes_bytes
    assert abs(ex.total_time - ps.total_time) <= time_rtol * ex.total_time
    for m in ex.traces:
        for i in range(ex.steps):
            te, dne, doe = ex.step_events(m, i)
            tp, dnp, dop = ps.step_events(m, i)
            if ex.step_ctx(i) in ps.probes:
                # probe steps: the exact DES stream, bit-for-bit
                assert np.array_equal(te, tp), (m, i)
                assert np.array_equal(dne, dnp), (m, i)
                assert np.array_equal(doe, dop), (m, i)
            else:
                # interior: needed deltas exact (drops never touch needed),
                # each step zero-balanced, times within the documented bound
                assert dne.sum() == dnp.sum() == 0, (m, i)
                assert doe.sum() == dop.sum() == 0, (m, i)
                order_e = np.argsort(te, kind="stable")
                order_p = np.argsort(tp, kind="stable")
                ne = np.cumsum(dne[order_e])
                npv = np.cumsum(dnp[order_p])
                assert ne.max(initial=0) == npv.max(initial=0), (m, i)
        assert ex.traces[m].peak_needed() == ps.traces[m].peak_needed(), m


# --- PSS vs exact DES across (config x context x subops) --------------------

FAST_GRID = [
    ("gpt2-xl", 64, 24, 2),
    ("dsr1d-qwen-1.5b", 64, 24, 2),
    ("dsr1d-qwen-1.5b", 200, 17, 1),
]
SLOW_GRID = [
    ("gpt2-xl", 256, 96, 4),
    ("dsr1d-qwen-1.5b", 256, 96, 4),
    ("gpt2-xl", 1024, 64, 2),
    ("dsr1d-qwen-1.5b", 1024, 64, 2),
]


@pytest.mark.parametrize("arch,start,steps,subops", FAST_GRID)
def test_pss_matches_exact_mini(arch, start, steps, subops):
    cfg = _mini(arch)
    accel = baseline_accelerator(32)
    kw = dict(start_ctx=start, steps=steps, batch=4, subops=subops)
    ex = simulate_decode(cfg, accel, fidelity="exact", **kw)
    ps = simulate_decode(cfg, accel, fidelity="pss", **kw)
    _assert_equivalent(ex, ps)
    # on eviction-free mini configs the whole stream is structural, so
    # interior deltas are bit-exact too, not just the needed curve
    for m in ex.traces:
        assert ex.traces[m].ev_dneeded == ps.traces[m].ev_dneeded
        assert ex.traces[m].ev_dobsolete == ps.traces[m].ev_dobsolete
        assert np.allclose(ex.traces[m].ev_times, ps.traces[m].ev_times,
                           rtol=1e-3, atol=1e-9)
        assert ex.traces[m].peak_total() == ps.traces[m].peak_total()


@pytest.mark.slow
@pytest.mark.parametrize("arch,start,steps,subops", SLOW_GRID)
def test_pss_matches_exact_slow(arch, start, steps, subops):
    cfg = _mini(arch)
    accel = baseline_accelerator(32)
    kw = dict(start_ctx=start, steps=steps, batch=8, subops=subops)
    ex = simulate_decode(cfg, accel, fidelity="exact", **kw)
    ps = simulate_decode(cfg, accel, fidelity="pss", **kw)
    _assert_equivalent(ex, ps)


@pytest.mark.slow
def test_pss_full_config_refinement():
    """Full-size dsr1d streams more weights than the SRAM per step, so the
    drop stream is only piecewise affine — adaptive refinement must still
    plan a PSS run and keep the needed curve exact."""
    cfg = get_arch("dsr1d-qwen-1.5b")
    accel = baseline_accelerator(128)
    kw = dict(start_ctx=2048, steps=48, batch=8, subops=2)
    ex = simulate_decode(cfg, accel, fidelity="exact", **kw)
    ps = simulate_decode(cfg, accel, fidelity="pss", **kw)
    _assert_equivalent(ex, ps)
    assert len(ps.probes) < kw["steps"] // 2


# --- probe construction ------------------------------------------------------

def test_decode_probe_contexts():
    pts = decode_probe_contexts(100, 1000, 3)
    assert pts[0] == 100 and pts[-1] == 1099
    assert pts == sorted(set(pts))
    assert len(pts) == 3
    assert decode_probe_contexts(5, 3, 4) == [5, 6, 7]     # degenerate
    assert decode_probe_contexts(1, 1) == [1]
    with pytest.raises(ValueError):
        decode_probe_contexts(1, 0)
    with pytest.raises(ValueError):
        decode_probe_contexts(1, 10, 1)


def test_explicit_probes_validated():
    cfg = _mini("dsr1d-qwen-1.5b")
    accel = baseline_accelerator(32)
    with pytest.raises(ValueError):
        simulate_decode(cfg, accel, start_ctx=64, steps=8, batch=4,
                        subops=2, probes=[500])


# --- fidelity dispatch -------------------------------------------------------

def test_obsolete_evictions_alone_stay_pss():
    """Pure obsolete evictions (free drops) are the borrowed-drop stream,
    not a PSS blocker — only write-backs force the exact path."""
    cfg = _mini("gpt2-xl")
    accel = baseline_accelerator(8).with_sram_capacity(48 * 1024)
    res = simulate_decode(cfg, accel, start_ctx=64, steps=16, batch=4,
                          subops=2, fidelity="auto")
    assert res.writebacks == 0


def test_auto_falls_back_on_writebacks():
    cfg = _mini("gpt2-xl")
    accel = baseline_accelerator(8).with_sram_capacity(16 * 1024)
    res = simulate_decode(cfg, accel, start_ctx=64, steps=16, batch=4,
                          subops=2, fidelity="auto", max_probes=4)
    assert res.fidelity == "exact"
    assert res.fallback_reason
    assert res.writebacks > 0


def test_forced_pss_raises_when_budget_exhausted():
    cfg = _mini("gpt2-xl")
    accel = baseline_accelerator(8).with_sram_capacity(16 * 1024)
    with pytest.raises(ValueError, match="budget"):
        simulate_decode(cfg, accel, start_ctx=64, steps=16, batch=4,
                        subops=2, fidelity="pss", max_probes=4)


def test_small_horizon_degenerates_to_exact():
    cfg = _mini("dsr1d-qwen-1.5b")
    accel = baseline_accelerator(32)
    res = simulate_decode(cfg, accel, start_ctx=64, steps=3, batch=4,
                          subops=2, fidelity="pss")
    assert res.fidelity == "exact"
    assert res.probes == (64, 65, 66)


# --- Stage-II consumption ----------------------------------------------------

def test_decode_result_feeds_stage_two():
    from repro.core.explorer import min_capacity_mib, sweep
    cfg = _mini("dsr1d-qwen-1.5b")
    res = simulate_decode(cfg, baseline_accelerator(32), start_ctx=64,
                          steps=32, batch=4, subops=2, fidelity="pss")
    lo = min_capacity_mib(res.peak_needed("sram"))
    table = sweep(res, mem_name="sram", capacities_mib=[lo], banks=(1, 4),
                  backend="numpy")
    assert len(table.rows) == 2
    assert table.best().result.e_total > 0


# --- DES layer memoization ---------------------------------------------------

@pytest.mark.parametrize("arch", ["gpt2-xl", "dsr1d-qwen-1.5b"])
def test_memoized_engine_bit_exact_occupancy(arch):
    g = build_decode_graph(get_arch(arch), context_len=384, batch=4,
                           subops=2)
    accel = baseline_accelerator(128)
    a = simulate(g, accel)
    eng = Engine(g, accel, memoize_layers=True)
    b = eng.run()
    assert b.replayed_layers > 0, eng.memo_misses
    assert a.writebacks == b.writebacks
    assert a.total_macs == b.total_macs
    assert a.access.reads_bytes == b.access.reads_bytes
    assert a.access.writes_bytes == b.access.writes_bytes
    for m in a.traces:
        assert a.traces[m].ev_dneeded == b.traces[m].ev_dneeded
        assert a.traces[m].ev_dobsolete == b.traces[m].ev_dobsolete
        assert np.allclose(a.traces[m].ev_times, b.traces[m].ev_times,
                           rtol=1e-9, atol=1e-12)
        assert a.traces[m].peak_needed() == b.traces[m].peak_needed()
        assert a.traces[m].peak_total() == b.traces[m].peak_total()
    assert abs(a.total_time - b.total_time) <= 1e-9 * a.total_time


def test_memoization_respects_mempeak_policy():
    g = build_decode_graph(_mini("dsr1d-qwen-1.5b"), context_len=128,
                           batch=4, subops=2)
    eng = Engine(g, baseline_accelerator(32), policy="mempeak",
                 memoize_layers=True)
    assert not eng.memoize_layers        # fifo-only fast path
    assert eng.run().replayed_layers == 0


# --- traffic fast-forward ----------------------------------------------------

@pytest.mark.parametrize("arch", ["dsr1d-qwen-1.5b", "recurrentgemma-2b",
                                  "mamba2-130m"])
def test_traffic_fast_forward_bit_identical(arch):
    """The PSS traffic path must reproduce the exact lockstep loop
    bit-for-bit: same event list, same float times, same stats."""
    cfg = get_arch(arch)
    reqs = generate("bursty", 5.0, 20.0, seed=7,
                    lengths=LengthModel(max_len=512))
    a = simulate_traffic(cfg, reqs, num_slots=4, max_len=512,
                         fidelity="exact")
    b = simulate_traffic(cfg, reqs, num_slots=4, max_len=512,
                         fidelity="pss")
    assert a.trace.ev_times == b.trace.ev_times
    assert a.trace.ev_dneeded == b.trace.ev_dneeded
    assert a.trace.ev_dobsolete == b.trace.ev_dobsolete
    assert a.bundle.access.reads_bytes == b.bundle.access.reads_bytes
    assert a.bundle.access.writes_bytes == b.bundle.access.writes_bytes
    assert a.total_time == b.total_time
    assert a.stats.decode_steps == b.stats.decode_steps
    assert a.stats.latency_s == b.stats.latency_s
    assert a.stats.queue_delay_s == b.stats.queue_delay_s
    assert a.stats.admitted_bytes == b.stats.admitted_bytes
    assert a.stats.retired_bytes == b.stats.retired_bytes


def test_traffic_fidelity_validated():
    cfg = get_arch("dsr1d-qwen-1.5b")
    with pytest.raises(ValueError):
        simulate_traffic(cfg, [], fidelity="bogus")
