"""Golden-trace conformance: the Stage-I DES is regression-locked.

The fixtures in `tests/golden/stage1_golden.json` freeze exact-DES
occupancy segments (integer byte values) and access statistics for mini
MHA/GQA prefill and decode cases. Any simulator change that alters them
must regenerate via `scripts/regen_golden.py` and justify the diff.

Also locked against the same fixtures: the layer-memoization fast path
(occupancy bit-exact, timestamps to float-translation error) and the PSS
probe contract (a probe step's event stream is the exact DES stream)."""
import json
import os

import numpy as np
import pytest

import golden_util
from golden_util import CASES, GOLDEN_PATH, case_payload, diff_payload

from repro.configs import get_arch, reduced
from repro.sim.accelerator import baseline_accelerator
from repro.sim.pss import simulate_decode
from repro.sim.trace import OccupancyTrace

DECODE_CASES = [n for n, s in CASES.items() if s["phase"] == "decode"]


@pytest.fixture(scope="module")
def golden():
    assert os.path.exists(GOLDEN_PATH), \
        "missing fixtures: run PYTHONPATH=src python scripts/regen_golden.py"
    with open(GOLDEN_PATH) as f:
        data = json.load(f)
    assert sorted(data) == sorted(CASES)
    return data


@pytest.mark.parametrize("case", sorted(CASES))
def test_exact_des_matches_golden(case, golden):
    errs = diff_payload(case_payload(case), golden[case])
    assert not errs, "\n".join(
        [f"{case} drifted from golden fixture — if intentional, regenerate "
         f"with scripts/regen_golden.py:"] + errs)


@pytest.mark.parametrize("case", sorted(CASES))
def test_memoized_des_matches_golden(case, golden):
    """Layer replay keeps integer occupancy/access bit-exact; timestamps
    agree to float-translation error (engine.MEMO_REL_TOL)."""
    got = case_payload(case, memoize_layers=True)
    errs = diff_payload(got, golden[case], time_rtol=1e-9)
    assert not errs, "\n".join([f"{case} (memoize_layers=True):"] + errs)


@pytest.mark.parametrize("case", DECODE_CASES)
def test_pss_probe_step_matches_golden(case, golden):
    """A PSS probe step's synthesized stream IS the exact DES stream: its
    integrated segments must equal the golden fixture bit-for-bit."""
    spec = CASES[case]
    cfg = reduced(get_arch(spec["arch"]), layers=2)
    accel = baseline_accelerator(spec["sram_mib"])
    start = spec["ctx"] - 6
    res = simulate_decode(cfg, accel, start_ctx=start, steps=12,
                          batch=spec["batch"], subops=spec["subops"],
                          fidelity="pss", probes=[spec["ctx"]])
    assert res.fidelity == "pss"
    assert spec["ctx"] in res.probes
    i = spec["ctx"] - start
    for m, want in golden[case]["mems"].items():
        rel_t, dn, do = res.step_events(m, i)
        tr = OccupancyTrace(m, accel.mem(m).capacity)
        tr.extend(rel_t, dn, do)
        # the trailing drain event sits exactly at the step latency, so the
        # zero-duration segment it opens is filtered and the integrated
        # segments equal the raw single-step DES trace bit-for-bit
        dur, needed, obsolete, _ = tr.segments(float(res.step_latency[i]))
        assert [int(v) for v in needed] == want["needed"], m
        assert [int(v) for v in obsolete] == want["obsolete"], m
        assert [float(d) for d in dur] == want["durations"], m


# ---------------------------------------------------------------------------
# Shared-prefix serving golden: the dual logical/physical occupancy traces
# of the prefix-sharing simulator are regression-locked (host-level, fully
# deterministic: seeded workload -> radix index / COW ledger -> traces)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prefix_golden():
    assert os.path.exists(golden_util.PREFIX_GOLDEN_PATH), \
        "missing fixtures: run PYTHONPATH=src python scripts/regen_golden.py"
    data = golden_util.load_prefix_golden()
    assert sorted(data) == sorted(golden_util.PREFIX_CASES)
    return data


@pytest.mark.parametrize("case", sorted(golden_util.PREFIX_CASES))
def test_prefix_occupancy_matches_golden(case, prefix_golden):
    got = golden_util.prefix_case_payload(case)
    want = prefix_golden[case]
    errs = []
    for key in ("n_requests", "stats", "access_reads", "access_writes"):
        if got[key] != want[key]:
            errs.append(f"{key}: {got[key]!r} != {want[key]!r}")
    if got["total_time"] != want["total_time"]:
        errs.append(f"total_time: {got['total_time']!r} != "
                    f"{want['total_time']!r}")
    assert sorted(got["mems"]) == sorted(want["mems"]) == \
        ["kv", "kv_logical"]
    for m, w in want["mems"].items():
        g = got["mems"][m]
        for key in ("n_events", "peak_needed", "peak_total", "final_needed",
                    "final_obsolete", "needed", "obsolete", "durations"):
            if g[key] != w[key]:
                errs.append(f"{m}.{key} mismatch")
    assert not errs, "\n".join(
        [f"{case} drifted from prefix golden — if intentional, regenerate "
         f"with scripts/regen_golden.py:"] + errs)


@pytest.mark.parametrize("case", sorted(golden_util.PREFIX_CASES))
def test_prefix_golden_invariants(case, prefix_golden):
    """Structural invariants of the frozen fixtures themselves: physical
    needed <= logical everywhere, both drain to zero, sharing happened."""
    want = prefix_golden[case]
    phys = want["mems"]["kv"]
    logi = want["mems"]["kv_logical"]
    assert phys["peak_needed"] <= logi["peak_needed"]
    assert phys["final_needed"] == 0 and logi["final_needed"] == 0
    assert want["stats"]["prefix_hits"] > 0
    assert want["stats"]["prefix_tokens_reused"] > 0
    assert want["stats"]["cow_splits"] > 0
    assert all(v >= 0 for v in phys["obsolete"])


# ---------------------------------------------------------------------------
# Quantized-ledger golden: the same prefix scenarios priced at 1 payload
# byte/element (int8 / fp8 pools); locks the kv_dtype_bytes plumbing
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def quant_golden():
    assert os.path.exists(golden_util.QUANT_GOLDEN_PATH), \
        "missing fixtures: run PYTHONPATH=src python scripts/regen_golden.py"
    data = golden_util.load_quant_golden()
    assert sorted(data) == sorted(golden_util.QUANT_CASES)
    return data


@pytest.mark.parametrize("case", sorted(golden_util.QUANT_CASES))
def test_quant_occupancy_matches_golden(case, quant_golden):
    got = golden_util.quant_case_payload(case)
    want = quant_golden[case]
    errs = []
    for key in ("n_requests", "stats", "access_reads", "access_writes",
                "kv_dtype_bytes"):
        if got[key] != want[key]:
            errs.append(f"{key}: {got[key]!r} != {want[key]!r}")
    if got["total_time"] != want["total_time"]:
        errs.append(f"total_time: {got['total_time']!r} != "
                    f"{want['total_time']!r}")
    for m, w in want["mems"].items():
        g = got["mems"][m]
        for key in ("n_events", "peak_needed", "peak_total", "final_needed",
                    "final_obsolete", "needed", "obsolete", "durations"):
            if g[key] != w[key]:
                errs.append(f"{m}.{key} mismatch")
    assert not errs, "\n".join(
        [f"{case} drifted from quant golden — if intentional, regenerate "
         f"with scripts/regen_golden.py:"] + errs)


@pytest.mark.parametrize("case", sorted(golden_util.QUANT_CASES))
def test_quant_golden_is_exact_byte_rescale_of_prefix(case, quant_golden,
                                                      prefix_golden):
    """The quantized fixture must be the bf16 prefix fixture with every
    occupancy level scaled by the byte ratio — same events, same times,
    same page counts. Any other difference means kv_dtype leaked into the
    host scheduling, which it never may."""
    want = quant_golden[case]
    base = prefix_golden[want["base_case"]]
    ratio = 2 // want["kv_dtype_bytes"]          # bf16 -> 1-byte pools
    assert ratio == 2
    assert want["total_time"] == base["total_time"]
    assert want["stats"] == base["stats"]
    for m in ("kv", "kv_logical"):
        w, b = want["mems"][m], base["mems"][m]
        assert w["n_events"] == b["n_events"]
        assert w["durations"] == b["durations"]
        for key in ("peak_needed", "peak_total", "final_needed",
                    "final_obsolete"):
            assert w[key] * ratio == b[key], (m, key)
        assert [v * ratio for v in w["needed"]] == b["needed"]
        assert [v * ratio for v in w["obsolete"]] == b["obsolete"]


# ---------------------------------------------------------------------------
# Speculative-decoding golden: the burst/rollback occupancy of the spec
# simulator is regression-locked (seeded acceptance draws -> verify-window
# bursts -> truncate_rows rollbacks across both KV lanes)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def spec_golden():
    assert os.path.exists(golden_util.SPEC_GOLDEN_PATH), \
        "missing fixtures: run PYTHONPATH=src python scripts/regen_golden.py"
    data = golden_util.load_spec_golden()
    assert sorted(data) == sorted(golden_util.SPEC_CASES)
    return data


@pytest.mark.parametrize("case", sorted(golden_util.SPEC_CASES))
def test_spec_occupancy_matches_golden(case, spec_golden):
    got = golden_util.spec_case_payload(case)
    want = spec_golden[case]
    errs = []
    for key in ("n_requests", "stats", "n_neg_deltas", "access_reads",
                "access_writes"):
        if got[key] != want[key]:
            errs.append(f"{key}: {got[key]!r} != {want[key]!r}")
    if got["total_time"] != want["total_time"]:
        errs.append(f"total_time: {got['total_time']!r} != "
                    f"{want['total_time']!r}")
    for m, w in want["mems"].items():
        g = got["mems"][m]
        for key in ("n_events", "peak_needed", "peak_total", "final_needed",
                    "final_obsolete", "needed", "obsolete", "durations"):
            if g[key] != w[key]:
                errs.append(f"{m}.{key} mismatch")
    assert not errs, "\n".join(
        [f"{case} drifted from spec golden — if intentional, regenerate "
         f"with scripts/regen_golden.py:"] + errs)


@pytest.mark.parametrize("case", sorted(golden_util.SPEC_CASES))
def test_spec_golden_invariants(case, spec_golden):
    """Structural invariants of the frozen fixtures: rollbacks really
    happened (mid-stream negative deltas strictly outnumber retires, and
    pages were rolled back), acceptance sits inside each round's [1, k+1]
    window, and the trace drains to zero."""
    want = spec_golden[case]
    st = want["stats"]
    k = golden_util.SPEC_CASES[case]["spec_k"]
    kv = want["mems"]["kv"]
    assert st["spec_rounds"] > 0
    assert st["rolled_back_pages"] > 0
    assert st["drafted_tokens"] == st["spec_rounds"] * k
    assert st["spec_rounds"] <= st["accepted_tokens"] \
        <= st["spec_rounds"] * (k + 1)
    # the rollback occupancy signature: more frees than request retirements
    assert want["n_neg_deltas"] > st["finished"]
    assert kv["final_needed"] == 0 and kv["final_obsolete"] == 0
    assert kv["peak_needed"] <= kv["peak_total"]
    assert all(v >= 0 for v in kv["needed"])
    assert all(d >= 0 for d in kv["durations"])


@pytest.fixture(scope="module")
def energy_golden():
    assert os.path.exists(golden_util.ENERGY_GOLDEN_PATH), \
        "missing fixtures: run PYTHONPATH=src python scripts/regen_golden.py"
    data = golden_util.load_energy_golden()
    assert sorted(data) == sorted(golden_util.ENERGY_CASES)
    return data


@pytest.mark.parametrize("case", sorted(golden_util.ENERGY_CASES))
def test_energy_export_matches_golden(case, energy_golden):
    """The streamed meter's Perfetto bank-state export is frozen: track
    schema (process/lane/counter names, span key set), per-state interval
    counts, wake-cause counters and the exact f64 energy totals."""
    got = golden_util.energy_case_payload(case)
    want = energy_golden[case]
    assert got["track_schema"] == want["track_schema"]
    assert got["n_span_events"] == want["n_span_events"]
    assert got["state_counts"] == want["state_counts"]
    assert got["wakes"] == want["wakes"]
    assert got["n_meter_events"] == want["n_meter_events"]
    assert got["n_transitions"] == want["n_transitions"]
    # exact f64: JSON round-trips doubles losslessly
    for key in ("e_leak_j", "e_sw_j", "live_e_j",
                "energy_counter_total_j", "stall_s", "total_time"):
        assert got[key] == want[key], (key, got[key], want[key])


@pytest.mark.parametrize("case", sorted(golden_util.ENERGY_CASES))
def test_energy_export_is_lossless(case, energy_golden):
    """The exported energy counter track carries the meter's exact live
    total (after a real JSON round-trip), and the active-banks counter
    integrates to the timeline's bank-seconds."""
    from repro.obs.perfetto import (ACTIVE_COUNTER, bank_state_events,
                                    counter_integral, energy_counter_total)
    meter, end = golden_util._energy_case_run(case)
    evs = json.loads(json.dumps(bank_state_events(meter, end_time=end)))
    assert energy_counter_total(evs) == meter.energy_j(end)
    assert energy_counter_total(evs) == energy_golden[case]["live_e_j"]
    t0s, durs, act = meter.activity_series(end)
    end_us = float((t0s[-1] + durs[-1]) * 1e6)
    got = counter_integral(evs, ACTIVE_COUNTER, end_us, series="active")
    assert np.isclose(got / 1e6, float((act * durs).sum()), rtol=1e-9)
    # bank-state spans tile each bank's lane without gaps or overlap
    by_bank = {}
    for e in evs:
        if e.get("ph") == "X":
            by_bank.setdefault(e["args"]["bank"], []).append(
                (e["ts"], e["ts"] + e["dur"]))
    for b, spans in by_bank.items():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1 - 1e-6, (b, a1, b0)


def test_fixture_case_coverage(golden):
    """Both paper workloads appear in both phases, and fixtures are sane."""
    phases = {(CASES[n]["arch"], CASES[n]["phase"]) for n in golden}
    for arch in ("gpt2-xl", "dsr1d-qwen-1.5b"):
        assert (arch, "prefill") in phases
        assert (arch, "decode") in phases
    for name, case in golden.items():
        assert case["writebacks"] == 0, name
        for m, mem in case["mems"].items():
            assert mem["peak_needed"] <= mem["peak_total"], (name, m)
            assert all(d >= 0 for d in mem["durations"]), (name, m)
            assert all(v >= 0 for v in mem["needed"]), (name, m)
