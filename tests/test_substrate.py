"""Data pipeline, optimizer, compression, checkpointing, fault tolerance."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, Prefetcher, SyntheticTokens
from repro.optim import (AdamW, apply_compression, constant,
                         cosine_with_warmup, init_error_state)
from repro.train import checkpoint as ckpt


# --- data ---------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    ds = SyntheticTokens(DataConfig(vocab_size=100, seq_len=16,
                                    global_batch=4, seed=7))
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch_at(6)["tokens"], b1["tokens"])


def test_data_shards_disjoint_streams():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=1)
    a = SyntheticTokens(cfg, shard=0, num_shards=2).batch_at(3)
    b = SyntheticTokens(cfg, shard=1, num_shards=2).batch_at(3)
    assert a["tokens"].shape == (4, 32)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_prefetcher_order_and_stop():
    ds = SyntheticTokens(DataConfig(vocab_size=50, seq_len=8, global_batch=2))
    pf = Prefetcher(ds, start_step=10)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    pf.stop()
    assert (s0, s1) == (10, 11)
    np.testing.assert_array_equal(b0["tokens"], ds.batch_at(10)["tokens"])


# --- optimizer -------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=constant(0.1), weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=constant(1e-2), clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full(3, 1e6)}, state, params)
    assert float(metrics["grad_norm"]) > 1e5   # pre-clip norm reported


def test_cosine_schedule_shape():
    lr = cosine_with_warmup(1.0, 10, 100)
    assert float(lr(jnp.array(0))) == 0.0
    assert float(lr(jnp.array(10))) == pytest.approx(1.0)
    assert float(lr(jnp.array(100))) == pytest.approx(0.1, abs=1e-3)


def test_int8ef_compression_error_feedback():
    params = {"w": jnp.zeros(64)}
    err = init_error_state(params)
    g = {"w": jnp.linspace(-1e-4, 1e-4, 64)}    # tiny grads quantize to ~0
    total = jnp.zeros(64)
    for _ in range(50):
        deq, err = apply_compression(g, "int8ef", err)
        total = total + deq["w"]
    # with error feedback, the accumulated compressed signal tracks the truth
    expect = g["w"] * 50
    assert float(jnp.abs(total - expect).max()) < 2e-4


# --- checkpointing -----------------------------------------------------------------

@pytest.fixture()
def ckdir(tmp_path):
    return str(tmp_path / "ck")


def test_checkpoint_roundtrip_exact(ckdir):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": jnp.array(7, jnp.int32)}}
    ckpt.save(ckdir, 42, tree)
    step, restored = ckpt.restore(ckdir, tree)
    assert step == 42
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_checkpoint_latest_and_prune(ckdir):
    tree = {"x": jnp.zeros(2)}
    for s in (10, 20, 30, 40):
        ckpt.save(ckdir, s, tree)
    assert ckpt.latest_step(ckdir) == 40
    ckpt.prune(ckdir, keep_last=2)
    assert ckpt.latest_step(ckdir) == 40
    assert sorted(os.listdir(ckdir)) == ["step_00000030", "step_00000040"]


def test_checkpoint_tmp_dirs_ignored(ckdir):
    tree = {"x": jnp.zeros(2)}
    ckpt.save(ckdir, 5, tree)
    os.makedirs(os.path.join(ckdir, "step_00000099.tmp_p0"))
    assert ckpt.latest_step(ckdir) == 5


def test_async_checkpointer(ckdir):
    tree = {"x": jnp.arange(4.0)}
    ac = ckpt.AsyncCheckpointer(ckdir, keep_last=2)
    ac.save_async(1, tree)
    ac.save_async(2, tree)        # waits for the first internally
    ac.wait()
    assert ckpt.latest_step(ckdir) == 2


def test_restore_shape_mismatch_raises(ckdir):
    ckpt.save(ckdir, 1, {"x": jnp.zeros(4)})
    with pytest.raises(AssertionError):
        ckpt.restore(ckdir, {"x": jnp.zeros(5)})


# --- fault tolerance: preemption == uninterrupted -------------------------------

def test_preemption_recovery_bit_exact(tmp_path):
    from repro.configs import get_arch, reduced
    from repro.models import build_model
    from repro.train import LoopConfig, TrainLoop

    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=4, seed=3))
    opt = AdamW(lr=constant(1e-3))

    def run(ckdir, fail_at=None):
        m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
        loop = TrainLoop(m, opt, data,
                         LoopConfig(total_steps=12, ckpt_every=4,
                                    ckpt_dir=ckdir),
                         fail_at_step=fail_at)
        return loop

    d1 = str(tmp_path / "uninterrupted")
    out1 = run(d1).run()

    d2 = str(tmp_path / "preempted")
    with pytest.raises(RuntimeError):
        run(d2, fail_at=6).run()
    out2 = run(d2).run()

    # identical final params: preemption is invisible
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    # and the loss trajectory after resume matches
    l1 = {h["step"]: h["loss"] for h in out1["history"]}
    l2 = {h["step"]: h["loss"] for h in out2["history"]}
    for s in range(8, 12):
        assert l1[s] == pytest.approx(l2[s], rel=1e-6)


def test_straggler_monitor():
    from repro.train import StragglerMonitor
    mon = StragglerMonitor(factor=3.0, window=5)
    for i in range(10):
        assert not mon.observe(i, 0.1)
    assert mon.observe(10, 1.0)
    assert mon.flagged == [10]


def test_microbatch_accumulation_matches_full_batch():
    """make_train_step(microbatches=k) must produce the same update as the
    full-batch step (same mean gradient)."""
    from repro.configs import get_arch, reduced
    from repro.models import build_model
    from repro.train.step import make_train_step

    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=constant(1e-2))
    state = opt.init(params)
    from repro.models import concrete_batch
    batch = concrete_batch(cfg, "train", 4, 16)

    p1, s1, m1 = make_train_step(m, opt)(params, state, batch)
    p2, s2, m2 = make_train_step(m, opt, microbatches=2)(params, state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    # Adam's per-element rescaling amplifies fp reassociation where v ~ 0;
    # the gradients themselves agree to fp32 summation order
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=1e-4)
