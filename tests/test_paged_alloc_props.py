"""Hypothesis property tests on the page allocator + page ledger (the
host-side half of the paged serving path), mirroring test_trace_props.py."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.paged import (OutOfPages, PageAllocator,  # noqa: E402
                               PagedKVLedger, pages_for)

PAGE_BYTES = 4096

# interleaved allocator ops: (alloc n) as positive ints, (free batch i) as
# negative picks of an outstanding allocation
alloc_ops_st = st.lists(st.integers(-5, 6), min_size=1, max_size=80)

# request lifetimes driven through the ledger: (slot, prompt_pages, grow
# steps, inter-event gap)
request_st = st.lists(
    st.tuples(st.integers(0, 3),                 # slot id
              st.integers(0, 4),                 # prompt pages
              st.lists(st.integers(0, 3), max_size=4),   # page growth deltas
              st.floats(0.0, 2.0)),              # time gap
    min_size=1, max_size=30)


@given(st.integers(2, 64), alloc_ops_st)
@settings(max_examples=80, deadline=None)
def test_allocator_invariants(num_pages, ops):
    """No double allocation, null page never handed out, frees restore the
    free count, conservation of pages throughout."""
    a = PageAllocator(num_pages)
    outstanding = []
    seen_live = set()
    for op in ops:
        if op > 0:
            try:
                pages = a.alloc(op)
            except OutOfPages:
                assert op > a.n_free
                continue
            assert len(pages) == op
            assert 0 not in pages                       # null page reserved
            assert not (set(pages) & seen_live)         # no double allocation
            seen_live.update(pages)
            outstanding.append(pages)
        elif op < 0 and outstanding:
            pages = outstanding.pop(abs(op) % len(outstanding))
            before = a.n_free
            a.free(pages)
            assert a.n_free == before + len(pages)
            seen_live.difference_update(pages)
        assert a.n_free + a.n_allocated == num_pages - 1
        assert a.n_allocated == len(seen_live)
    # full drain returns the pool to pristine
    for pages in outstanding:
        a.free(pages)
    assert a.n_allocated == 0 and a.n_free == num_pages - 1


def test_allocator_rejects_double_free_and_foreign_pages():
    a = PageAllocator(8)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError):
        a.free(pages)
    with pytest.raises(ValueError):
        a.free([0])


@given(request_st)
@settings(max_examples=60, deadline=None)
def test_ledger_occupancy_is_pages_times_page_bytes(stream):
    """At every event the integrated trace equals the allocator's
    outstanding pages x page_bytes, and alloc/free deltas integrate to zero
    once every slot is retired."""
    # pool sized above the stream's worst case so growth never throws
    led = PagedKVLedger(128, PAGE_BYTES)
    t = 0.0
    live = {}
    for slot, n_prompt, grows, gap in stream:
        t += gap
        if slot in live:
            led.retire(slot, t)
            del live[slot]
            continue
        pages = led.admit(slot, n_prompt, t)
        assert len(pages) == n_prompt
        live[slot] = n_prompt
        for g in grows:
            t += 0.1
            led.grow(slot, live[slot] + g, t)
            live[slot] += g
        assert led.occupancy_bytes() == \
            led.allocator.n_allocated * PAGE_BYTES
        tarr, n, _ = led.trace.as_arrays()
        if len(n):
            assert int(n[-1]) == led.occupancy_bytes()
            assert (n % PAGE_BYTES == 0).all()
            assert int(n.max()) <= led.trace.capacity
    for slot in list(live):
        t += 0.1
        led.retire(slot, t)
    assert led.allocator.n_allocated == 0
    if led.trace.n_events:
        assert sum(led.trace.ev_dneeded) == 0          # integrates to zero
        _, n, _ = led.trace.as_arrays()
        assert int(n[-1]) == 0


@given(st.integers(1, 200), st.integers(1, 32))
@settings(max_examples=60, deadline=None)
def test_pages_for_covers_tokens_tightly(tokens, ps):
    n = pages_for(tokens, ps)
    assert n * ps >= tokens
    assert (n - 1) * ps < tokens


# ---------------------------------------------------------------------------
# Speculative rollback: truncate_rows + the draft lane
# ---------------------------------------------------------------------------

PAGE_SIZE = 16

# per-slot speculative lifetimes: (slot, prompt_rows, list of (grow_rows,
# keep_rows) burst/rollback rounds)
spec_stream_st = st.lists(
    st.tuples(st.integers(0, 3),                     # slot id
              st.integers(1, 40),                    # prompt rows
              st.lists(st.tuples(st.integers(0, 30), st.integers(0, 30)),
                       max_size=5)),                 # (grow, rollback) rounds
    min_size=1, max_size=20)


@given(spec_stream_st, st.booleans())
@settings(max_examples=60, deadline=None)
def test_truncate_rows_conserves_pages_and_drains(stream, with_draft):
    """Speculative burst/rollback through the ledger: grow both lanes to a
    verify window's worst case, truncate back to the accepted rows. At every
    step pages are conserved, no page is leaked or double-freed (the base
    allocator raises on either), truncation never cuts below the accepted
    rows' pages, both lanes stay in lockstep, and a full retire drains the
    allocator to zero with the trace integrating to zero."""
    led = PagedKVLedger(256, PAGE_BYTES, PAGE_SIZE)
    if with_draft:
        led.enable_draft_lane(PAGE_BYTES // 4)
    t = 0.0
    rows = {}
    for slot, n_rows, rounds in stream:
        t += 0.1
        if slot in rows:
            before = led.allocator.n_allocated
            held = len(led.slot_pages[slot]) + \
                len(led.draft_pages.get(slot, []))
            freed = led.retire(slot, t)
            assert freed == held
            assert led.allocator.n_allocated == before - held
            del rows[slot]
            continue
        npg = pages_for(n_rows, PAGE_SIZE)
        led.admit(slot, npg, t)
        if with_draft:
            dp = led.admit_draft(slot, npg, t)
            assert len(dp) == npg
        rows[slot] = n_rows
        for grow_rows, keep_rows in rounds:
            t += 0.1
            total = rows[slot] + grow_rows              # speculative burst
            led.grow(slot, pages_for(total, PAGE_SIZE), t)
            if with_draft:
                led.grow_draft(slot, pages_for(total, PAGE_SIZE), t)
            keep = max(rows[slot], min(total, rows[slot] + keep_rows))
            ft, fd = led.truncate_rows(slot, keep, t)   # rollback
            rows[slot] = keep
            kp = pages_for(keep, PAGE_SIZE)
            assert len(led.slot_pages[slot]) == kp
            assert len(ft) == pages_for(total, PAGE_SIZE) - kp
            if with_draft:
                assert len(led.draft_pages[slot]) == kp    # lanes lockstep
                assert len(fd) == len(ft)
            else:
                assert fd == []
        assert led.allocator.n_free + led.allocator.n_allocated == 256 - 1
    for slot in list(rows):
        t += 0.1
        led.retire(slot, t)
    assert led.allocator.n_allocated == 0
    if led.trace.n_events:
        assert sum(led.trace.ev_dneeded) == 0
        _, n, _ = led.trace.as_arrays()
        assert int(n[-1]) == 0


@given(st.integers(1, 60), st.integers(0, 40), st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_shared_ledger_truncate_never_frees_shared_pages(prompt_rows,
                                                         spec_rows, ps_pow):
    """SharedKVLedger rollback safety: a slot whose prefix pages are shared
    (with the radix index and a sibling slot) can truncate its speculative
    tail without ever reclaiming a shared page — shared pages only lose the
    truncating slot's reference (COW semantics preserved); only the private
    speculative tail returns to the free list."""
    from repro.serve.prefix import SharedKVLedger
    ps = 2 ** ps_pow
    led = SharedKVLedger(256, PAGE_BYTES, ps)
    npg = pages_for(prompt_rows, ps)
    shared = led.allocator.alloc(npg)       # stand-in for an indexed run
    led.admit(0, 0, 0.0, shared=shared)
    led.admit(1, 0, 0.1, shared=shared)     # sibling mapping the same run
    total = prompt_rows + spec_rows
    led.grow(0, pages_for(total, ps), 0.2)  # slot 0's speculative burst
    before_free = led.allocator.n_free
    ft, fd = led.truncate_rows(0, prompt_rows, 0.3)
    assert fd == []
    # every freed page is private (was refcount 1); shared pages survive
    assert not (set(ft) & set(shared))
    assert led.allocator.n_free == before_free + len(ft)
    for p in shared:
        assert led.allocator.refcount(p) >= 2   # slot 1 + original ref
    # truncating INTO the shared prefix drops refs but frees nothing
    led.grow(0, pages_for(total, ps), 0.4)
    led.truncate_rows(0, 0, 0.5)
    for p in shared:
        assert led.allocator.refcount(p) >= 1
    led.retire(0, 0.6)
    led.retire(1, 0.7)
    led.allocator.release(shared)
    assert led.allocator.n_allocated == 0
