"""Hypothesis property tests on the page allocator + page ledger (the
host-side half of the paged serving path), mirroring test_trace_props.py."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.paged import (OutOfPages, PageAllocator,  # noqa: E402
                               PagedKVLedger, pages_for)

PAGE_BYTES = 4096

# interleaved allocator ops: (alloc n) as positive ints, (free batch i) as
# negative picks of an outstanding allocation
alloc_ops_st = st.lists(st.integers(-5, 6), min_size=1, max_size=80)

# request lifetimes driven through the ledger: (slot, prompt_pages, grow
# steps, inter-event gap)
request_st = st.lists(
    st.tuples(st.integers(0, 3),                 # slot id
              st.integers(0, 4),                 # prompt pages
              st.lists(st.integers(0, 3), max_size=4),   # page growth deltas
              st.floats(0.0, 2.0)),              # time gap
    min_size=1, max_size=30)


@given(st.integers(2, 64), alloc_ops_st)
@settings(max_examples=80, deadline=None)
def test_allocator_invariants(num_pages, ops):
    """No double allocation, null page never handed out, frees restore the
    free count, conservation of pages throughout."""
    a = PageAllocator(num_pages)
    outstanding = []
    seen_live = set()
    for op in ops:
        if op > 0:
            try:
                pages = a.alloc(op)
            except OutOfPages:
                assert op > a.n_free
                continue
            assert len(pages) == op
            assert 0 not in pages                       # null page reserved
            assert not (set(pages) & seen_live)         # no double allocation
            seen_live.update(pages)
            outstanding.append(pages)
        elif op < 0 and outstanding:
            pages = outstanding.pop(abs(op) % len(outstanding))
            before = a.n_free
            a.free(pages)
            assert a.n_free == before + len(pages)
            seen_live.difference_update(pages)
        assert a.n_free + a.n_allocated == num_pages - 1
        assert a.n_allocated == len(seen_live)
    # full drain returns the pool to pristine
    for pages in outstanding:
        a.free(pages)
    assert a.n_allocated == 0 and a.n_free == num_pages - 1


def test_allocator_rejects_double_free_and_foreign_pages():
    a = PageAllocator(8)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError):
        a.free(pages)
    with pytest.raises(ValueError):
        a.free([0])


@given(request_st)
@settings(max_examples=60, deadline=None)
def test_ledger_occupancy_is_pages_times_page_bytes(stream):
    """At every event the integrated trace equals the allocator's
    outstanding pages x page_bytes, and alloc/free deltas integrate to zero
    once every slot is retired."""
    # pool sized above the stream's worst case so growth never throws
    led = PagedKVLedger(128, PAGE_BYTES)
    t = 0.0
    live = {}
    for slot, n_prompt, grows, gap in stream:
        t += gap
        if slot in live:
            led.retire(slot, t)
            del live[slot]
            continue
        pages = led.admit(slot, n_prompt, t)
        assert len(pages) == n_prompt
        live[slot] = n_prompt
        for g in grows:
            t += 0.1
            led.grow(slot, live[slot] + g, t)
            live[slot] += g
        assert led.occupancy_bytes() == \
            led.allocator.n_allocated * PAGE_BYTES
        tarr, n, _ = led.trace.as_arrays()
        if len(n):
            assert int(n[-1]) == led.occupancy_bytes()
            assert (n % PAGE_BYTES == 0).all()
            assert int(n.max()) <= led.trace.capacity
    for slot in list(live):
        t += 0.1
        led.retire(slot, t)
    assert led.allocator.n_allocated == 0
    if led.trace.n_events:
        assert sum(led.trace.ev_dneeded) == 0          # integrates to zero
        _, n, _ = led.trace.as_arrays()
        assert int(n[-1]) == 0


@given(st.integers(1, 200), st.integers(1, 32))
@settings(max_examples=60, deadline=None)
def test_pages_for_covers_tokens_tightly(tokens, ps):
    n = pages_for(tokens, ps)
    assert n * ps >= tokens
    assert (n - 1) * ps < tokens
