"""Hypothesis property tests on the telemetry histogram and merge laws."""
import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs.telemetry import (DEFAULT_BUCKETS, Histogram, Telemetry,
                                 log_bucket_edges)

samples_st = st.lists(st.floats(1e-7, 1e5, allow_nan=False,
                                allow_infinity=False),
                      min_size=1, max_size=200)


def _hist(xs, name="h"):
    h = Histogram(name, edges=DEFAULT_BUCKETS)
    for x in xs:
        h.observe(x)
    return h


@given(samples_st, samples_st)
@settings(max_examples=80, deadline=None)
def test_merge_equals_concatenated_observation(xs, ys):
    """merge(H(xs), H(ys)) is indistinguishable from H(xs + ys): identical
    bucket counts, extrema, and therefore identical quantile estimates."""
    merged = _hist(xs)
    merged.merge(_hist(ys))
    concat = _hist(xs + ys)
    assert merged == concat
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        assert merged.quantile(q) == concat.quantile(q)


@given(samples_st, st.sampled_from([0.0, 0.1, 0.5, 0.9, 0.99, 1.0]))
@settings(max_examples=120, deadline=None)
def test_quantile_estimate_bounded_by_true_order_stat_buckets(xs, q):
    """The estimate for quantile q lies inside the union of the buckets
    that truly contain the two bounding order statistics (numpy rank
    convention k = q*(n-1)), clamped to the observed extrema — the
    resolution guarantee fixed bucket edges can actually deliver."""
    h = _hist(xs)
    s = sorted(xs)
    k = q * (len(s) - 1)
    x_lo, x_hi = s[int(math.floor(k))], s[int(math.ceil(k))]
    lo = max(h.bucket_bounds(x_lo)[0], h.min_value)
    hi = min(h.bucket_bounds(x_hi)[1], h.max_value)
    est = h.quantile(q)
    assert lo - 1e-12 <= est <= hi + 1e-12
    # and never escapes the observed range
    assert h.min_value - 1e-12 <= est <= h.max_value + 1e-12


@given(samples_st)
@settings(max_examples=60, deadline=None)
def test_observe_array_matches_scalar_observes(xs):
    bulk = Histogram("b", edges=DEFAULT_BUCKETS)
    bulk.observe_array(np.asarray(xs))
    assert bulk == _hist(xs)


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=5),
       st.lists(st.integers(0, 1000), min_size=1, max_size=5),
       st.lists(st.integers(0, 1000), min_size=1, max_size=5))
@settings(max_examples=40, deadline=None)
def test_registry_merge_is_associative_on_counters(a, b, c):
    def reg(vals):
        t = Telemetry(enabled=True)
        for i, v in enumerate(vals):
            t.counter(f"c{i}").inc(v)
        return t

    left = reg(a).merge(reg(b).merge(reg(c)))
    right = reg(a).merge(reg(b)).merge(reg(c))
    assert left.snapshot()["counters"] == right.snapshot()["counters"]


def test_bucket_edges_monotone():
    for edges in (DEFAULT_BUCKETS, log_bucket_edges(1e-5, 1e3, per_decade=8)):
        assert all(a < b for a, b in zip(edges, edges[1:]))
