"""Shared construction of the frozen Stage-I golden fixtures.

Used by `tests/test_golden_traces.py` (comparison) and
`scripts/regen_golden.py` (regeneration) so the two can never drift. Cases
are deliberately tiny — reduced 2-layer paper configs on an 8 MiB SRAM —
so regeneration takes seconds and the JSON stays reviewable, while still
exercising prefill and decode graphs of both an MHA (gpt2-xl) and a GQA
(dsr1d-qwen-1.5b) workload."""
import json
import os

import numpy as np

from repro.configs import get_arch, reduced
from repro.core.workload import build_decode_graph, build_graph
from repro.sim.accelerator import baseline_accelerator
from repro.sim.engine import simulate

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "stage1_golden.json")

CASES = {
    "gpt2-xl-mini-prefill": dict(
        arch="gpt2-xl", phase="prefill", M=128, subops=2, sram_mib=8),
    "dsr1d-qwen-1.5b-mini-prefill": dict(
        arch="dsr1d-qwen-1.5b", phase="prefill", M=128, subops=2, sram_mib=8),
    "gpt2-xl-mini-decode": dict(
        arch="gpt2-xl", phase="decode", ctx=96, batch=4, subops=2,
        sram_mib=8),
    "dsr1d-qwen-1.5b-mini-decode": dict(
        arch="dsr1d-qwen-1.5b", phase="decode", ctx=96, batch=4, subops=2,
        sram_mib=8),
}


def run_case(name: str, **engine_kw):
    spec = CASES[name]
    cfg = reduced(get_arch(spec["arch"]), layers=2)
    if spec["phase"] == "prefill":
        g = build_graph(cfg, M=spec["M"], subops=spec["subops"])
    else:
        g = build_decode_graph(cfg, context_len=spec["ctx"],
                               batch=spec["batch"], subops=spec["subops"])
    accel = baseline_accelerator(spec["sram_mib"])
    return simulate(g, accel, **engine_kw), accel


def case_payload(name: str, **engine_kw) -> dict:
    sim, _ = run_case(name, **engine_kw)
    mems = {}
    for m, tr in sim.traces.items():
        if tr.n_events == 0:
            continue
        dur, needed, obsolete, _ = tr.segments(sim.total_time)
        mems[m] = {
            "n_events": tr.n_events,
            "peak_needed": int(tr.peak_needed()),
            "peak_total": int(tr.peak_total()),
            "durations": [float(d) for d in dur],
            "needed": [int(v) for v in needed],
            "obsolete": [int(v) for v in obsolete],
        }
    return {
        "total_time": float(sim.total_time),
        "writebacks": int(sim.writebacks),
        "total_macs": int(sim.total_macs),
        "total_vector_ops": int(sim.total_vector_ops),
        "dram_traffic_bytes": int(sim.dram_traffic_bytes),
        "access_reads": {k: int(v)
                         for k, v in sorted(sim.access.reads_bytes.items())},
        "access_writes": {k: int(v)
                          for k, v in sorted(sim.access.writes_bytes.items())},
        "mems": mems,
    }


def build_golden() -> dict:
    return {name: case_payload(name) for name in sorted(CASES)}


# ---------------------------------------------------------------------------
# Shared-prefix serving golden (host-level, model-free and fully
# deterministic: seeded workload -> radix index / COW ledger -> dual traces)
# ---------------------------------------------------------------------------

PREFIX_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                                  "prefix_golden.json")

PREFIX_CASES = {
    "dsr1d-chat-sysprompt": dict(
        arch="dsr1d-qwen-1.5b", workload="chat_sysprompt", rate=4.0,
        horizon_s=8.0, seed=0, prefix_len=256, sharing=6, num_slots=4,
        page_size=16, max_len=1024),
    "gpt2-agentic-fanout": dict(
        arch="gpt2-xl", workload="agentic_fanout", rate=4.0,
        horizon_s=8.0, seed=1, prefix_len=256, sharing=4, num_slots=4,
        page_size=16, max_len=1024),
}


def prefix_case_payload(name: str, kv_dtype_bytes: int = 2) -> dict:
    from repro.traffic.generators import LengthModel, generate_workload
    from repro.traffic.occupancy import simulate_prefix_traffic

    spec = PREFIX_CASES[name]
    cfg = get_arch(spec["arch"])
    lengths = LengthModel(max_len=spec["max_len"])
    reqs = generate_workload(spec["workload"], spec["rate"],
                             spec["horizon_s"], seed=spec["seed"],
                             lengths=lengths, prefix_len=spec["prefix_len"],
                             sharing=spec["sharing"],
                             fanout=spec["sharing"])
    sim = simulate_prefix_traffic(cfg, reqs, num_slots=spec["num_slots"],
                                  page_size=spec["page_size"],
                                  max_len=spec["max_len"],
                                  kv_dtype_bytes=kv_dtype_bytes,
                                  seed=spec["seed"])
    st = sim.stats
    mems = {}
    for m, tr in sim.bundle.traces.items():
        dur, needed, obsolete, _ = tr.segments(sim.total_time)
        _, n_int, o_int = tr.as_arrays()
        mems[m] = {
            "n_events": tr.n_events,
            "peak_needed": int(tr.peak_needed()),
            "peak_total": int(tr.peak_total()),
            # integrated state after the last event (the drain check: the
            # final retire lands at total_time, so segments() filters its
            # zero-duration row)
            "final_needed": int(n_int[-1]) if len(n_int) else 0,
            "final_obsolete": int(o_int[-1]) if len(o_int) else 0,
            "durations": [float(d) for d in dur],
            "needed": [int(v) for v in needed],
            "obsolete": [int(v) for v in obsolete],
        }
    return {
        "total_time": float(sim.total_time),
        "n_requests": len(reqs),
        "stats": {
            "admitted": st.admitted, "finished": st.finished,
            "decode_steps": st.decode_steps,
            "prefix_hits": st.prefix_hits,
            "prefix_tokens_reused": st.prefix_tokens_reused,
            "cow_splits": st.cow_splits,
            "evicted_pages": st.evicted_pages,
        },
        "access_reads": {k: int(v)
                         for k, v in sorted(sim.bundle.access
                                            .reads_bytes.items())},
        "access_writes": {k: int(v)
                          for k, v in sorted(sim.bundle.access
                                             .writes_bytes.items())},
        "mems": mems,
    }


def build_prefix_golden() -> dict:
    return {name: prefix_case_payload(name) for name in sorted(PREFIX_CASES)}


# ---------------------------------------------------------------------------
# Quantized-ledger golden: the SAME prefix scenarios re-priced at 1
# payload byte/element (int8 / fp8-E4M3 pools). The request streams, page
# counts and event times are dtype-independent — only the byte scale of
# the occupancy changes — so these fixtures lock the kv_dtype_bytes
# plumbing through ledger, traces and access stats, and every `needed`
# level must be exactly half its bf16 counterpart.
# ---------------------------------------------------------------------------

QUANT_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                                 "quant_golden.json")

# name -> (PREFIX_CASES base scenario, payload bytes/element). Scale
# overhead of int8 pools is deliberately excluded by the model-free
# simulators (see traffic.campaign.Scenario.kv_dtype_bytes), so int8 and
# fp8 share the 1-byte geometry.
QUANT_CASES = {
    "dsr1d-chat-sysprompt-int8": ("dsr1d-chat-sysprompt", 1),
    "gpt2-agentic-fanout-fp8": ("gpt2-agentic-fanout", 1),
}


def quant_case_payload(name: str) -> dict:
    base, nbytes = QUANT_CASES[name]
    payload = prefix_case_payload(base, kv_dtype_bytes=nbytes)
    payload["base_case"] = base
    payload["kv_dtype_bytes"] = nbytes
    return payload


def build_quant_golden() -> dict:
    return {name: quant_case_payload(name) for name in sorted(QUANT_CASES)}


# ---------------------------------------------------------------------------
# Speculative-decoding golden: the burst/rollback occupancy of the
# model-free spec simulator is regression-locked (seeded acceptance draws ->
# per-round verify-window bursts -> truncate_rows rollbacks, both KV lanes)
# ---------------------------------------------------------------------------

SPEC_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                                "spec_golden.json")

SPEC_CASES = {
    "dsr1d-spec-k4": dict(
        arch="dsr1d-qwen-1.5b", arrival="poisson", rate=4.0, horizon_s=8.0,
        seed=0, spec_k=4, acceptance=0.7, draft_kv_frac=0.5, num_slots=4,
        page_size=16, max_len=1024),
    "gpt2-spec-k2-lowacc": dict(
        arch="gpt2-xl", arrival="poisson", rate=4.0, horizon_s=8.0,
        seed=1, spec_k=2, acceptance=0.3, draft_kv_frac=0.25, num_slots=4,
        page_size=16, max_len=1024),
}


def spec_case_payload(name: str, kv_dtype_bytes: int = 2) -> dict:
    from repro.traffic.generators import LengthModel, generate
    from repro.traffic.occupancy import simulate_spec_traffic

    spec = SPEC_CASES[name]
    cfg = get_arch(spec["arch"])
    lengths = LengthModel(max_len=spec["max_len"])
    reqs = generate(spec["arrival"], spec["rate"], spec["horizon_s"],
                    seed=spec["seed"], lengths=lengths)
    sim = simulate_spec_traffic(cfg, reqs, num_slots=spec["num_slots"],
                                page_size=spec["page_size"],
                                max_len=spec["max_len"],
                                spec_k=spec["spec_k"],
                                acceptance=spec["acceptance"],
                                draft_kv_frac=spec["draft_kv_frac"],
                                kv_dtype_bytes=kv_dtype_bytes,
                                seed=spec["seed"])
    st = sim.stats
    tr = sim.bundle.traces["kv"]
    dur, needed, obsolete, _ = tr.segments(sim.total_time)
    _, n_int, o_int = tr.as_arrays()
    ev = np.asarray(tr.ev_dneeded)
    return {
        "total_time": float(sim.total_time),
        "n_requests": len(reqs),
        "stats": {
            "admitted": st.admitted, "finished": st.finished,
            "decode_steps": st.decode_steps,
            "spec_rounds": st.spec_rounds,
            "drafted_tokens": st.drafted_tokens,
            "accepted_tokens": st.accepted_tokens,
            "rolled_back_pages": st.rolled_back_pages,
        },
        # rollback signature: frees strictly outnumber retires when
        # speculative tails are truncated mid-stream
        "n_neg_deltas": int((ev < 0).sum()),
        "access_reads": {k: int(v)
                         for k, v in sorted(sim.bundle.access
                                            .reads_bytes.items())},
        "access_writes": {k: int(v)
                          for k, v in sorted(sim.bundle.access
                                             .writes_bytes.items())},
        "mems": {
            "kv": {
                "n_events": tr.n_events,
                "peak_needed": int(tr.peak_needed()),
                "peak_total": int(tr.peak_total()),
                "final_needed": int(n_int[-1]) if len(n_int) else 0,
                "final_obsolete": int(o_int[-1]) if len(o_int) else 0,
                "durations": [float(d) for d in dur],
                "needed": [int(v) for v in needed],
                "obsolete": [int(v) for v in obsolete],
            },
        },
    }


def build_spec_golden() -> dict:
    return {name: spec_case_payload(name) for name in sorted(SPEC_CASES)}


# ---------------------------------------------------------------------------
# Energy-observability golden: the Perfetto bank-state export of a streamed
# `BankEnergyMeter` over a deterministic model-free sim. Locks the track
# schema (process/lane/counter names, span-event key set) and the exact f64
# energy totals; the loader test additionally proves the exported energy
# counter track carries the meter total losslessly.
# ---------------------------------------------------------------------------

ENERGY_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                                  "energy_golden.json")

# `off_multiple` widens the drowsy policy's gate-off threshold so the
# scenario's idle-run distribution actually splits between gated and
# drowsy intervals (at the default threshold every run gates).
ENERGY_CASES = {
    "dsr1d-chat-conservative": dict(
        base="dsr1d-chat-sysprompt", meter="32,8,0.9,conservative"),
    "dsr1d-chat-drowsy": dict(
        base="dsr1d-chat-sysprompt", meter="32,8,0.9,drowsy",
        off_multiple=1e5),
}


def _energy_case_run(name: str):
    """(meter, end_time) for one energy golden case — the base prefix
    scenario re-simulated with a streaming meter attached."""
    from repro.obs.energy import BankEnergyMeter
    from repro.traffic.generators import LengthModel, generate_workload
    from repro.traffic.occupancy import simulate_prefix_traffic

    case = ENERGY_CASES[name]
    spec = PREFIX_CASES[case["base"]]
    cfg = get_arch(spec["arch"])
    lengths = LengthModel(max_len=spec["max_len"])
    reqs = generate_workload(spec["workload"], spec["rate"],
                             spec["horizon_s"], seed=spec["seed"],
                             lengths=lengths, prefix_len=spec["prefix_len"],
                             sharing=spec["sharing"], fanout=spec["sharing"])
    if "off_multiple" in case:
        from repro.core.gating import Policy
        c_mib, banks, alpha, pname = case["meter"].split(",")
        assert pname == "drowsy"
        pol = Policy.drowsy(float(alpha),
                            off_multiple=float(case["off_multiple"]))
        meter = BankEnergyMeter(int(float(c_mib)) << 20, int(banks),
                                policy=pol)
    else:
        meter = BankEnergyMeter.from_spec(case["meter"])
    sim = simulate_prefix_traffic(cfg, reqs, num_slots=spec["num_slots"],
                                  page_size=spec["page_size"],
                                  max_len=spec["max_len"],
                                  seed=spec["seed"], meter=meter)
    return meter, float(sim.total_time)


def energy_case_payload(name: str) -> dict:
    from repro.obs.perfetto import (ACTIVE_COUNTER, BANKS_PID,
                                    ENERGY_COUNTER, bank_state_events,
                                    energy_counter_total)

    meter, end = _energy_case_run(name)
    evs = bank_state_events(meter, end_time=end)
    lanes = sorted(e["args"]["name"] for e in evs
                   if e["ph"] == "M" and e["name"] == "thread_name")
    spans = [e for e in evs if e["ph"] == "X"]
    counters = sorted({e["name"] for e in evs if e["ph"] == "C"})
    state_counts: dict = {}
    for e in spans:
        state_counts[e["name"]] = state_counts.get(e["name"], 0) + 1
    res = meter.finalize(end)
    return {
        "meter_spec": ENERGY_CASES[name]["meter"],
        "base_case": ENERGY_CASES[name]["base"],
        "total_time": end,
        "n_meter_events": meter.n_events,
        "track_schema": {
            "pid": BANKS_PID,
            "process": next(e["args"]["name"] for e in evs
                            if e["ph"] == "M"
                            and e["name"] == "process_name"),
            "lanes": lanes,
            "counters": counters,
            "span_keys": sorted(spans[0].keys()) if spans else [],
            "span_arg_keys": sorted(spans[0]["args"].keys()) if spans
            else [],
            "active_counter": ACTIVE_COUNTER,
            "energy_counter": ENERGY_COUNTER,
        },
        "n_span_events": len(spans),
        "state_counts": dict(sorted(state_counts.items())),
        # exact f64 (JSON round-trips doubles losslessly via repr)
        "e_leak_j": res.e_leak,
        "e_sw_j": res.e_sw,
        "n_transitions": res.n_transitions,
        "live_e_j": meter.energy_j(end),
        "energy_counter_total_j": energy_counter_total(evs),
        "wakes": dict(sorted(meter.wake_counts(end).items())),
        "stall_s": meter.stall_s(end),
    }


def build_energy_golden() -> dict:
    return {name: energy_case_payload(name) for name in sorted(ENERGY_CASES)}


def load_energy_golden() -> dict:
    with open(ENERGY_GOLDEN_PATH) as f:
        return json.load(f)


def load_spec_golden() -> dict:
    with open(SPEC_GOLDEN_PATH) as f:
        return json.load(f)


def load_quant_golden() -> dict:
    with open(QUANT_GOLDEN_PATH) as f:
        return json.load(f)


def load_prefix_golden() -> dict:
    with open(PREFIX_GOLDEN_PATH) as f:
        return json.load(f)


def load_golden() -> dict:
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def diff_payload(got: dict, want: dict, *, time_rtol: float = 0.0) -> list:
    """Differences between a live payload and the stored fixture.

    Integer occupancy, event counts and access statistics compare *exactly*;
    durations/total_time allow `time_rtol` (0 locks them bit-for-bit — the
    engine's time arithmetic is pure IEEE-754 and deterministic)."""
    errs = []

    def tclose(a, b):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        if a.shape != b.shape:
            return False
        if time_rtol == 0.0:
            return bool(np.array_equal(a, b))
        return bool(np.allclose(a, b, rtol=time_rtol, atol=1e-18))

    for key in ("writebacks", "total_macs", "total_vector_ops",
                "dram_traffic_bytes", "access_reads", "access_writes"):
        if got[key] != want[key]:
            errs.append(f"{key}: {got[key]!r} != {want[key]!r}")
    if not tclose(got["total_time"], want["total_time"]):
        errs.append(f"total_time: {got['total_time']!r} != "
                    f"{want['total_time']!r}")
    if sorted(got["mems"]) != sorted(want["mems"]):
        errs.append(f"memories: {sorted(got['mems'])} != "
                    f"{sorted(want['mems'])}")
        return errs
    for m, w in want["mems"].items():
        g = got["mems"][m]
        for key in ("n_events", "peak_needed", "peak_total",
                    "needed", "obsolete"):
            if g[key] != w[key]:
                detail = ""
                if isinstance(w[key], list) and len(g[key]) == len(w[key]):
                    bad = [i for i, (x, y) in enumerate(zip(g[key], w[key]))
                           if x != y][:5]
                    detail = f" (first diffs at segments {bad})"
                errs.append(f"{m}.{key} mismatch{detail}")
        if not tclose(g["durations"], w["durations"]):
            errs.append(f"{m}.durations mismatch")
    return errs
