"""Config registry: identity, analytic param counts, shape rules."""
import pytest

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCHS, SHAPES, get_arch,
                           list_archs, reduced, shape_supported)


def test_all_assigned_archs_registered():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS + PAPER_ARCHS:
        cfg = get_arch(a)
        assert cfg.name == a


@pytest.mark.parametrize("name,lo,hi", [
    ("qwen2-7b", 7.0e9, 8.2e9),
    ("tinyllama-1.1b", 1.0e9, 1.2e9),
    ("deepseek-coder-33b", 32e9, 35e9),
    ("granite-34b", 32e9, 36e9),
    ("olmoe-1b-7b", 6.5e9, 7.3e9),
    ("llama4-scout-17b-a16e", 100e9, 112e9),
    ("mamba2-130m", 0.11e9, 0.15e9),
    ("recurrentgemma-2b", 2.4e9, 3.0e9),
    ("internvl2-2b", 1.7e9, 2.1e9),
    ("seamless-m4t-large-v2", 1.4e9, 2.0e9),
])
def test_param_counts_in_published_range(name, lo, hi):
    assert lo <= get_arch(name).param_count() <= hi


def test_moe_active_params():
    o = get_arch("olmoe-1b-7b")
    assert 1.0e9 <= o.active_param_count() <= 1.5e9
    l4 = get_arch("llama4-scout-17b-a16e")
    assert 15e9 <= l4.active_param_count() <= 19e9


def test_padded_vocab_shards_evenly():
    for a in list_archs():
        assert get_arch(a).padded_vocab % 16 == 0


def test_shape_skip_rules():
    long = SHAPES["long_500k"]
    ok, _ = shape_supported(get_arch("mamba2-130m"), long)
    assert ok
    ok, _ = shape_supported(get_arch("recurrentgemma-2b"), long)
    assert ok
    ok, _ = shape_supported(get_arch("llama4-scout-17b-a16e"), long)
    assert ok
    for a in ("qwen2-7b", "deepseek-coder-33b", "olmoe-1b-7b",
              "seamless-m4t-large-v2", "internvl2-2b"):
        ok, reason = shape_supported(get_arch(a), long)
        assert not ok and "full-attention" in reason
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        for a in ASSIGNED_ARCHS:
            ok, _ = shape_supported(get_arch(a), SHAPES[s])
            assert ok


def test_reduced_preserves_family():
    for a in list_archs():
        cfg = get_arch(a)
        r = reduced(cfg)
        assert r.family == cfg.family
        assert r.block_pattern == cfg.block_pattern
        assert (r.moe is None) == (cfg.moe is None)
        assert (r.ssm is None) == (cfg.ssm is None)
        assert r.is_encdec == cfg.is_encdec
        assert r.param_count() < 5e6


def test_paper_table1_mac_consistency():
    """Table I: GPT-2 XL 1.48B/3.66T, DS-R1D 1.31B/3.04T (excl. embeddings)."""
    from repro.core.workload import build_graph
    g1 = build_graph(get_arch("gpt2-xl"), M=2048, subops=4)
    g2 = build_graph(get_arch("dsr1d-qwen-1.5b"), M=2048, subops=4)
    assert abs(g1.total_macs() / 3.66e12 - 1) < 0.01
    assert abs(g2.total_macs() / 3.04e12 - 1) < 0.01
    # weights (int8 bytes == param count, embeddings excluded like the paper)
    assert abs(g1.total_weight_bytes() / 1.48e9 - 1) < 0.02
    assert abs(g2.total_weight_bytes() / 1.31e9 - 1) < 0.03
