"""SLA-aware scheduling: chunked prefill, preemptive priority admission,
and the PSS-forecast pre-wake gating controller (plus the satellites:
long-prompt validation, clock ownership, dual-clock latency stamps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.obs.telemetry import Telemetry
from repro.serve import (AdmissionQueue, BatchedServer,
                         PagedContinuousBatcher, Request, ServeConfig)
from repro.serve.scheduler import ContinuousBatcher
from repro.sim.pss import AffineForecaster
from repro.traffic import ControllerConfig, LengthModel, generate, \
    simulate_online, simulate_traffic
from repro.traffic.controller import ForecastConfig, compare, \
    simulate_online_forecast


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _batcher(m, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 32)
    kw.setdefault("max_pages_per_slot", 8)
    kw.setdefault("chunk_steps", 4)
    kw.setdefault("attn_backend", "ref")
    return PagedContinuousBatcher(m, params, **kw)


# ---------------------------------------------------------------------------
# Tentpole 1: chunked prefill — bit-exact vs monolithic, TBT relief
# ---------------------------------------------------------------------------

def test_chunked_prefill_tokens_bit_identical_to_monolithic(small):
    """Slicing the prompt must not change a single emitted token. Greedy
    tokens are compared against the plain monolithic prefill; logits are
    compared bit-for-bit against the *fixed-width* monolithic reference (a
    prefix batcher with an empty index), which shares the chunked path's
    padded attention width — the plain prefill computes at its own width,
    so its logits can differ in the last ulp without any token moving."""
    cfg, m, params = small
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (40, 17, 33)]
    new = [6, 5, 7]

    mono = _batcher(m, params, num_slots=1)
    for i, (p, n) in enumerate(zip(prompts, new)):
        mono.submit(Request(rid=i, tokens=p, max_new_tokens=n))
    tok_refs = {r.rid: list(r.output) for r in mono.run()}

    fixed = {}
    for i, (p, n) in enumerate(zip(prompts, new)):
        b = _batcher(m, params, num_slots=1, prefix_cache=True,
                     collect_logits=True)
        b.submit(Request(rid=i, tokens=p, max_new_tokens=n))
        (r,) = b.run()
        assert b.stats.prefix_hits == 0
        fixed[i] = [np.asarray(x) for x in r.logits]

    cb = _batcher(m, params, num_slots=1, prefill_chunk_tokens=16,
                  collect_logits=True)
    for i, (p, n) in enumerate(zip(prompts, new)):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=n))
    done = cb.run()
    assert cb.stats.prefill_slices >= 3 + 3      # 40 -> 3 slices, 33 -> 3
    for r in done:
        assert list(r.output) == tok_refs[r.rid]
        np.testing.assert_array_equal(np.stack(r.logits),
                                      np.stack(fixed[r.rid]))


def test_chunked_prefill_interleaves_decode_between_slices(small):
    """While a long prompt admits slice-by-slice, already-active slots must
    keep streaming tokens (the whole point of chunking) — and still emit
    exactly the tokens an isolated run would."""
    cfg, m, params = small
    rng = np.random.default_rng(12)
    short = rng.integers(0, cfg.vocab_size, 9)
    long = rng.integers(0, cfg.vocab_size, 48)
    srv = BatchedServer(m, params, ServeConfig(max_len=64))
    ref_short = np.asarray(srv.generate(
        {"tokens": jnp.asarray(short[None, :], jnp.int32)},
        max_new_tokens=12)["tokens"][0])
    ref_long = np.asarray(srv.generate(
        {"tokens": jnp.asarray(long[None, :], jnp.int32)},
        max_new_tokens=5)["tokens"][0])

    cb = _batcher(m, params, num_slots=2, num_pages=32,
                  prefill_chunk_tokens=16)
    cb.submit(Request(rid=0, tokens=short, max_new_tokens=12))
    cb.submit(Request(rid=1, tokens=long, max_new_tokens=5))
    done = cb.run()
    assert len(done) == 2
    by = {r.rid: r for r in done}
    np.testing.assert_array_equal(np.asarray(by[0].output), ref_short)
    np.testing.assert_array_equal(np.asarray(by[1].output), ref_long)
    # the long admission ran >= 3 slices with decode chunks between them
    assert cb.stats.prefill_slices >= 3
    assert cb.stats.peak_active_slots == 2
    assert cb.ledger.allocator.n_allocated == 0


def test_chunked_prefill_validation(small):
    cfg, m, params = small
    with pytest.raises(ValueError, match="multiple of"):
        _batcher(m, params, prefill_chunk_tokens=12)    # not a page multiple
    with pytest.raises(ValueError, match="multiple of"):
        _batcher(m, params, prefill_chunk_tokens=0)


def test_chunked_suffix_prefill_on_prefix_hit_bit_identical(small):
    """prefill_chunk_tokens now composes with prefix_cache: on a hit only
    the un-matched *suffix* is prefilled, in page-aligned slices (the first
    slice re-aligns a mid-page match boundary). The emitted tokens must be
    bit-identical to the monolithic suffix prefill, the match must still be
    reused, and the suffix must actually have been sliced."""
    cfg, m, params = small
    rng = np.random.default_rng(17)
    shared = rng.integers(0, cfg.vocab_size, 21)        # mid-page boundary
    suffixes = [rng.integers(0, cfg.vocab_size, n) for n in (37, 41)]
    prompts = [np.concatenate([shared, s]) for s in suffixes]
    new = [6, 5]

    def run(**kw):
        b = _batcher(m, params, num_slots=1, num_pages=64,
                     max_pages_per_slot=12, prefix_cache=True, **kw)
        for i, (p, n) in enumerate(zip(prompts, new)):
            b.submit(Request(rid=i, tokens=p, max_new_tokens=n))
        return {r.rid: list(r.output) for r in b.run()}, b

    ref, mono = run()
    assert mono.stats.prefix_hits == 1          # request 1 reuses `shared`
    got, chunked = run(prefill_chunk_tokens=16)
    assert got == ref
    assert chunked.stats.prefix_hits == 1
    assert chunked.stats.prefix_tokens_reused == \
        mono.stats.prefix_tokens_reused
    # both the miss (58 tokens) and the hit's suffix (>=41 tokens past the
    # 16-token match boundary realignment) ran in multiple slices
    assert chunked.stats.prefill_slices >= 4 + 3
    assert chunked.ledger.allocator.n_allocated == \
        mono.ledger.allocator.n_allocated


# ---------------------------------------------------------------------------
# Tentpole 2: priority admission + preemption-and-requeue
# ---------------------------------------------------------------------------

def test_priority_queue_orders_classes_fifo_within():
    q = AdmissionQueue()
    reqs = [Request(rid=i, tokens=np.arange(4), priority=p)
            for i, p in enumerate([0, 2, 1, 2, 0])]
    for r in reqs:
        q.push(r)
    assert [q.pop().rid for _ in range(len(reqs))] == [1, 3, 2, 0, 4]
    assert len(q) == 0


def test_preemption_frees_slot_for_high_priority(small):
    """A high-priority arrival with every slot busy evicts the lowest-
    priority slot; the victim requeues, re-prefills from scratch, and its
    final tokens are bit-identical to an uncontended run."""
    cfg, m, params = small
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (10, 14, 12)]
    refs = []
    srv = BatchedServer(m, params, ServeConfig(max_len=64))
    for p in prompts:
        refs.append(np.asarray(srv.generate(
            {"tokens": jnp.asarray(p[None, :], jnp.int32)},
            max_new_tokens=20)["tokens"][0]))

    cb = _batcher(m, params, num_slots=1, num_pages=32, chunk_steps=2)
    cb.submit(Request(rid=0, tokens=prompts[0], max_new_tokens=20,
                      priority=0))
    # admit rid=0, decode one chunk, then a priority-1 arrival preempts it
    cb._admit([])
    done = []
    cb._decode_chunk(done)
    assert cb.slots[0] is not None and cb.slots[0].rid == 0
    cb.submit(Request(rid=1, tokens=prompts[1], max_new_tokens=20,
                      priority=1))
    cb.submit(Request(rid=2, tokens=prompts[2], max_new_tokens=20,
                      priority=0))
    done += cb.run()
    assert len(done) == 3
    by = {r.rid: r for r in done}
    # the victim restarted: preemption counted, tokens still exact
    assert by[0].preemptions >= 1
    assert cb.stats.preemptions >= 1
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(by[i].output), refs[i])
    # high priority finished before the preempted low-priority request
    assert by[1].finished_s < by[0].finished_s
    assert cb.ledger.allocator.n_allocated == 0


def test_equal_priority_never_preempts(small):
    cfg, m, params = small
    rng = np.random.default_rng(14)
    cb = _batcher(m, params, num_slots=1, chunk_steps=2)
    for i in range(3):
        cb.submit(Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, 8),
                          max_new_tokens=6, priority=5))
    done = cb.run()
    assert len(done) == 3
    assert cb.stats.preemptions == 0
    assert all(r.preemptions == 0 for r in done)
    # FCFS within the class: retirement order == submission order
    assert [r.rid for r in done] == [0, 1, 2]


def test_preemption_on_page_pressure_not_just_slots(small):
    """Backpressure path: slots are free but the pool is not — a high-
    priority head may still evict a lower-priority page holder."""
    cfg, m, params = small
    cb = _batcher(m, params, num_slots=2, num_pages=7, max_pages_per_slot=6,
                  page_size=8, chunk_steps=2)
    # 33-token prompt + 8 new -> worst 5 pages; two never fit (6 free pages)
    cb.submit(Request(rid=0, tokens=np.arange(33) % cfg.vocab_size,
                      max_new_tokens=8, priority=0))
    cb._admit([])
    assert cb.slots[0] is not None
    cb.submit(Request(rid=1, tokens=(np.arange(33) * 5) % cfg.vocab_size,
                      max_new_tokens=8, priority=3))
    done = cb.run()
    assert len(done) == 2
    assert cb.stats.preemptions >= 1
    by = {r.rid: r for r in done}
    assert by[1].finished_s < by[0].finished_s
    assert cb.ledger.allocator.n_allocated == 0


# ---------------------------------------------------------------------------
# S1: long-prompt validation at submit() on both batchers
# ---------------------------------------------------------------------------

def test_long_prompt_rejected_dense(small):
    cfg, m, params = small
    cb = ContinuousBatcher(m, params, num_slots=1, max_len=32)
    with pytest.raises(ValueError, match="max_len"):
        cb.submit(Request(rid=0, tokens=np.arange(40) % cfg.vocab_size,
                          max_new_tokens=4))
    # nothing half-submitted: the queue stayed empty and a valid request
    # still runs through cleanly
    assert len(cb.queue) == 0
    cb.submit(Request(rid=1, tokens=np.arange(8) % cfg.vocab_size,
                      max_new_tokens=4))
    assert len(cb.run()) == 1


def test_long_prompt_truncated_dense(small):
    """Truncation must be consistent between compute and trace: the trace
    never exceeds the declared capacity and admitted == retired bytes."""
    cfg, m, params = small
    cb = ContinuousBatcher(m, params, num_slots=1, max_len=32,
                           on_long_prompt="truncate")
    cb.submit(Request(rid=0, tokens=np.arange(50) % cfg.vocab_size,
                      max_new_tokens=4))
    done = cb.run()
    assert len(done) == 1 and len(done[0].output) == 4
    assert len(done[0].tokens) == 32
    assert cb.trace.peak_needed() <= cb.trace.capacity
    assert cb.stats.admitted_kv_bytes == cb.stats.retired_kv_bytes


def test_long_prompt_rejected_paged(small):
    cfg, m, params = small
    from repro.serve import OutOfPages
    cb = _batcher(m, params)          # 8 pages x 8 tokens = 64-token slots
    with pytest.raises(OutOfPages):
        cb.submit(Request(rid=0, tokens=np.arange(70) % cfg.vocab_size,
                          max_new_tokens=4))
    assert len(cb.queue) == 0


def test_long_prompt_truncated_paged(small):
    cfg, m, params = small
    cb = _batcher(m, params, on_long_prompt="truncate")
    cb.submit(Request(rid=0, tokens=np.arange(70) % cfg.vocab_size,
                      max_new_tokens=5))
    done = cb.run()
    assert len(done) == 1 and len(done[0].output) == 5
    # decode budget kept; prompt cut to what the slot table can hold
    assert len(done[0].tokens) == 8 * 8 - 4
    assert cb.ledger.allocator.n_allocated == 0
    assert cb.ledger.trace.peak_needed() <= cb.ledger.trace.capacity


# ---------------------------------------------------------------------------
# S2: telemetry clock ownership — two engines, one registry
# ---------------------------------------------------------------------------

def test_second_batcher_on_same_registry_raises(small):
    cfg, m, params = small
    tel = Telemetry(enabled=True)
    cb1 = _batcher(m, params, telemetry=tel)
    with pytest.raises(RuntimeError, match="clock"):
        _batcher(m, params, telemetry=tel)
    with pytest.raises(RuntimeError, match="clock"):
        BatchedServer(m, params, ServeConfig(max_len=32), telemetry=tel)
    # releasing the clock makes the registry reusable
    tel.release_clock()
    cb2 = _batcher(m, params, telemetry=tel)
    assert cb2 is not None
    del cb1


def test_dense_and_engine_also_claim_clock(small):
    cfg, m, params = small
    tel = Telemetry(enabled=True)
    ContinuousBatcher(m, params, num_slots=1, max_len=32, telemetry=tel)
    with pytest.raises(RuntimeError, match="clock"):
        ContinuousBatcher(m, params, num_slots=1, max_len=32, telemetry=tel)


# ---------------------------------------------------------------------------
# S3: dual-clock request stamps
# ---------------------------------------------------------------------------

def test_latency_on_sim_clock_matches_slo_time_base(small):
    cfg, m, params = small
    tel = Telemetry(enabled=True)
    cb = _batcher(m, params, telemetry=tel)
    cb.submit(Request(rid=0, tokens=np.arange(9) % cfg.vocab_size,
                      max_new_tokens=6))
    done = cb.run()
    r = done[0]
    # sim-clock latency: bounded by the batcher's logical end time and
    # consistent with the request's own timeline stamps
    assert 0 < r.latency_s <= cb._sim_t
    assert r.latency_s == pytest.approx(r.finished_s - r.submitted_s)
    assert r.timeline is not None
    assert r.finished_s == pytest.approx(r.timeline.finish_t)
    # e2e percentile of the single request == its sim latency
    s = cb.slo_summary()
    assert s.e2e_p99_s == pytest.approx(r.latency_s)
    # wall stamps exist and are on a different (host) time base
    assert r.finished_wall_s > r.submitted_wall_s > 0
    assert r.wall_latency_s > 0


# ---------------------------------------------------------------------------
# Tentpole 3: forecast-driven pre-wake gating
# ---------------------------------------------------------------------------

def test_affine_forecaster_exact_and_causal():
    t = np.linspace(0.0, 10.0, 101)
    y = 3.0 + 2.0 * t
    fc = AffineForecaster(t, y, window_s=1.0)
    v, b = fc.fit(5.0)
    assert v == pytest.approx(13.0)
    assert b == pytest.approx(2.0)
    assert fc.forecast(5.0, 0.5) == pytest.approx(14.0)
    # strictly causal: a step at t=5 is invisible to queries at t<5
    y2 = np.where(t < 5.0, 1.0, 100.0)
    fc2 = AffineForecaster(t, y2, window_s=1.0)
    assert fc2.fit(4.9)[0] == pytest.approx(1.0)
    assert fc2.slope(4.9) == pytest.approx(0.0)
    # conditioning: re-centering keeps the fit usable at large absolute
    # times (without it the normal equations lose every significant digit)
    fc3 = AffineForecaster(t + 1e6, y, window_s=1.0)
    assert fc3.slope(1e6 + 5.0) == pytest.approx(2.0, rel=1e-2)
    with pytest.raises(ValueError):
        AffineForecaster(t[::-1], y, 1.0)
    with pytest.raises(ValueError):
        AffineForecaster(t, y, 0.0)


@pytest.fixture(scope="module")
def diurnal_trace():
    cfg = get_arch("tinyllama-1.1b")
    reqs = generate("diurnal", 6.0, 30.0, seed=0,
                    lengths=LengthModel(max_len=2048))
    sim = simulate_traffic(cfg, reqs, num_slots=8, max_len=2048)
    dur, occ = sim.trace.occupancy_series(sim.total_time, use="needed")
    return sim, dur, occ


def test_forecast_reduces_violations_within_energy_bound(diurnal_trace):
    """The acceptance criterion: on diurnal traffic the forecast controller
    must cut wake violations vs the reactive policy while staying within
    +2% energy of the offline oracle."""
    sim, dur, occ = diurnal_trace
    cap = 32 * 2**20
    kw = dict(capacity=cap, banks=8,
              n_reads=sim.bundle.access.n_reads("kv"),
              n_writes=sim.bundle.access.n_writes("kv"))
    c = compare(dur, occ, cfg=ControllerConfig(), fcfg=ForecastConfig(),
                backend="ref", **kw)
    assert c.forecast is not None
    assert c.forecast.wake_violations < c.online.wake_violations
    assert c.forecast.pre_wakes > 0
    assert c.forecast.early_wake_s > 0
    assert c.forecast_vs_oracle_pct <= 2.0
    # stall accounting mirrors the reactive controller's
    assert c.forecast.stall_s == pytest.approx(
        c.forecast.wake_violations * ControllerConfig().wake_latency_s)


def test_forecast_with_zero_lead_gates_like_reactive(diurnal_trace):
    """lead=0 never crosses a threshold early, so gated/leak seconds match
    the reactive policy bank-for-bank."""
    sim, dur, occ = diurnal_trace
    kw = dict(capacity=32 * 2**20, banks=8)
    re = simulate_online(dur, occ, **kw)
    fc = simulate_online_forecast(dur, occ, fcfg=ForecastConfig(lead_s=0.0),
                                  **kw)
    assert fc.gating.gated_bank_seconds == pytest.approx(
        re.gating.gated_bank_seconds)
    assert fc.wake_violations == re.wake_violations
    assert fc.early_wake_s == pytest.approx(0.0)


def test_forecast_flat_trace_never_pre_wakes():
    """No rising trend -> no speculative wakes; identical to reactive."""
    d = np.array([1.0, 1.0] * 8)
    occ = np.array([100 * 2**20, 1 * 2**20] * 8, np.int64)
    kw = dict(capacity=128 * 2**20, banks=8)
    re = simulate_online(d, occ, **kw)
    fc = simulate_online_forecast(d, occ, **kw)
    # square-wave idle runs have flat-or-falling interiors: zero early leak
    assert fc.pre_wakes == 0
    assert fc.e_total == pytest.approx(re.e_total)
    assert fc.wake_violations == re.wake_violations
