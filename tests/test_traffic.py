"""Serving-traffic subsystem: generators, occupancy model, online controller,
campaign grid, batcher trace emission, and the satellite fixes."""
import numpy as np
import pytest

from repro.configs import get_arch, reduced, resolve_arch
from repro.core.explorer import MIB, sweep
from repro.core.gating import Policy, evaluate
from repro.serve.scheduler import kv_bytes_at, kv_slot_budget, slot_state_bytes
from repro.sim.trace import OccupancyTrace, TraceBundle, merge_traces
from repro.traffic import (ControllerConfig, LengthModel, compare, generate,
                           simulate_online, simulate_traffic)
from repro.traffic.campaign import Scenario, fast_candidate_energies, \
    run_scenario
from repro.traffic.generators import bursty, diurnal, poisson, replay


# --------------------------------------------------------------- generators

@pytest.mark.parametrize("gen", [poisson, bursty, diurnal])
def test_generators_seeded_determinism(gen):
    a = gen(3.0, 12.0, seed=7)
    b = gen(3.0, 12.0, seed=7)
    c = gen(3.0, 12.0, seed=8)
    assert a == b
    assert a != c
    assert all(0.0 <= r.arrival_s < 12.0 for r in a)
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr)
    assert all(r.prompt_len >= 1 and r.output_len >= 1 for r in a)


def test_generator_mean_rate_roughly_matches():
    for gen in (poisson, bursty, diurnal):
        n = len(gen(5.0, 200.0, seed=0))
        assert 0.6 * 1000 < n < 1.4 * 1000, (gen.__name__, n)


def test_replay_explicit_lengths():
    reqs = replay([0.5, 0.1, 0.3], prompt_lens=[4, 5, 6],
                  output_lens=[2, 3, 4])
    assert [r.arrival_s for r in reqs] == [0.1, 0.3, 0.5]
    # log pairing survives the sort: t=0.5 arrived with prompt 4 / output 2
    assert [(r.prompt_len, r.output_len) for r in reqs] == \
        [(5, 3), (6, 4), (4, 2)]
    with pytest.raises(ValueError):
        replay([0.1], prompt_lens=[1, 2], output_lens=[1])
    with pytest.raises(ValueError):
        replay([0.1], prompt_lens=[1])        # one-sided log is an error


# ---------------------------------------------------------- occupancy model

@pytest.fixture(scope="module")
def gqa_traffic():
    cfg = get_arch("dsr1d-qwen-1.5b")
    reqs = generate("poisson", 3.0, 8.0, seed=0,
                    lengths=LengthModel(max_len=512))
    return cfg, simulate_traffic(cfg, reqs, num_slots=4, max_len=512)


def test_occupancy_conserves_bytes(gqa_traffic):
    """Admitted bytes == retired bytes at drain; trace returns to zero."""
    _, sim = gqa_traffic
    assert sim.stats.finished == sim.stats.admitted > 0
    assert sim.stats.admitted_bytes == sim.stats.retired_bytes > 0
    _, needed, obsolete = sim.trace.as_arrays()
    assert needed[-1] == 0
    assert (needed >= 0).all()
    assert (obsolete == 0).all()


def test_occupancy_respects_slot_capacity(gqa_traffic):
    cfg, sim = gqa_traffic
    per_slot = kv_bytes_at(cfg, 512) + slot_state_bytes(cfg)
    assert sim.trace.peak_needed() <= 4 * per_slot
    assert sim.stats.peak_active_slots <= 4


def test_single_token_requests_drain():
    cfg = get_arch("dsr1d-qwen-1.5b")
    reqs = replay([0.0, 0.1], prompt_lens=[16, 16], output_lens=[1, 1])
    sim = simulate_traffic(cfg, reqs, num_slots=2, max_len=128)
    assert sim.stats.finished == 2
    assert sim.stats.decode_steps == 0       # prefill token satisfied both
    assert sim.stats.admitted_bytes == sim.stats.retired_bytes


def test_mha_vs_gqa_peak_under_identical_traffic():
    """The paper's headline, under load instead of a single inference."""
    reqs = generate("poisson", 3.0, 8.0, seed=0,
                    lengths=LengthModel(max_len=512))
    gqa = simulate_traffic(get_arch("dsr1d-qwen-1.5b"), reqs, num_slots=4,
                           max_len=512)
    mha = simulate_traffic(get_arch("gpt2-xl"), reqs, num_slots=4,
                           max_len=512)
    assert mha.trace.peak_needed() > 4 * gqa.trace.peak_needed()


def test_traffic_determinism(gqa_traffic):
    cfg, sim = gqa_traffic
    reqs = generate("poisson", 3.0, 8.0, seed=0,
                    lengths=LengthModel(max_len=512))
    sim2 = simulate_traffic(cfg, reqs, num_slots=4, max_len=512)
    assert sim2.trace.ev_times == sim.trace.ev_times
    assert sim2.trace.ev_dneeded == sim.trace.ev_dneeded
    assert sim2.total_time == sim.total_time


# ------------------------------------------------------------- trace helpers

def test_merge_traces_superposes():
    a = OccupancyTrace("a", 100)
    b = OccupancyTrace("b", 100)
    a.event(0.0, 10, 0)
    a.event(2.0, -10, 0)
    b.event(1.0, 5, 0)
    b.event(3.0, -5, 0)
    m = merge_traces([a, b])
    t, n, _ = m.as_arrays()
    assert list(t) == [0.0, 1.0, 2.0, 3.0]
    assert list(n) == [10, 15, 5, 0]


def test_resampled_bounds_segments_and_preserves_mass(gqa_traffic):
    _, sim = gqa_traffic
    end = sim.total_time
    coarse = sim.trace.resampled(0.25, end)
    dur, _ = coarse.occupancy_series(end)
    assert len(dur) <= int(end / 0.25) + 3
    assert coarse.peak_needed() <= sim.trace.peak_needed()
    fine_mean = sim.trace.time_weighted_mean(end)
    coarse_mean = coarse.time_weighted_mean(end)
    assert abs(coarse_mean - fine_mean) < 0.25 * max(fine_mean, 1.0)


# ----------------------------------------------------------- online control

def test_online_between_oracle_and_none(gqa_traffic):
    _, sim = gqa_traffic
    dur, occ = sim.trace.occupancy_series(sim.total_time, use="needed")
    cap = max(64 * MIB, int(sim.trace.peak_needed()))
    c = compare(dur, occ, capacity=cap, banks=8,
                n_reads=sim.bundle.access.n_reads("kv"),
                n_writes=sim.bundle.access.n_writes("kv"))
    assert c.oracle.e_total <= c.online.e_total <= c.none.e_total
    assert c.online.wake_violations >= 0
    assert c.online.stall_s == pytest.approx(
        c.online.wake_violations * ControllerConfig().wake_latency_s)


def test_online_beats_none_on_long_idles():
    """1 s busy / 1 s idle alternation with sub-ms break-even: the timeout
    policy must strictly beat leaving every bank on."""
    d = np.array([1.0, 1.0] * 8)
    occ = np.array([100 * MIB, 1 * MIB] * 8, np.int64)
    kw = dict(capacity=128 * MIB, banks=8, n_reads=100, n_writes=100)
    online = simulate_online(d, occ, **kw)
    none = evaluate(d, occ, policy=Policy.none(0.9), **kw)
    oracle = evaluate(d, occ, policy=Policy("oracle", 0.9, gate=True,
                                            min_gate_multiple=2.0), **kw)
    assert oracle.e_total <= online.e_total < none.e_total
    assert online.wake_violations > 0
    # leakage gap vs the oracle is exactly the hysteresis wait
    assert online.gating.gated_bank_seconds < oracle.gated_bank_seconds


def test_online_hysteresis_monotone():
    """Longer hysteresis -> never more gated seconds."""
    d = np.array([1.0, 1.0] * 8)
    occ = np.array([100 * MIB, 1 * MIB] * 8, np.int64)
    kw = dict(capacity=128 * MIB, banks=8)
    prev = None
    for mult in (1.0, 2.0, 8.0):
        r = simulate_online(d, occ, cfg=ControllerConfig(
            hysteresis_multiple=mult), **kw)
        if prev is not None:
            assert r.gating.gated_bank_seconds <= prev + 1e-12
        prev = r.gating.gated_bank_seconds


# -------------------------------------------------------- campaign / Stage II

def test_sweep_runs_on_traffic_bundle(gqa_traffic):
    _, sim = gqa_traffic
    table = sweep(sim.bundle, mem_name="kv", max_capacity_mib=max(
        128, int(sim.trace.peak_needed() / MIB) + 16))
    assert len(table.rows) >= 6
    by_c = table.by_capacity()
    rows = next(iter(by_c.values()))
    base = next(r for r in rows if r.banks == 1)
    best = min(rows, key=lambda r: r.result.e_total)
    assert best.banks > 1
    assert best.result.e_total < base.result.e_total


def test_fast_grid_lower_bounds_oracle(gqa_traffic):
    _, sim = gqa_traffic
    dur, occ = sim.trace.occupancy_series(sim.total_time, use="needed")
    n_r = sim.bundle.access.n_reads("kv")
    n_w = sim.bundle.access.n_writes("kv")
    caps, banks = [64, 128], [1, 4, 8]
    fast = fast_candidate_energies(dur, occ, capacities_mib=caps,
                                   banks=banks, alpha=0.9, n_reads=n_r,
                                   n_writes=n_w, backend="ref")
    assert fast.shape == (6,)
    assert (fast > 0).all()
    for i, (c, b) in enumerate((c, b) for c in caps for b in banks):
        oracle = evaluate(dur, occ, capacity=c * MIB, banks=b,
                          policy=Policy("o", 0.9, gate=True,
                                        min_gate_multiple=2.0),
                          n_reads=n_r, n_writes=n_w)
        assert fast[i] <= oracle.e_total * (1 + 1e-6)


def test_run_scenario_deterministic():
    scn = Scenario(arch="dsr1d-qwen-1.5b", rate=2.0, horizon_s=5.0,
                   num_slots=4, max_len=512)
    kw = dict(capacities_mib=None, banks=(1, 8), ctrl=ControllerConfig(),
              lengths=LengthModel(max_len=512), fast_backend="ref")
    _, rows1, fast1 = run_scenario(scn, **kw)
    _, rows2, fast2 = run_scenario(scn, **kw)
    assert [r.e_online for r in rows1] == [r.e_online for r in rows2]
    np.testing.assert_array_equal(fast1, fast2)
    assert rows1, "auto capacities produced no rows"


# ------------------------------------------------- satellites: budget + engine

def test_kv_slot_budget_unbounded_is_none():
    from dataclasses import replace
    from repro.configs.base import RGLRUConfig
    # truly stateless: attention with no KV heads holds nothing per sequence
    stateless = replace(get_arch("gpt2-xl"), name="tmp-stateless",
                        num_kv_heads=0)
    assert kv_slot_budget(stateless, 16e9, max_len=1024) is None
    # stateful archs still return finite budgets — including pure RG-LRU,
    # whose recurrent state is per-sequence even though it holds no KV
    rglru = replace(get_arch("mamba2-130m"), name="tmp-rglru",
                    block_pattern=("rglru",), ssm=None, rglru=RGLRUConfig())
    assert isinstance(kv_slot_budget(rglru, 16e9, 1024), int)
    assert slot_state_bytes(rglru) > 0
    assert isinstance(kv_slot_budget(get_arch("gpt2-xl"), 16e9, 1024), int)


def test_find_min_sram_bisection_matches_linear_scan():
    from repro.core.workload import build_graph
    from repro.sim.accelerator import baseline_accelerator
    from repro.sim.engine import find_min_sram, simulate
    cfg = reduced(get_arch("dsr1d-qwen-1.5b"))
    g = build_graph(cfg, M=256, subops=4)
    accel = baseline_accelerator(8)
    mib, res = find_min_sram(g, accel, lo_mib=1, hi_mib=16, step_mib=1)
    assert res.writebacks == 0
    # ground truth: first zero-writeback capacity on the grid
    for m in range(1, 17):
        if simulate(g, accel.with_sram_capacity(m * 2**20)).writebacks == 0:
            assert mib == m
            break


def test_resolve_arch_spellings():
    assert resolve_arch("dsr1d_qwen_1_5b").name == "dsr1d-qwen-1.5b"
    assert resolve_arch("GPT2_XL").name == "gpt2-xl"
    assert resolve_arch("gpt2-xl").name == "gpt2-xl"
    with pytest.raises(KeyError):
        resolve_arch("no-such-arch")


# -------------------------------------------------- batcher trace emission

@pytest.fixture(scope="module")
def tiny_batcher_run():
    import jax
    import jax.numpy as jnp
    from repro.models import build_model
    from repro.serve.scheduler import ContinuousBatcher, Request
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(m, params, num_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, 6 + i),
                    max_new_tokens=3 + i % 3) for i in range(4)]
    for r in reqs:
        cb.submit(r)
    done = cb.run()
    return cfg, cb, done


def test_batcher_emits_occupancy_trace(tiny_batcher_run):
    cfg, cb, done = tiny_batcher_run
    assert len(done) == 4
    assert len(cb.trace.ev_times) > 0
    assert cb.stats.admitted_kv_bytes == cb.stats.retired_kv_bytes > 0
    _, needed, _ = cb.trace.as_arrays()
    assert needed[-1] == 0
    assert needed.max() > 0
    bundle = cb.occupancy_bundle()
    assert isinstance(bundle, TraceBundle)
    assert bundle.total_time > 0
    # Stage II consumes the live serving trace unchanged
    table = sweep(bundle, mem_name="kv", capacities_mib=[16], banks=(1, 4))
    assert len(table.rows) == 2


def test_batcher_trace_clamps_at_max_len(tiny_batcher_run):
    """Decoding past the jitted cache bound must not grow the trace past the
    declared capacity."""
    cfg, cb, _ = tiny_batcher_run
    from repro.serve.scheduler import ContinuousBatcher, Request
    cb2 = ContinuousBatcher(cb.model, cb.params, num_slots=1, max_len=64)
    cb2.submit(Request(rid=0, tokens=np.arange(60) % cfg.vocab_size,
                       max_new_tokens=16))
    cb2.run()
    assert cb2.trace.peak_needed() <= cb2.trace.capacity
    assert cb2.stats.admitted_kv_bytes == cb2.stats.retired_kv_bytes


def test_batcher_first_token_counts(tiny_batcher_run):
    """max_new_tokens=1 must be satisfied by the prefill's token alone."""
    cfg, cb, _ = tiny_batcher_run
    import jax
    from repro.serve.scheduler import ContinuousBatcher, Request
    cb2 = ContinuousBatcher(cb.model, cb.params, num_slots=1, max_len=64)
    cb2.submit(Request(rid=0, tokens=np.arange(5) % cfg.vocab_size,
                       max_new_tokens=1))
    done = cb2.run()
    assert len(done) == 1
    assert len(done[0].output) == 1
    assert cb2.stats.decode_steps == 0
