"""End-to-end behaviour tests: the TRAPTI two-stage flow on arbitrary archs,
train -> serve round trip, and the serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_arch, reduced
from repro.core.explorer import min_capacity_mib, sweep
from repro.core.workload import build_graph
from repro.sim.accelerator import baseline_accelerator
from repro.sim.engine import find_min_sram, simulate


def test_trapti_two_stage_end_to_end():
    """Stage I (size -> trace) then Stage II (banking) on the paper workload."""
    cfg = get_arch("dsr1d-qwen-1.5b")
    g = build_graph(cfg, M=2048, subops=4)
    mib, sim = find_min_sram(g, baseline_accelerator(128), lo_mib=16,
                             hi_mib=128, step_mib=16)
    assert sim.writebacks == 0
    table = sweep(sim, capacities_mib=[mib, 128])
    best = table.best()
    assert best.banks > 1
    assert best.result.e_total < table.rows[0].result.e_total


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_trapti_applies_to_every_assigned_arch(name):
    """The paper's technique is workload-agnostic: every assigned arch lowers
    to a graph, simulates, and yields a banking recommendation."""
    cfg = reduced(get_arch(name))
    g = build_graph(cfg, M=256, subops=4)
    assert g.total_macs() > 0
    sim = simulate(g, baseline_accelerator(64))
    assert sim.total_time > 0
    tr = sim.traces["sram"]
    assert tr.peak_needed() > 0
    table = sweep(sim, capacities_mib=[16], banks=(1, 4, 8))
    assert len(table.rows) == 3
    assert table.best().result.e_total <= table.rows[0].result.e_total


def test_gqa_vs_mha_banking_advantage():
    """Paper claim C5: the GQA workload benefits more from banking+PG."""
    gpt = simulate(build_graph(get_arch("gpt2-xl"), M=2048, subops=4),
                   baseline_accelerator(160))
    ds = simulate(build_graph(get_arch("dsr1d-qwen-1.5b"), M=2048, subops=4),
                  baseline_accelerator(128))
    t_gpt = sweep(gpt, capacities_mib=[128])
    t_ds = sweep(ds, capacities_mib=[128])
    best_gpt = min(r.delta_e_pct for r in t_gpt.rows)
    best_ds = min(r.delta_e_pct for r in t_ds.rows)
    assert best_ds < best_gpt - 10.0     # ours: ~ -70% vs -49%


def test_train_then_serve_round_trip(tmp_path):
    from repro.data import DataConfig, SyntheticTokens
    from repro.models import build_model
    from repro.optim import AdamW, constant
    from repro.serve import BatchedServer, ServeConfig
    from repro.train import LoopConfig, TrainLoop

    cfg = reduced(get_arch("dsr1d-qwen-1.5b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    opt = AdamW(lr=constant(2e-3))
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=8, seed=11))
    loop = TrainLoop(m, opt, data, LoopConfig(
        total_steps=30, ckpt_every=30, ckpt_dir=str(tmp_path / "ck")))
    out = loop.run()
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]

    srv = BatchedServer(m, out["params"], ServeConfig(max_len=64,
                                                      max_new_tokens=6))
    prompts = {"tokens": jnp.asarray(
        np.arange(3 * 12).reshape(3, 12) % cfg.vocab_size, jnp.int32)}
    res = srv.generate(prompts)
    assert res["tokens"].shape == (3, 6)
    assert (res["tokens"] >= 0).all()
    assert (res["tokens"] < cfg.padded_vocab).all()
    # greedy decoding is deterministic
    res2 = srv.generate(prompts)
    np.testing.assert_array_equal(res["tokens"], res2["tokens"])


def test_serve_batch_entries_independent():
    """Row i's generation must not depend on other rows in the batch."""
    from repro.models import build_model
    from repro.serve import BatchedServer, ServeConfig
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    srv = BatchedServer(m, params, ServeConfig(max_len=32, max_new_tokens=4))
    p1 = np.arange(8)[None, :] % cfg.vocab_size
    p2 = (np.arange(8)[None, :] * 3 + 1) % cfg.vocab_size
    both = srv.generate({"tokens": jnp.asarray(
        np.concatenate([p1, p2]), jnp.int32)})
    solo = srv.generate({"tokens": jnp.asarray(p1, jnp.int32)})
    np.testing.assert_array_equal(both["tokens"][0], solo["tokens"][0])
