"""Stage-I engine: trace integrity, eviction/write-back behavior, determinism,
multi-level residency."""
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.workload import build_graph
from repro.sim.accelerator import (baseline_accelerator,
                                   multilevel_accelerator, sram_latency_ns)
from repro.sim.engine import find_min_sram, simulate


@pytest.fixture(scope="module")
def ds_result():
    g = build_graph(get_arch("dsr1d-qwen-1.5b"), M=2048, subops=4)
    return simulate(g, baseline_accelerator(128))


def test_all_ops_complete(ds_result):
    assert ds_result.total_time > 0
    assert abs(ds_result.total_macs / 3.04e12 - 1) < 0.01   # paper Table I


def test_trace_conserves_time(ds_result):
    tr = ds_result.traces["sram"]
    dur, n, o, tot = tr.segments(ds_result.total_time)
    assert abs(dur.sum() - ds_result.total_time) / ds_result.total_time < 0.01
    assert (n >= 0).all() and (o >= 0).all()


def test_occupancy_never_exceeds_capacity_materially(ds_result):
    tr = ds_result.traces["sram"]
    # in-flight staging may transiently overshoot; bounded at < 5%
    assert tr.peak_total() <= 128 * 2**20 * 1.05


def test_paper_claims_c1_c2(ds_result):
    """C1/C2: GQA peak and latency substantially below MHA."""
    g = build_graph(get_arch("gpt2-xl"), M=2048, subops=4)
    gpt = simulate(g, baseline_accelerator(128))
    peak_ratio = gpt.peak_needed() / ds_result.peak_needed()
    time_ratio = gpt.total_time / ds_result.total_time
    assert peak_ratio > 1.8, peak_ratio          # paper: 2.72x, ours ~2.06x
    assert time_ratio > 1.7, time_ratio          # paper: 1.89x, ours ~2.05x
    # absolute latency within 15% of the paper's 593.9 / 313.6 ms
    assert abs(gpt.total_time - 0.5939) / 0.5939 < 0.15
    assert abs(ds_result.total_time - 0.3136) / 0.3136 < 0.15
    # GPT-2 XL peak within 5% of the paper's 107.3 MiB
    assert abs(gpt.peak_needed() / 2**20 - 107.3) / 107.3 < 0.05


def test_tiny_sram_forces_writebacks():
    cfg = reduced(get_arch("dsr1d-qwen-1.5b"))
    g = build_graph(cfg, M=256, subops=4)
    small = simulate(g, baseline_accelerator(8).with_sram_capacity(64 * 1024))
    big = simulate(g, baseline_accelerator(64))
    assert small.writebacks > 0
    assert big.writebacks == 0
    assert small.total_time > big.total_time


def test_find_min_sram_monotone():
    cfg = reduced(get_arch("gpt2-xl"))
    g = build_graph(cfg, M=512, subops=4)
    mib, res = find_min_sram(g, baseline_accelerator(128), lo_mib=1,
                             hi_mib=64, step_mib=1)
    assert res.writebacks == 0
    assert res.peak_needed() <= mib * 2**20


def test_determinism(ds_result):
    g = build_graph(get_arch("dsr1d-qwen-1.5b"), M=2048, subops=4)
    r2 = simulate(g, baseline_accelerator(128))
    assert r2.total_time == ds_result.total_time
    assert r2.peak_needed() == ds_result.peak_needed()
    assert r2.access.reads_bytes == ds_result.access.reads_bytes


def test_multilevel_hierarchy():
    g = build_graph(get_arch("dsr1d-qwen-1.5b"), M=2048, subops=4)
    r = simulate(g, multilevel_accelerator(64))
    for mem in ("sram", "dm1", "dm2"):
        assert r.traces[mem].peak_needed() > 0
        assert r.traces[mem].peak_needed() <= 64 * 2**20
    # paper Sec IV-D: multilevel is slower due to data hopping via the SRAM
    base = simulate(g, baseline_accelerator(128))
    assert r.total_time > base.total_time
    assert r.pe_utilization < base.pe_utilization


def test_sram_latency_model_matches_paper_points():
    # paper: 32 ns @ 128 MiB, 22 ns @ 64 MiB
    assert abs(sram_latency_ns(128 * 2**20) - 32.0) < 2.0
    assert abs(sram_latency_ns(64 * 2**20) - 22.0) < 2.5


def test_per_op_breakdown_covers_all_tags(ds_result):
    ops = ds_result.ops
    assert "attn.qk" in ops.compute
    assert "ffn" in ops.compute
    for tag, c in ops.compute.items():
        assert c >= 0
        assert ops.memory.get(tag, 0) >= 0
