"""Speculative decoding on the paged KV cache.

The acceptance guarantee pinned here: with greedy decoding, every token the
speculative loop emits is the TARGET model's argmax — the draft only moves
the acceptance rate — so the accepted output stream must be bit-identical
to the non-speculative PR-4 paged decode loop, for any draft and any
speculate_k. Plus the rollback mechanics: both page lanes truncate back to
the accepted context at chunk boundaries, pages are conserved, and the
allocator drains to zero.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.models.transformer import self_spec_draft
from repro.serve import PagedContinuousBatcher, Request


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


@pytest.fixture(scope="module")
def small4():
    """4 layers so self-spec skip=2 is a genuinely different (2-layer)
    draft with an imperfect acceptance rate — the rollback exerciser."""
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=4)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(1))
    return cfg, m, params


def _batcher(m, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 64)
    kw.setdefault("max_pages_per_slot", 8)
    kw.setdefault("chunk_steps", 4)
    kw.setdefault("attn_backend", "ref")
    return PagedContinuousBatcher(m, params, **kw)


def _run(m, params, prompts, new, **kw):
    b = _batcher(m, params, **kw)
    for i, (p, n) in enumerate(zip(prompts, new)):
        b.submit(Request(rid=i, tokens=np.asarray(p), max_new_tokens=n))
    done = b.run()
    return {r.rid: list(r.output) for r in done}, b


# ---------------------------------------------------------------------------
# Bit-identity: accepted tokens == the non-speculative loop's tokens
# ---------------------------------------------------------------------------

def test_spec_tokens_bit_identical_to_nonspec_loop(small4):
    """The headline guarantee: greedy speculative output is bit-identical
    to the non-speculative paged loop, with an *imperfect* draft (skip=2
    self-speculation) actually rejecting candidates along the way."""
    cfg, m, params = small4
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (7, 12, 5)]
    new = [11, 9, 13]
    ref, _ = _run(m, params, prompts, new)
    for k in (1, 2, 3):
        got, b = _run(m, params, prompts, new, speculate_k=k)
        assert got == ref, f"speculate_k={k} changed the output stream"
        st = b.stats
        assert st.accepted_tokens == sum(n - 1 for n in new)
        assert st.spec_rounds >= 1
        assert st.drafted_tokens == st.spec_rounds * k
        # every round accepts in [1, k+1]
        assert st.spec_rounds <= st.accepted_tokens
        assert st.accepted_tokens <= st.spec_rounds * (k + 1)
        assert b.ledger.allocator.n_allocated == 0


def test_spec_oracle_draft_accepts_everything(small):
    """skip=1 self-speculation IS the target: every candidate must be
    accepted (m = k+1 per full round), giving the upper-bound round count
    ceil(tokens / (k+1)) per request — and the same tokens."""
    cfg, m, params = small
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (9, 6)]
    new = [12, 12]
    ref, _ = _run(m, params, prompts, new)
    draft, dparams = self_spec_draft(m, params, skip=1)
    got, b = _run(m, params, prompts, new, speculate_k=3,
                  draft_model=draft, draft_params=dparams)
    assert got == ref
    st = b.stats
    assert st.accepted_tokens == st.spec_rounds * 4 - \
        (-st.accepted_tokens % 4)  # all full rounds but the last remainder
    # 11 post-prefill tokens per request at 4/round -> 3 rounds each
    assert st.spec_rounds == 6


def test_spec_eos_clips_inside_window(small):
    """An EOS landing mid-verify-window must clip acceptance exactly where
    the sequential loop would stop; tokens after it are discarded even if
    the target would have accepted them."""
    cfg, m, params = small
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (8, 11)]
    new = [40, 40]
    ref, rb = _run(m, params, prompts, new)
    # pick an eos that actually occurs mid-stream in the reference output
    eos = None
    for rid, toks in ref.items():
        for tok in toks[1:-1]:
            eos = int(tok)
            break
        if eos is not None:
            break
    assert eos is not None

    def run_eos(**kw):
        b = _batcher(m, params, **kw)
        for i, (p, n) in enumerate(zip(prompts, new)):
            b.submit(Request(rid=i, tokens=np.asarray(p), max_new_tokens=n,
                             eos_id=eos))
        return {r.rid: list(r.output) for r in b.run()}, b

    ref_eos, _ = run_eos()
    got_eos, b = run_eos(speculate_k=3)
    assert got_eos == ref_eos
    assert b.ledger.allocator.n_allocated == 0


def test_spec_composes_with_prefix_cache(small):
    """Speculation on top of prefix sharing: the draft lane never shares
    (full fresh prefill), the target lane still reuses the radix match,
    and rollback truncation never reclaims a shared page — output stays
    bit-identical."""
    cfg, m, params = small
    rng = np.random.default_rng(6)
    shared = rng.integers(0, cfg.vocab_size, 17)
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, n)])
               for n in (9, 7, 12)]
    new = [8, 9, 7]
    ref, _ = _run(m, params, prompts, new, num_pages=128,
                  max_pages_per_slot=10)
    got, b = _run(m, params, prompts, new, num_pages=128,
                  max_pages_per_slot=10, prefix_cache=True, speculate_k=2)
    assert got == ref
    assert b.stats.prefix_hits >= 1
    assert b.stats.accepted_tokens == sum(n - 1 for n in new)
    # retirement leaves only index-cached pages; none of them draft pages
    assert b.ledger.draft_pages == {}
    assert b.ledger.allocator.n_allocated == b.ledger.index.n_cached_pages


# ---------------------------------------------------------------------------
# Rollback-by-truncation mechanics + occupancy signature
# ---------------------------------------------------------------------------

def test_spec_rollback_truncates_pages_midstream(small4):
    """Rejected speculative tails must actually free pages mid-stream: the
    occupancy trace carries negative deltas before the final retire, the
    rolled-back page counter moves, and burst/rollback conserves pages."""
    cfg, m, params = small4
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (9, 6)]
    got, b = _run(m, params, prompts, [16, 18], speculate_k=3)
    st = b.stats
    assert st.rolled_back_pages > 0
    assert st.pages_freed > st.rolled_back_pages  # retire frees the rest
    ev = np.asarray(b.ledger.trace.ev_dneeded)
    # negative (rollback/retire) deltas interleave with positive bursts
    assert (ev < 0).sum() > len(prompts)          # more frees than retires
    assert ev.sum() == 0                          # drains to zero
    assert b.ledger.allocator.n_allocated == 0


def test_spec_timeline_and_occupancy_bundle(small):
    """The spec loop still produces a well-formed Stage-I bundle: the trace
    integrates to zero, peak covers both lanes, and access accounting saw
    draft + target traffic."""
    cfg, m, params = small
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, 10)]
    got, b = _run(m, params, prompts, [12], speculate_k=2)
    bundle = b.occupancy_bundle()
    tr = bundle.traces["kv"]
    _, n, _ = tr.as_arrays()
    assert int(n[-1]) == 0
    assert tr.peak_needed() > 0
    assert bundle.access.n_reads("kv") > 0
    assert bundle.access.n_writes("kv") > 0


# ---------------------------------------------------------------------------
# Validation / gating
# ---------------------------------------------------------------------------

def test_spec_validation(small):
    cfg, m, params = small
    with pytest.raises(ValueError, match="speculate_k"):
        _batcher(m, params, speculate_k=0)
    with pytest.raises(NotImplementedError, match="collect_logits"):
        _batcher(m, params, speculate_k=2, collect_logits=True)
    with pytest.raises(NotImplementedError, match="int8"):
        _batcher(m, params, speculate_k=2, kv_dtype="int8")
    draft, dparams = self_spec_draft(m, params, skip=2)
    with pytest.raises(ValueError, match="together"):
        _batcher(m, params, speculate_k=2, draft_model=draft)


def test_self_spec_draft_shapes(small):
    cfg, m, params = small
    draft, dparams = self_spec_draft(m, params, skip=2)
    assert draft.cfg.num_layers == 1
    assert draft.cfg.name.endswith("-selfspec2")
    # sliced stacked params keep the layer axis, length = kept layers
    leaf = jax.tree.leaves(dparams["blocks"][0])[0]
    ref = jax.tree.leaves(params["blocks"][0])[0]
    assert leaf.shape[0] == 1 and ref.shape[0] == 2
    assert leaf.shape[1:] == ref.shape[1:]
    with pytest.raises(ValueError, match="skip"):
        self_spec_draft(m, params, skip=0)
