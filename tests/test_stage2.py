"""Stage II: CACTI surrogate calibration, banking Eq.(1), gating Eq.(2-5)."""
import numpy as np
import pytest

from repro.core.banking import bank_activity, bank_on_matrix, idle_runs
from repro.core.cacti import characterize
from repro.core.explorer import min_capacity_mib, sweep
from repro.core.gating import Policy, evaluate

MIB = 2**20


# --- CACTI surrogate vs the paper's own CACTI-7 Table II points ------------

@pytest.mark.parametrize("c,b,area", [
    (48, 1, 854.50), (64, 1, 1126.74), (80, 1, 1432.50), (96, 1, 1696.02),
    (112, 1, 1959.54), (128, 1, 2196.94), (128, 8, 2357.82),
    (128, 16, 2425.46), (64, 16, 1287.32),
])
def test_area_within_5pct_of_paper(c, b, area):
    ch = characterize(c * MIB, b)
    assert abs(ch.area_mm2 / area - 1) < 0.05, (c, b, ch.area_mm2)


def test_leakage_linear_in_capacity():
    p64 = characterize(64 * MIB, 1).leak_w_total
    p128 = characterize(128 * MIB, 1).leak_w_total
    assert 1.9 < p128 / p64 < 2.1
    # absolute scale from the Table II fit: ~0.68 W/MiB
    assert 0.6 < p64 / 64 < 0.78


def test_banked_leakage_conserves_total():
    for b in (2, 4, 8, 16, 32):
        ch = characterize(128 * MIB, b)
        ch1 = characterize(128 * MIB, 1)
        # all banks on leaks slightly more than a monolithic array (periphery)
        assert ch.leak_w_total >= ch1.leak_w_total * 0.98
        assert ch.leak_w_total <= ch1.leak_w_total * 1.25


def test_access_energy_decreases_with_banking():
    e1 = characterize(128 * MIB, 1).e_read_j
    e16 = characterize(128 * MIB, 16).e_read_j
    assert e16 < e1


def test_break_even_is_sub_millisecond():
    for b in (4, 8, 16):
        assert characterize(128 * MIB, b).break_even_s < 1e-3


# --- Eq. (1) ----------------------------------------------------------------

def test_bank_activity_eq1():
    occ = np.array([0, 1, 10 * MIB, 64 * MIB, 128 * MIB], np.int64)
    act = bank_activity(occ, 1.0, 128 * MIB, 8)
    assert list(act) == [0, 1, 1, 4, 8]
    act09 = bank_activity(occ, 0.9, 128 * MIB, 8)
    assert (act09 >= act).all()
    assert act09[-1] == 8          # clipped at B


def test_alpha_validation():
    with pytest.raises(ValueError):
        bank_activity(np.array([1]), 0.0, MIB, 2)
    with pytest.raises(ValueError):
        bank_activity(np.array([1]), 1.5, MIB, 2)


def test_idle_runs_partition():
    d = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    on = np.array([True, False, False, True, False])
    run_d, s, e = idle_runs(d, on)
    assert list(run_d) == [5.0, 5.0]
    assert list(s) == [1, 3 + 1]
    assert list(e) == [3, 5]


# --- Eq. (2)-(5) -------------------------------------------------------------

def _toy_trace():
    # 1 s at high occupancy, 1 s nearly empty, repeated
    d = np.array([1.0, 1.0] * 8)
    occ = np.array([100 * MIB, 1 * MIB] * 8, np.int64)
    return d, occ


def test_gating_saves_leakage():
    d, occ = _toy_trace()
    kw = dict(capacity=128 * MIB, banks=8, n_reads=1000, n_writes=1000)
    none = evaluate(d, occ, policy=Policy.none(), **kw)
    cons = evaluate(d, occ, policy=Policy.conservative(), **kw)
    aggr = evaluate(d, occ, policy=Policy.aggressive(), **kw)
    assert cons.e_leak < none.e_leak
    assert aggr.e_leak <= cons.e_leak          # alpha=1.0 packs tighter
    assert cons.e_sw > 0 and none.e_sw == 0
    # switching overhead negligible (paper's observation)
    assert cons.e_sw < 0.01 * cons.e_total


def test_energy_decomposition_sums():
    d, occ = _toy_trace()
    r = evaluate(d, occ, capacity=128 * MIB, banks=16,
                 policy=Policy.conservative(), n_reads=5000, n_writes=3000)
    assert r.e_total == pytest.approx(r.e_dyn + r.e_leak + r.e_sw)


def test_single_bank_cannot_gate():
    d, occ = _toy_trace()
    r = evaluate(d, occ, capacity=128 * MIB, banks=1,
                 policy=Policy.conservative(), n_reads=0, n_writes=0)
    # occupancy never 0 -> the single bank stays on
    assert r.gated_bank_seconds == 0.0


def test_sweep_banking_beats_monolithic():
    """The paper's core Table-II finding on our traces."""
    from repro.configs import get_arch
    from repro.core.workload import build_graph
    from repro.sim.accelerator import baseline_accelerator
    from repro.sim.engine import simulate
    g = build_graph(get_arch("dsr1d-qwen-1.5b"), M=2048, subops=4)
    sim = simulate(g, baseline_accelerator(128))
    t = sweep(sim, capacities_mib=[64, 128])
    by_c = t.by_capacity()
    for c, rows in by_c.items():
        base = next(r for r in rows if r.banks == 1)
        best = min(rows, key=lambda r: r.result.e_total)
        assert best.banks in (8, 16, 32)
        assert best.result.e_total < 0.75 * base.result.e_total
        # area grows with banking
        assert all(r.result.area_mm2 >= base.result.area_mm2 for r in rows)


def test_min_capacity_rounding():
    assert min_capacity_mib(int(39.1 * MIB)) == 48
    assert min_capacity_mib(int(107.3 * MIB)) == 112
    assert min_capacity_mib(int(51.5 * MIB)) == 64
