"""Batched candidate-evaluation engine vs the scalar Stage-II references."""
import numpy as np
import pytest

from repro.core.candidates import (Candidate, evaluate_candidates,
                                   lower_bound_energies, make_grid)
from repro.core.cacti import characterize
from repro.core.gating import Policy, evaluate
from repro.core.sensitivity import evaluate_drowsy

MIB = 2**20
REL = 1e-9          # numpy backend is float64 — far inside the 1e-6 budget


def _assert_gate_matches(d, occ, cands, res, n_reads, n_writes):
    for i, c in enumerate(cands):
        pol = (Policy.none(c.alpha) if c.policy == "none"
               else Policy("g", c.alpha, True, c.min_gate_multiple))
        ref = evaluate(d, occ, capacity=c.capacity, banks=c.banks,
                       policy=pol, n_reads=n_reads, n_writes=n_writes)
        assert int(res.n_off[i]) == ref.n_transitions, (i, c)
        assert res.e_dyn[i] == pytest.approx(ref.e_dyn, rel=REL)
        assert res.e_leak[i] == pytest.approx(ref.e_leak, rel=REL, abs=1e-18)
        assert res.e_sw[i] == pytest.approx(ref.e_sw, rel=REL, abs=1e-18)
        assert res.e_total[i] == pytest.approx(ref.e_total, rel=REL)
        assert res.gated_bank_seconds[i] == pytest.approx(
            ref.gated_bank_seconds, rel=REL, abs=1e-12)
        g = res.gating_result(i)
        assert g.e_total == pytest.approx(ref.e_total, rel=REL)
        assert g.area_mm2 == pytest.approx(ref.area_mm2)


def _grid():
    return [Candidate(c * MIB, b, a, p, m)
            for c in (48, 128) for b in (1, 4, 32)
            for a in (0.9, 1.0) for p, m in
            (("gate", 1.0), ("gate", 5.0), ("none", 1.0))]


def test_batched_matches_scalar_on_dense_grid():
    rng = np.random.default_rng(0)
    d = rng.random(200) * 1e-3 + 1e-6
    occ = rng.integers(0, 130 * MIB, 200).astype(np.int64)
    cands = _grid()
    res = evaluate_candidates(d, occ, cands, n_reads=1000, n_writes=500)
    _assert_gate_matches(d, occ, cands, res, 1000, 500)


def test_batched_drowsy_matches_scalar():
    rng = np.random.default_rng(1)
    d = rng.random(150) * 1e-3 + 1e-6
    occ = rng.integers(0, 130 * MIB, 150).astype(np.int64)
    cands = [Candidate(c * MIB, b, 0.9, "drowsy", m)
             for c in (64, 128) for b in (1, 8, 16) for m in (1.0, 1e3)]
    res = evaluate_candidates(d, occ, cands, n_reads=42, n_writes=17)
    for i, c in enumerate(cands):
        ref = evaluate_drowsy(d, occ, capacity=c.capacity, banks=c.banks,
                              n_reads=42, n_writes=17,
                              off_multiple=c.min_gate_multiple)
        assert int(res.n_off[i]) == ref.n_off
        assert int(res.n_drowsy[i]) == ref.n_drowsy
        assert res.e_leak_on[i] == pytest.approx(ref.e_leak_on, rel=REL)
        assert res.e_leak_drowsy[i] == pytest.approx(
            ref.e_leak_drowsy, rel=REL, abs=1e-18)
        assert res.e_sw[i] == pytest.approx(ref.e_sw, rel=REL, abs=1e-18)
        dr = res.drowsy_result(i)
        assert dr.e_total == pytest.approx(ref.e_total, rel=REL)


@pytest.mark.parametrize("case", ["empty", "single", "always_idle",
                                  "always_busy", "zero_durations"])
def test_edge_traces(case):
    if case == "empty":
        d, occ = np.zeros(0), np.zeros(0, np.int64)
    elif case == "single":
        d, occ = np.array([2.5]), np.array([30 * MIB], np.int64)
    elif case == "always_idle":
        d, occ = np.ones(20), np.zeros(20, np.int64)
    elif case == "always_busy":
        d, occ = np.ones(20), np.full(20, 128 * MIB, np.int64)
    else:
        d = np.array([0.0, 1.0, 0.0, 1.0, 0.0])
        occ = np.array([0, 100 * MIB, 0, 100 * MIB, 0], np.int64)
    cands = [Candidate(128 * MIB, b, a, p)
             for b in (1, 8) for a in (0.9,) for p in ("none", "gate")]
    res = evaluate_candidates(d, occ, cands, n_reads=3, n_writes=4)
    _assert_gate_matches(d, occ, cands, res, 3, 4)
    dres = evaluate_candidates(d, occ,
                               [Candidate(128 * MIB, 8, policy="drowsy")],
                               n_reads=3, n_writes=4)
    ref = evaluate_drowsy(d, occ, capacity=128 * MIB, banks=8,
                          n_reads=3, n_writes=4)
    assert dres.e_total[0] == pytest.approx(ref.e_total, rel=REL)
    assert int(dres.n_off[0]) == ref.n_off
    assert int(dres.n_drowsy[0]) == ref.n_drowsy


def test_lower_bound_bounds_every_policy():
    rng = np.random.default_rng(2)
    d = rng.random(120) * 1e-3 + 1e-6
    occ = rng.integers(0, 100 * MIB, 120).astype(np.int64)
    cands = _grid() + [Candidate(c * MIB, b, 0.9, "drowsy", m)
                       for c in (48, 128) for b in (4, 32) for m in (1.0, 10)]
    lb = lower_bound_energies(d, occ, cands, n_reads=11, n_writes=13)
    res = evaluate_candidates(d, occ, cands, n_reads=11, n_writes=13)
    assert (lb <= res.e_total * (1 + 1e-12) + 1e-18).all()


def test_prune_never_drops_argmin():
    rng = np.random.default_rng(3)
    for trial in range(5):
        n = rng.integers(5, 120)
        d = rng.random(n) * 1e-3 + 1e-6
        occ = rng.integers(0, 140 * MIB, n).astype(np.int64)
        cands = make_grid([c * MIB for c in (48, 64, 96, 128, 160)],
                          (1, 2, 4, 8, 16, 32), alphas=(0.9, 1.0),
                          policies=("gate", "none", "drowsy"))
        full = evaluate_candidates(d, occ, cands, n_reads=100, n_writes=100)
        pruned = evaluate_candidates(d, occ, cands, n_reads=100,
                                     n_writes=100, prune=True)
        assert pruned.evaluated.sum() < len(cands), "prune did nothing"
        i, j = full.argmin(), pruned.argmin()
        assert full.e_total[i] == pytest.approx(pruned.e_total[j], rel=1e-12)
        # pruned rows carry the lower bound, which cannot beat the winner
        lb_rows = pruned.e_total[~pruned.evaluated]
        assert (lb_rows >= full.e_total[i] * (1 - 1e-9)).all()


def test_always_evaluate_exempts_indices():
    d = np.array([1.0, 1.0] * 8)
    occ = np.array([100 * MIB, 1 * MIB] * 8, np.int64)
    cands = make_grid([128 * MIB, 256 * MIB], (1, 2, 4, 8, 16, 32))
    res = evaluate_candidates(d, occ, cands, n_reads=0, n_writes=0,
                              prune=True, always_evaluate=[0, 6])
    assert res.evaluated[0] and res.evaluated[6]


def test_alpha_validation_matches_scalar():
    with pytest.raises(ValueError):
        Candidate(MIB, 2, alpha=0.0)
    with pytest.raises(ValueError):
        Candidate(MIB, 2, alpha=1.5)
    with pytest.raises(ValueError):
        Candidate(MIB, 2, policy="laissez-faire")


# --- satellites: memoization, sensitivity hook --------------------------------

def test_characterize_is_memoized():
    assert characterize(64 * MIB, 8) is characterize(64 * MIB, 8)
    assert characterize(64 * MIB, 8) is not characterize(64 * MIB, 16)


def test_e_switch_scale_hook():
    base = characterize(128 * MIB, 8)
    scaled = characterize(128 * MIB, 8, e_switch_scale=10.0)
    assert scaled.e_switch_j == pytest.approx(10 * base.e_switch_j)
    # break-even is implied by E_sw, so it must scale along
    assert scaled.break_even_s == pytest.approx(10 * base.break_even_s)
    assert scaled.leak_w_per_bank == base.leak_w_per_bank


def test_drowsy_e_switch_scale_matches_scalar():
    """The scale hook must stay reference-checkable for drowsy too."""
    rng = np.random.default_rng(4)
    d = rng.random(80) * 1e-3 + 1e-6
    occ = rng.integers(0, 130 * MIB, 80).astype(np.int64)
    for s in (0.1, 10.0):
        res = evaluate_candidates(
            d, occ, [Candidate(128 * MIB, 8, 0.9, "drowsy", 1.0,
                               e_switch_scale=s)],
            n_reads=5, n_writes=7)
        ref = evaluate_drowsy(d, occ, capacity=128 * MIB, banks=8,
                              n_reads=5, n_writes=7, off_multiple=1.0,
                              e_switch_scale=s)
        assert int(res.n_off[0]) == ref.n_off
        assert int(res.n_drowsy[0]) == ref.n_drowsy
        assert res.e_total[0] == pytest.approx(ref.e_total, rel=REL)


def test_policy_sensitivity_scale_leg_matches_scalar():
    """The batched sw_scale leg == scalar evaluate() with a scaled char."""
    from repro.core.sensitivity import policy_sensitivity
    d = np.array([1e-3, 1e-3] * 16)
    occ = np.array([100 * MIB, 1 * MIB] * 16, np.int64)
    sens = policy_sensitivity(d, occ, capacity=128 * MIB, banks=8,
                              n_reads=100, n_writes=100)
    for s in (0.1, 100.0):
        ch = characterize(128 * MIB, 8, e_switch_scale=s)
        ref = evaluate(d, occ, capacity=128 * MIB, banks=8,
                       policy=Policy("sens", 0.9, True, 1.0),
                       n_reads=100, n_writes=100, char=ch)
        assert sens["sw_scale"][s] == pytest.approx(ref.e_total, rel=REL)


# --- satellite: explorer delta baseline ---------------------------------------

def test_sweep_deltas_without_b1_baseline():
    """banks without B=1 must baseline against the smallest count present,
    not silently report 0.0 deltas."""
    from repro.core.explorer import sweep
    from repro.sim.trace import AccessStats, OccupancyTrace, TraceBundle
    tr = OccupancyTrace("kv", 256 * MIB)
    tr.event(0.0, 40 * MIB, 0)
    tr.event(1.0, -39 * MIB, 0)
    tr.event(2.0, 39 * MIB, 0)
    bundle = TraceBundle("toy", 3.0, {"kv": tr}, AccessStats())
    table = sweep(bundle, mem_name="kv", capacities_mib=[64],
                  banks=(4, 8, 16))
    assert [r.banks for r in table.rows] == [4, 8, 16]
    base = table.rows[0]
    assert base.delta_e_pct == 0.0 and base.delta_a_pct == 0.0
    others = table.rows[1:]
    assert any(r.delta_e_pct != 0.0 for r in others)
    assert all(r.delta_a_pct > 0.0 for r in others)   # more banks, more area
    for r in others:
        assert r.delta_e_pct == pytest.approx(
            100.0 * (r.result.e_total / base.result.e_total - 1.0))


def test_sweep_prune_keeps_best_row():
    from repro.core.explorer import sweep
    from repro.sim.trace import AccessStats, OccupancyTrace, TraceBundle
    tr = OccupancyTrace("kv", 256 * MIB)
    for k in range(12):
        tr.event(k * 1.0, 30 * MIB if k % 2 == 0 else -29 * MIB, 0)
    bundle = TraceBundle("toy", 12.0, {"kv": tr}, AccessStats())
    kw = dict(mem_name="kv", capacities_mib=[32, 64, 128],
              banks=(1, 2, 4, 8, 16, 32))
    full = sweep(bundle, **kw)
    pruned = sweep(bundle, prune=True, **kw)
    assert len(pruned.rows) < len(full.rows)
    fb, pb = full.best(), pruned.best()
    assert (fb.capacity_mib, fb.banks) == (pb.capacity_mib, pb.banks)
    assert fb.result.e_total == pytest.approx(pb.result.e_total, rel=1e-12)


# Property tests (randomized traces, all policies, all backends) live in
# tests/test_candidates_props.py — they need hypothesis, which is optional.
