"""Beyond-paper features: mempeak scheduler, decode workload graphs,
roofline HLO parsing."""
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.workload import build_decode_graph, build_graph
from repro.launch.roofline import (collective_bytes, min_hbm_bytes,
                                   model_flops)
from repro.sim.accelerator import baseline_accelerator
from repro.sim.engine import simulate


def test_mempeak_reduces_peak_occupancy():
    g = build_graph(get_arch("dsr1d-qwen-1.5b"), M=2048, subops=4)
    a = baseline_accelerator(128)
    fifo = simulate(g, a, policy="fifo")
    mem = simulate(g, a, policy="mempeak")
    assert mem.traces["sram"].peak_needed() < 0.7 * fifo.traces["sram"].peak_needed()
    assert mem.writebacks == 0
    # same work is done either way
    assert mem.total_macs == fifo.total_macs


def test_mempeak_deterministic():
    cfg = reduced(get_arch("gpt2-xl"))
    g = build_graph(cfg, M=256, subops=4)
    a = baseline_accelerator(64)
    r1 = simulate(g, a, policy="mempeak")
    r2 = simulate(g, a, policy="mempeak")
    assert r1.total_time == r2.total_time
    assert r1.traces["sram"].peak_needed() == r2.traces["sram"].peak_needed()


def test_decode_graph_kv_scaling():
    """Fig.-1 mechanism: decode energy/traffic scales with kv-head count."""
    from dataclasses import replace
    base = get_arch("dsr1d-qwen-1.5b")
    mha = replace(base, name="tmp-mha", num_kv_heads=base.num_heads)
    g_gqa = build_decode_graph(base, context_len=2048, batch=16)
    g_mha = build_decode_graph(mha, context_len=2048, batch=16)

    def kv_bytes(g):
        return sum(t.size for t in g.tensors.values() if t.kind == "kv")

    ratio = kv_bytes(g_mha) / kv_bytes(g_gqa)
    assert 5.0 < ratio < 7.0          # 12 kv heads vs 2 -> ~6x
    a = baseline_accelerator(128)
    t_ratio = simulate(g_mha, a).total_time / simulate(g_gqa, a).total_time
    assert t_ratio > 2.0              # paper Fig. 1: 3.14x


def test_collective_bytes_parses_hlo_text():
    hlo = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%x), replica_groups=[4,16]<=[64]
  %all-gather = bf16[4096,128]{1,0} all-gather(%y), replica_groups=[2,8]<=[16]
  %reduce-scatter.3 = f32[64]{0} reduce-scatter(%z), replica_groups=[4,16]<=[64]
  %all-reduce-start = f32[256]{0} all-reduce-start(%w), replica_groups=[1,2]<=[2]
  %all-reduce-done = f32[256]{0} all-reduce-done(%all-reduce-start)
  %add = f32[9999]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 1024 * 512 * 4 + 256 * 4     # -done not counted
    assert out["all-gather"] == 4096 * 128 * 2
    assert out["reduce-scatter"] == 64 * 4 * 16              # x group size
    assert out["all-to-all"] == 0


def test_model_flops_sane():
    from repro.configs import SHAPES
    cfg = get_arch("qwen2-7b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
    assert pf == pytest.approx(2 * cfg.param_count() * 32 * 32768, rel=1e-6)
    assert dc < pf / 1000             # one token per sequence

    moe = get_arch("olmoe-1b-7b")
    assert model_flops(moe, SHAPES["train_4k"]) \
        == pytest.approx(6 * moe.active_param_count() * 256 * 4096, rel=1e-6)


def test_min_hbm_bytes_decode_counts_kv():
    from repro.configs import SHAPES
    cfg = get_arch("qwen2-7b")
    b = min_hbm_bytes(cfg, SHAPES["decode_32k"], 256)
    # weights bf16 / 256 chips is the floor
    assert b > cfg.param_count() * 2 / 256
    # local-window archs cap the decode KV term
    rg = get_arch("recurrentgemma-2b")
    b_rg = min_hbm_bytes(rg, SHAPES["long_500k"], 256)
    b_rg32 = min_hbm_bytes(rg, SHAPES["decode_32k"], 256)
    # long_500k batch is 128x smaller; per-batch KV is window-capped
    assert b_rg < b_rg32
