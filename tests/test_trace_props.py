"""Hypothesis property tests on Stage-II invariants and the trace pipeline."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.banking import (active_bank_seconds, bank_activity,
                                bank_on_matrix, idle_runs)
from repro.core.cacti import characterize
from repro.core.gating import Policy, evaluate

MIB = 2**20

trace_st = st.integers(min_value=1, max_value=200).flatmap(
    lambda n: st.tuples(
        st.lists(st.floats(1e-6, 10.0), min_size=n, max_size=n),
        st.lists(st.integers(0, 256 * MIB), min_size=n, max_size=n)))

cb_st = st.tuples(st.sampled_from([16, 32, 64, 128, 256]),
                  st.sampled_from([1, 2, 4, 8, 16, 32]))


@given(trace_st, cb_st, st.floats(0.1, 1.0))
@settings(max_examples=60, deadline=None)
def test_bank_activity_bounds_and_monotonicity(trace, cb, alpha):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    c_mib, b = cb
    act = bank_activity(occ, alpha, c_mib * MIB, b)
    assert (act >= 0).all() and (act <= b).all()
    # monotone in occupancy
    order = np.argsort(occ)
    assert (np.diff(act[order]) >= 0).all()
    # covers occupancy when not clipped
    usable = alpha * c_mib * MIB / b
    unclipped = act < b
    assert (act[unclipped] * usable >= occ[unclipped] - 1e-6).all()


@given(trace_st, cb_st)
@settings(max_examples=40, deadline=None)
def test_on_matrix_consistent_with_activity(trace, cb):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    c_mib, b = cb
    act = bank_activity(occ, 0.9, c_mib * MIB, b)
    on = bank_on_matrix(act, b)
    assert (on.sum(axis=1) == act).all()
    # banks fill lowest-first: on[:, j] implies on[:, i] for i < j
    for j in range(1, b):
        assert (on[:, j] <= on[:, j - 1]).all()


@given(trace_st)
@settings(max_examples=40, deadline=None)
def test_idle_runs_cover_idle_time_exactly(trace):
    d = np.asarray(trace[0])
    on = np.asarray(trace[1], np.int64) % 2 == 0
    run_d, starts, ends = idle_runs(d, on)
    assert run_d.sum() == np.float64(d[~on].sum()).round(10).item() or \
        abs(run_d.sum() - d[~on].sum()) < 1e-6
    # runs are disjoint and ordered
    for i in range(1, len(starts)):
        assert starts[i] >= ends[i - 1]


@given(trace_st, cb_st)
@settings(max_examples=30, deadline=None)
def test_gating_never_increases_leakage_beyond_none(trace, cb):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    c_mib, b = cb
    if c_mib * MIB < occ.max():
        occ = np.minimum(occ, c_mib * MIB)
    kw = dict(capacity=c_mib * MIB, banks=b, n_reads=100, n_writes=100)
    none = evaluate(d, occ, policy=Policy.none(), **kw)
    gated = evaluate(d, occ, policy=Policy.aggressive(), **kw)
    # gating is applied only when it passes break-even, so total never worse
    assert gated.e_leak + gated.e_sw <= none.e_leak * (1 + 1e-9) + 1e-12
    assert gated.e_dyn == none.e_dyn
    assert gated.n_transitions >= 0


@given(st.sampled_from([16, 32, 64, 128]), st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=30, deadline=None)
def test_cacti_surrogate_sanity(c_mib, b):
    ch = characterize(c_mib * MIB, b)
    assert ch.area_mm2 > 0
    assert ch.leak_w_per_bank > 0
    assert ch.e_read_j > 0 and ch.e_write_j > ch.e_read_j * 0.99
    assert ch.break_even_s > 0
    # smaller banks -> lower per-bank leakage
    if b > 1:
        assert ch.leak_w_per_bank < characterize(c_mib * MIB, 1).leak_w_per_bank


@given(trace_st, st.sampled_from([1, 2, 4, 8, 16, 32]))
@settings(max_examples=30, deadline=None)
def test_bank_energy_kernel_matches_numpy_reference(trace, b):
    """Pallas bank_energy (interpret mode) == banking.py reference math."""
    from repro.kernels.bank_energy import bank_activity_stats
    d = np.asarray(trace[0], np.float32)
    occ = np.asarray(trace[1], np.float32)
    cap = 128 * MIB
    alpha = 0.9
    out = np.asarray(bank_activity_stats(
        d, occ, np.asarray([alpha * cap / b], np.float32),
        np.asarray([float(b)], np.float32), backend="interpret",
        block_s=64))
    act = bank_activity(occ.astype(np.int64), alpha, cap, b)
    expect_seconds = active_bank_seconds(d, act)
    expect_trans = np.abs(np.diff(act.astype(np.float64))).sum()
    assert abs(out[0, 0] - expect_seconds) <= max(1e-3 * expect_seconds, 1e-3)
    assert abs(out[0, 1] - expect_trans) <= 1e-3 * max(expect_trans, 1.0)
