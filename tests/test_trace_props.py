"""Hypothesis property tests on Stage-II invariants and the trace pipeline."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.banking import (active_bank_seconds, bank_activity,
                                bank_on_matrix, idle_runs)
from repro.core.cacti import characterize
from repro.core.gating import Policy, evaluate

MIB = 2**20

trace_st = st.integers(min_value=1, max_value=200).flatmap(
    lambda n: st.tuples(
        st.lists(st.floats(1e-6, 10.0), min_size=n, max_size=n),
        st.lists(st.integers(0, 256 * MIB), min_size=n, max_size=n)))

cb_st = st.tuples(st.sampled_from([16, 32, 64, 128, 256]),
                  st.sampled_from([1, 2, 4, 8, 16, 32]))


@given(trace_st, cb_st, st.floats(0.1, 1.0))
@settings(max_examples=60, deadline=None)
def test_bank_activity_bounds_and_monotonicity(trace, cb, alpha):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    c_mib, b = cb
    act = bank_activity(occ, alpha, c_mib * MIB, b)
    assert (act >= 0).all() and (act <= b).all()
    # monotone in occupancy
    order = np.argsort(occ)
    assert (np.diff(act[order]) >= 0).all()
    # covers occupancy when not clipped
    usable = alpha * c_mib * MIB / b
    unclipped = act < b
    assert (act[unclipped] * usable >= occ[unclipped] - 1e-6).all()


@given(trace_st, cb_st)
@settings(max_examples=40, deadline=None)
def test_on_matrix_consistent_with_activity(trace, cb):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    c_mib, b = cb
    act = bank_activity(occ, 0.9, c_mib * MIB, b)
    on = bank_on_matrix(act, b)
    assert (on.sum(axis=1) == act).all()
    # banks fill lowest-first: on[:, j] implies on[:, i] for i < j
    for j in range(1, b):
        assert (on[:, j] <= on[:, j - 1]).all()


@given(trace_st)
@settings(max_examples=40, deadline=None)
def test_idle_runs_cover_idle_time_exactly(trace):
    d = np.asarray(trace[0])
    on = np.asarray(trace[1], np.int64) % 2 == 0
    run_d, starts, ends = idle_runs(d, on)
    assert run_d.sum() == np.float64(d[~on].sum()).round(10).item() or \
        abs(run_d.sum() - d[~on].sum()) < 1e-6
    # runs are disjoint and ordered
    for i in range(1, len(starts)):
        assert starts[i] >= ends[i - 1]


@given(trace_st, cb_st)
@settings(max_examples=30, deadline=None)
def test_gating_never_increases_leakage_beyond_none(trace, cb):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    c_mib, b = cb
    if c_mib * MIB < occ.max():
        occ = np.minimum(occ, c_mib * MIB)
    kw = dict(capacity=c_mib * MIB, banks=b, n_reads=100, n_writes=100)
    none = evaluate(d, occ, policy=Policy.none(), **kw)
    gated = evaluate(d, occ, policy=Policy.aggressive(), **kw)
    # gating is applied only when it passes break-even, so total never worse
    assert gated.e_leak + gated.e_sw <= none.e_leak * (1 + 1e-9) + 1e-12
    assert gated.e_dyn == none.e_dyn
    assert gated.n_transitions >= 0


@given(st.sampled_from([16, 32, 64, 128]), st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=30, deadline=None)
def test_cacti_surrogate_sanity(c_mib, b):
    ch = characterize(c_mib * MIB, b)
    assert ch.area_mm2 > 0
    assert ch.leak_w_per_bank > 0
    assert ch.e_read_j > 0 and ch.e_write_j > ch.e_read_j * 0.99
    assert ch.break_even_s > 0
    # smaller banks -> lower per-bank leakage
    if b > 1:
        assert ch.leak_w_per_bank < characterize(c_mib * MIB, 1).leak_w_per_bank


# ---------------------------------------------------------------------------
# OccupancyTrace invariants (Stage-I artifact contract)
# ---------------------------------------------------------------------------

event_stream_st = st.lists(
    st.tuples(st.floats(0.0, 10.0), st.integers(-50 * MIB, 50 * MIB),
              st.integers(-50 * MIB, 50 * MIB)),
    min_size=1, max_size=120)

request_stream_st = st.lists(
    st.tuples(st.floats(0.0, 5.0),              # inter-arrival gap [s]
              st.integers(1, 300),              # prompt_len
              st.integers(1, 40)),              # output_len
    min_size=1, max_size=25)


def _trace_from(events):
    from repro.sim.trace import OccupancyTrace
    tr = OccupancyTrace("m", 512 * MIB)
    tr.event(0.0, MIB, 0)     # guarantee a non-empty stream (zero-delta
    ts, dn, do = zip(*events)  # rows are dropped by extend())
    tr.extend(ts, dn, do)
    return tr


@given(request_stream_st, st.sampled_from(["exact", "pss"]))
@settings(max_examples=25, deadline=None)
def test_traffic_deltas_sum_to_zero_and_respect_capacity(stream, fidelity):
    """Over every request lifetime admitted == retired, so the drained
    trace's delta events sum to zero and never exceed the slot capacity."""
    from repro.configs import get_arch
    from repro.traffic.generators import RequestSpec
    from repro.traffic.occupancy import simulate_traffic
    cfg = get_arch("dsr1d-qwen-1.5b")
    t, reqs = 0.0, []
    for i, (gap, p, o) in enumerate(stream):
        t += gap
        reqs.append(RequestSpec(rid=i, arrival_s=t, prompt_len=p,
                                output_len=o))
    sim = simulate_traffic(cfg, reqs, num_slots=4, max_len=256,
                           fidelity=fidelity)
    assert sim.stats.finished == len(reqs)
    assert sum(sim.trace.ev_dneeded) == 0
    assert sim.stats.admitted_bytes == sim.stats.retired_bytes
    assert sim.trace.peak_total() <= sim.trace.capacity


@given(event_stream_st, st.floats(0.1, 3.0))
@settings(max_examples=40, deadline=None)
def test_segment_durations_nonneg_and_cover_makespan(events, tail):
    tr = _trace_from(events)
    t, _, _ = tr.as_arrays()
    end = float(t[-1]) + tail
    dur, n, o, tot = tr.segments(end)
    assert (dur > 0).all()
    assert abs(dur.sum() - (end - t[0])) <= 1e-9 * max(end, 1.0)
    assert np.array_equal(tot, n + o)


@given(event_stream_st, event_stream_st)
@settings(max_examples=30, deadline=None)
def test_merge_preserves_time_integral(ev_a, ev_b):
    from repro.sim.trace import merge_traces
    a, b = _trace_from(ev_a), _trace_from(ev_b)
    end = max(max(t for t, _, _ in ev_a), max(t for t, _, _ in ev_b)) + 1.0
    merged = merge_traces([a, b])
    want = a.time_integral(end) + b.time_integral(end)
    got = merged.time_integral(end)
    assert abs(got - want) <= 1e-6 * max(abs(want), 1.0)


@given(event_stream_st, st.floats(0.05, 1.0))
@settings(max_examples=30, deadline=None)
def test_resample_preserves_integral_within_grid_bound(events, dt):
    """Right-edge resampling misattributes each delta by at most one grid
    cell, so the integral moves by <= dt * sum(|deltas|) (+ the held tail
    past the requested end)."""
    tr = _trace_from(events)
    t, n, o = tr.as_arrays()
    end = float(t[-1]) + 1.0
    res = tr.resampled(dt, end)
    want = tr.time_integral(end)
    got = res.time_integral(end)
    slack = dt * (np.abs(np.asarray(tr.ev_dneeded)).sum()
                  + np.abs(np.asarray(tr.ev_dobsolete)).sum()
                  + abs(int(n[-1]) + int(o[-1])))
    assert abs(got - want) <= slack * (1 + 1e-9) + 1e-6


@given(event_stream_st)
@settings(max_examples=30, deadline=None)
def test_as_arrays_cache_invalidation(events):
    """Cached integration must be transparent across event()/extend()."""
    tr = _trace_from(events)
    t1 = tr.as_arrays()
    assert tr.as_arrays()[0] is t1[0]          # cached object reused
    tr.event(11.0, 123, 0)
    t2, n2, _ = tr.as_arrays()
    assert len(t2) == len(t1[0]) + 1
    assert n2[-1] == t1[1][-1] + 123
    tr.extend([12.0], [1], [1])
    assert tr.as_arrays()[1][-1] == n2[-1] + 1


@given(trace_st, st.sampled_from([1, 2, 4, 8, 16, 32]))
@settings(max_examples=30, deadline=None)
def test_bank_energy_kernel_matches_numpy_reference(trace, b):
    """Pallas bank_energy (interpret mode) == banking.py reference math."""
    from repro.kernels.bank_energy import bank_activity_stats
    d = np.asarray(trace[0], np.float32)
    occ = np.asarray(trace[1], np.float32)
    cap = 128 * MIB
    alpha = 0.9
    out = np.asarray(bank_activity_stats(
        d, occ, np.asarray([alpha * cap / b], np.float32),
        np.asarray([float(b)], np.float32), backend="interpret",
        block_s=64))
    act = bank_activity(occ.astype(np.int64), alpha, cap, b)
    expect_seconds = active_bank_seconds(d, act)
    expect_trans = np.abs(np.diff(act.astype(np.float64))).sum()
    assert abs(out[0, 0] - expect_seconds) <= max(1e-3 * expect_seconds, 1e-3)
    assert abs(out[0, 1] - expect_trans) <= 1e-3 * max(expect_trans, 1.0)
