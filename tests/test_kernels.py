"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bank_energy import (bank_activity_stats, candidate_grid,
                                       exact_bank_stats, exact_bank_stats_np)
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.gqa_decode import gqa_decode, gqa_decode_ref
from repro.kernels.int8_matmul import (int8_matmul, int8_matmul_ref,
                                       quantize_cols, quantize_rows)
from repro.kernels.paged_gqa_decode import (gather_pages, paged_gqa_decode,
                                            paged_gqa_decode_ref)
from repro.kernels.paged_gqa_verify import (paged_gqa_verify,
                                            paged_gqa_verify_ref)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# --- flash attention ---------------------------------------------------------

@pytest.mark.parametrize("B,H,K,S,T,d", [
    (1, 2, 2, 128, 128, 64),       # MHA
    (2, 4, 1, 128, 256, 64),       # MQA
    (1, 8, 2, 256, 128, 128),      # GQA group 4
    (2, 6, 3, 384, 384, 32),       # non-pow2 heads, small head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, K, S, T, d, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, H, S, d), dtype)
    k = _rand(ks[1], (B, K, T, d), dtype)
    v = _rand(ks[2], (B, K, T, d), dtype)
    out = flash_attention(q, k, v, causal=causal, backend="interpret",
                          block_q=128, block_k=128)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_block_shape_independence():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 2, 256, 64), jnp.float32)
    k = _rand(ks[1], (1, 2, 256, 64), jnp.float32)
    v = _rand(ks[2], (1, 2, 256, 64), jnp.float32)
    o1 = flash_attention(q, k, v, backend="interpret", block_q=64, block_k=128)
    o2 = flash_attention(q, k, v, backend="interpret", block_q=128, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# --- gqa decode ---------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,T,d", [
    (1, 4, 4, 256, 64),
    (2, 8, 2, 512, 64),
    (4, 16, 1, 256, 128),
    (2, 12, 3, 768, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gqa_decode_sweep(B, H, K, T, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = _rand(ks[0], (B, H, d), dtype)
    k = _rand(ks[1], (B, K, T, d), dtype)
    v = _rand(ks[2], (B, K, T, d), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1, jnp.int32)
    out = gqa_decode(q, k, v, lengths, backend="interpret")
    ref = gqa_decode_ref(q, k, v, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_gqa_decode_respects_length_mask():
    """Entries beyond `lengths` must not affect the output."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, H, K, T, d = 1, 4, 2, 256, 64
    q = _rand(ks[0], (B, H, d), jnp.float32)
    k = _rand(ks[1], (B, K, T, d), jnp.float32)
    v = _rand(ks[2], (B, K, T, d), jnp.float32)
    lengths = jnp.array([100], jnp.int32)
    o1 = gqa_decode(q, k, v, lengths, backend="interpret")
    k2 = k.at[:, :, 150:].set(99.0)
    v2 = v.at[:, :, 150:].set(-99.0)
    o2 = gqa_decode(q, k2, v2, lengths, backend="interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


# --- paged gqa decode ---------------------------------------------------------

def _paged_case(seed, B, K, d, ps, P, N, max_len=None):
    """Random pool + ragged shuffled page tables; every slot gets a distinct
    length (first one is a full-page multiple, rest arbitrary — so both a
    partially-filled and an exactly-full last page are exercised)."""
    rng = np.random.default_rng(seed)
    pool_k = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    cap = max_len or P * ps
    lengths = rng.integers(1, cap + 1, B)
    lengths[0] = min(ps * max(1, int(lengths[0]) // ps), cap)  # page multiple
    pt = np.zeros((B, P), np.int64)
    pool_ids = list(range(1, N))
    rng.shuffle(pool_ids)
    for b in range(B):
        npg = -(-int(lengths[b]) // ps)
        pt[b, :npg] = [pool_ids.pop() for _ in range(npg)]
    return pool_k, pool_v, jnp.asarray(pt, jnp.int32), jnp.asarray(
        lengths, jnp.int32)


@pytest.mark.parametrize("B,H,K,d,ps,P,N", [
    (2, 4, 4, 32, 8, 4, 12),       # MHA
    (3, 8, 2, 64, 16, 3, 16),      # GQA group 4
    (2, 8, 1, 64, 8, 6, 16),       # MQA
    (2, 12, 3, 32, 8, 4, 12),      # non-pow2 heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_gqa_decode_sweep(B, H, K, d, ps, P, N, dtype):
    pool_k, pool_v, pt, lengths = _paged_case(10 + B, B, K, d, ps, P, N)
    q = _rand(jax.random.PRNGKey(B), (B, H, d), dtype)
    pool_k, pool_v = pool_k.astype(dtype), pool_v.astype(dtype)
    out = paged_gqa_decode(q, pool_k, pool_v, pt, lengths,
                           backend="interpret")
    ref = paged_gqa_decode_ref(q, pool_k, pool_v, pt, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_paged_ref_matches_dense_oracle():
    """Gathering the pages densely and running the dense GQA decode oracle
    must agree exactly with the paged reference."""
    B, H, K, d, ps, P, N = 3, 8, 2, 32, 8, 5, 24
    pool_k, pool_v, pt, lengths = _paged_case(3, B, K, d, ps, P, N)
    q = _rand(jax.random.PRNGKey(7), (B, H, d), jnp.float32)
    ref = paged_gqa_decode_ref(q, pool_k, pool_v, pt, lengths)
    dense = gqa_decode_ref(q, gather_pages(pool_k, pt),
                           gather_pages(pool_v, pt), lengths)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(dense))


def test_paged_gqa_decode_respects_length_and_table():
    """Pool pages a slot does not own — and the tail of its partially-filled
    last page — must not affect its output."""
    B, H, K, d, ps, P, N = 2, 4, 2, 32, 8, 4, 16
    pool_k, pool_v, pt, lengths = _paged_case(4, B, K, d, ps, P, N,
                                              max_len=P * ps - 3)
    q = _rand(jax.random.PRNGKey(9), (B, H, d), jnp.float32)
    o1 = paged_gqa_decode(q, pool_k, pool_v, pt, lengths,
                          backend="interpret")
    owned = np.unique(np.asarray(pt))
    foreign = [p for p in range(N) if p not in owned]
    pk = pool_k.at[jnp.asarray(foreign)].set(99.0)
    pv = pool_v.at[jnp.asarray(foreign)].set(-99.0)
    # also poison the invalid tail of each slot's last page
    for b in range(B):
        L = int(lengths[b])
        last = int(np.asarray(pt)[b, (L - 1) // ps])
        if L % ps:
            pk = pk.at[last, :, L % ps:].set(77.0)
            pv = pv.at[last, :, L % ps:].set(-77.0)
    o2 = paged_gqa_decode(q, pk, pv, pt, lengths, backend="interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


# --- paged gqa verify ----------------------------------------------------------

def _verify_case(seed, B, K, d, ps, P, N, V):
    """Random pool + ragged base lengths for a V-row speculative window;
    every slot's page table covers base + V rows (the window rows are
    written before verification). First slot's base is a page multiple so
    both an exactly-full and a partially-filled last page are exercised."""
    rng = np.random.default_rng(seed)
    pool_k = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    cap = P * ps - V
    base = rng.integers(1, cap + 1, B)
    base[0] = min(ps * max(1, int(base[0]) // ps), cap)   # page multiple
    pt = np.zeros((B, P), np.int64)
    pool_ids = list(range(1, N))
    rng.shuffle(pool_ids)
    for b in range(B):
        npg = -(-(int(base[b]) + V) // ps)
        pt[b, :npg] = [pool_ids.pop() for _ in range(npg)]
    return pool_k, pool_v, jnp.asarray(pt, jnp.int32), jnp.asarray(
        base, jnp.int32)


@pytest.mark.parametrize("B,H,K,d,ps,P,N,V", [
    (2, 4, 4, 32, 8, 4, 12, 3),    # MHA
    (3, 8, 2, 64, 16, 3, 16, 4),   # GQA group 4
    (2, 8, 1, 64, 8, 6, 16, 2),    # MQA
    (2, 12, 3, 32, 8, 4, 12, 5),   # non-pow2 heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_gqa_verify_sweep(B, H, K, d, ps, P, N, V, dtype):
    pool_k, pool_v, pt, base = _verify_case(20 + B + V, B, K, d, ps, P, N, V)
    q = _rand(jax.random.PRNGKey(B + V), (B, V, H, d), dtype)
    pool_k, pool_v = pool_k.astype(dtype), pool_v.astype(dtype)
    out = paged_gqa_verify(q, pool_k, pool_v, pt, base, backend="interpret")
    ref = paged_gqa_verify_ref(q, pool_k, pool_v, pt, base)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_paged_gqa_verify_rows_match_decode():
    """Row v of the fused verify kernel must equal the decode kernel run at
    that row's causal length base + v + 1 — verification is exactly V fused
    decode calls sharing one pass over the pages."""
    B, H, K, d, ps, P, N, V = 2, 8, 2, 32, 8, 4, 16, 3
    pool_k, pool_v, pt, base = _verify_case(31, B, K, d, ps, P, N, V)
    q = _rand(jax.random.PRNGKey(17), (B, V, H, d), jnp.float32)
    out = paged_gqa_verify(q, pool_k, pool_v, pt, base, backend="interpret")
    for v in range(V):
        row = paged_gqa_decode(q[:, v], pool_k, pool_v, pt, base + v + 1,
                               backend="interpret")
        np.testing.assert_allclose(np.asarray(out[:, v]), np.asarray(row),
                                   atol=1e-6, rtol=1e-6)


def test_paged_gqa_verify_respects_window_and_table():
    """Pool pages a slot does not own — and tokens at or past the widest
    row's horizon base + V, including the partially-filled last page —
    must not affect any window row."""
    B, H, K, d, ps, P, N, V = 2, 4, 2, 32, 8, 4, 16, 3
    pool_k, pool_v, pt, base = _verify_case(43, B, K, d, ps, P, N, V)
    q = _rand(jax.random.PRNGKey(23), (B, V, H, d), jnp.float32)
    o1 = paged_gqa_verify(q, pool_k, pool_v, pt, base, backend="interpret")
    owned = np.unique(np.asarray(pt))
    foreign = [p for p in range(N) if p not in owned]
    pk = pool_k.at[jnp.asarray(foreign)].set(99.0)
    pv = pool_v.at[jnp.asarray(foreign)].set(-99.0)
    # poison everything past each slot's widest horizon base + V
    for b in range(B):
        L = int(base[b]) + V
        last = int(np.asarray(pt)[b, (L - 1) // ps])
        if L % ps:
            pk = pk.at[last, :, L % ps:].set(77.0)
            pv = pv.at[last, :, L % ps:].set(-77.0)
    o2 = paged_gqa_verify(q, pk, pv, pt, base, backend="interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


# --- int8 matmul ---------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [
    (128, 128, 128), (256, 384, 128), (128, 512, 384), (384, 256, 256),
])
def test_int8_matmul_sweep(M, K, N):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(ks[0], (M, K)) * 3.0
    w = jax.random.normal(ks[1], (K, N))
    xq, sx = quantize_rows(x)
    wq, sw = quantize_cols(w)
    out = int8_matmul(xq, wq, sx, sw, backend="interpret")
    ref = int8_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-5)
    # end-to-end quantization error vs fp32 stays small
    full = np.asarray(x @ w)
    rel = np.abs(np.asarray(out) - full).max() / np.abs(full).max()
    assert rel < 0.03


def test_int8_matmul_exact_integers():
    """Integer inputs with unit scales must be exact."""
    rng = np.random.default_rng(0)
    xq = jnp.asarray(rng.integers(-127, 128, (128, 256)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (256, 128)), jnp.int8)
    sx = jnp.ones((128, 1), jnp.float32)
    sw = jnp.ones((1, 128), jnp.float32)
    out = int8_matmul(xq, wq, sx, sw, backend="interpret")
    ref = np.asarray(xq, np.int64) @ np.asarray(wq, np.int64)
    np.testing.assert_array_equal(np.asarray(out, np.int64), ref)


# --- bank energy ----------------------------------------------------------------

@pytest.mark.parametrize("nseg", [17, 256, 1000, 4096])
def test_bank_energy_padding_and_grid(nseg):
    rng = np.random.default_rng(5)
    d = rng.random(nseg).astype(np.float32) * 1e-3
    occ = (rng.random(nseg) * 128 * 2**20).astype(np.float32)
    us, nb, meta = candidate_grid(
        [c * 2**20 for c in (48, 64, 128)], [1, 4, 16], 0.9)
    out_i = np.asarray(bank_activity_stats(d, occ, us, nb,
                                           backend="interpret", block_s=256))
    out_r = np.asarray(bank_activity_stats(d, occ, us, nb, backend="ref"))
    np.testing.assert_allclose(out_i, out_r, rtol=1e-5, atol=1e-4)


def test_bank_energy_float32_range_regression():
    """128 MiB capacity: byte-valued occupancy near 10^8 sits beyond f32's
    exact-integer range, so the old f32 default misread bank boundaries
    (act off by one on a few-byte offset). The auto backend must now be
    exact on CPU (float64 numpy)."""
    from repro.core.banking import bank_activity
    mib = 2**20
    cap, banks, alpha = 128 * mib, 5, 0.9
    usable = alpha * (cap / banks)              # non-power-of-two divisor
    occ = np.floor(np.array([k * usable + off for off in (-3.0, 3.0)
                             for k in range(1, 6)]))
    d = np.ones_like(occ)
    act = bank_activity(occ.astype(np.int64), alpha, cap, banks)
    out = np.asarray(bank_activity_stats(
        d, occ, np.array([usable]), np.array([float(banks)])))
    assert out[0, 0] == pytest.approx(float((act * d).sum()), abs=1e-9)
    assert out[0, 1] == pytest.approx(
        float(np.abs(np.diff(act.astype(np.float64))).sum()), abs=1e-9)


# --- exact idle-run stats (batched Stage-II engine) ----------------------------

def _exact_inputs(nseg, seed=6):
    rng = np.random.default_rng(seed)
    d = rng.random(nseg) * 1e-3 + 1e-6
    occ = (rng.integers(0, 130 * 2**20, nseg) // 1024 * 1024).astype(
        np.float64)
    us, nb, _ = candidate_grid(
        [c * 2**20 for c in (48, 64, 128)], [1, 4, 16, 32], 0.9)
    th = np.tile([1e-4, 5e-4, 1e-3, 2e-3], 3)
    return d, occ, us, nb, th


@pytest.mark.parametrize("nseg", [1, 17, 256, 1000])
def test_exact_bank_stats_kernel_vs_numpy(nseg):
    """Pallas exact-stats kernel (interpret mode, cross-tile carries) vs the
    float64 reference."""
    d, occ, us, nb, th = _exact_inputs(nseg)
    ref = exact_bank_stats_np(d, occ, us, nb, th)
    out = np.asarray(exact_bank_stats(d, occ, us, nb, th,
                                      backend="interpret", block_s=64))
    np.testing.assert_allclose(out, ref, rtol=5e-3, atol=5e-3)


def test_exact_bank_stats_block_shape_independence():
    d, occ, us, nb, th = _exact_inputs(300, seed=7)
    o1 = np.asarray(exact_bank_stats(d, occ, us, nb, th,
                                     backend="interpret", block_s=32))
    o2 = np.asarray(exact_bank_stats(d, occ, us, nb, th,
                                     backend="interpret", block_s=128))
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


def test_exact_bank_stats_jnp_vs_numpy():
    d, occ, us, nb, th = _exact_inputs(500, seed=8)
    ref = exact_bank_stats_np(d, occ, us, nb, th)
    out = np.asarray(exact_bank_stats(d, occ, us, nb, th, backend="ref"))
    np.testing.assert_allclose(out, ref, rtol=5e-3, atol=5e-3)
