"""Continuous batching scheduler + elastic restart."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serve.scheduler import ContinuousBatcher, Request, kv_slot_budget


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_continuous_batching_completes_all(small):
    cfg, m, params = small
    cb = ContinuousBatcher(m, params, num_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size, 8 + i),
                    max_new_tokens=4 + i % 3)
            for i in range(5)]
    for r in reqs:
        cb.submit(r)
    done = cb.run()
    assert len(done) == 5
    assert cb.stats.finished == 5
    assert cb.stats.prefills == 5
    # more requests than slots -> overlapping lifetimes
    assert cb.stats.peak_active_slots == 2
    for r in done:
        assert len(r.output) == r.max_new_tokens
        assert all(0 <= t for t in r.output)


def test_continuous_batching_matches_dedicated_server(small):
    """A request decoded via the slot scheduler must produce the same greedy
    tokens as a single dedicated generate() call."""
    cfg, m, params = small
    from repro.serve import BatchedServer, ServeConfig
    prompt = np.arange(10) % cfg.vocab_size
    srv = BatchedServer(m, params, ServeConfig(max_len=64, max_new_tokens=6))
    ref = srv.generate({"tokens": jnp.asarray(prompt[None, :], jnp.int32)})

    cb = ContinuousBatcher(m, params, num_slots=3, max_len=64)
    cb.submit(Request(rid=0, tokens=prompt, max_new_tokens=6))
    # add competing traffic to prove slot independence
    rng = np.random.default_rng(1)
    for i in range(3):
        cb.submit(Request(rid=i + 1,
                          tokens=rng.integers(0, cfg.vocab_size, 7),
                          max_new_tokens=5))
    done = cb.run()
    mine = next(r for r in done if r.rid == 0)
    np.testing.assert_array_equal(np.asarray(mine.output),
                                  np.asarray(ref["tokens"][0]))


def test_eos_frees_slot_early(small):
    cfg, m, params = small
    cb = ContinuousBatcher(m, params, num_slots=1, max_len=64)
    prompt = np.arange(8) % cfg.vocab_size
    # discover the greedy second token, then use it as "EOS"
    probe = ContinuousBatcher(m, params, num_slots=1, max_len=64)
    probe.submit(Request(rid=0, tokens=prompt, max_new_tokens=3))
    out = probe.run()[0].output
    eos = out[1]
    cb.submit(Request(rid=1, tokens=prompt, max_new_tokens=10, eos_id=eos))
    done = cb.run()
    assert len(done[0].output) <= 2 + 1


def test_kv_slot_budget_gqa_advantage():
    """The serving form of the paper's claim: GQA supports ~H/K more slots."""
    from dataclasses import replace
    gqa = get_arch("dsr1d-qwen-1.5b")                 # H=12, K=2
    mha = replace(gqa, name="tmp", num_kv_heads=gqa.num_heads)
    hbm = 16e9
    n_gqa = kv_slot_budget(gqa, hbm, max_len=32768)
    n_mha = kv_slot_budget(mha, hbm, max_len=32768)
    assert n_gqa > 4 * n_mha
    # attention-free archs: slots bounded only by the small SSM state
    # (75 MB/slot at any context length vs GQA's ~0.94 GB at 32k)
    assert kv_slot_budget(get_arch("mamba2-130m"), hbm, 32768) > 100


def test_elastic_restart_across_device_counts(tmp_path):
    """Checkpoint written under one 'cluster size' restores under another —
    host-sharded leaves are device-count independent by construction."""
    from repro.data import DataConfig, SyntheticTokens
    from repro.optim import AdamW, constant
    from repro.train import LoopConfig, TrainLoop
    from repro.launch.mesh import make_host_mesh

    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=4, seed=5))
    opt = AdamW(lr=constant(1e-3))
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    ckdir = str(tmp_path / "elastic")
    loop = TrainLoop(m, opt, data, LoopConfig(total_steps=6, ckpt_every=3,
                                              ckpt_dir=ckdir))
    out1 = loop.run()
    # "elastic event": a new mesh over whatever devices exist now
    mesh = make_host_mesh()
    assert mesh.size >= 1
    loop2 = TrainLoop(m, opt, data, LoopConfig(total_steps=8, ckpt_every=4,
                                               ckpt_dir=ckdir))
    out2 = loop2.run()
    assert out2["history"][0]["step"] == 6
    assert out2["history"][-1]["step"] == 7
