"""Shared quantization helpers + quantized paged KV cache.

Covers the `kernels.quant` module (round-trip error bounds, requantization
idempotency, fp8 saturating casts and the uint8 code table), the quantized
paged-GQA decode kernel against its mirrored jnp reference, and the serving
regression that matters end to end: an int8 / fp8 `PagedContinuousBatcher`
must reproduce the fp32 batcher's greedy tokens on the reduced configs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.kernels import quant
from repro.models import build_model
from repro.serve import PagedContinuousBatcher, Request
from repro.serve.paged import page_bytes


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    rows_st = hnp.arrays(
        np.float32,
        st.tuples(st.integers(1, 5), st.integers(1, 8), st.integers(1, 16)),
        elements=st.floats(-1e4, 1e4, width=32, allow_nan=False))

    @given(rows_st)
    @settings(max_examples=60, deadline=None)
    def test_roundtrip_error_half_scale(x):
        """Symmetric rounding: |dequant(quant(x)) - x| <= s/2 per element."""
        q, s = quant.quantize_page_rows(jnp.asarray(x))
        err = np.abs(np.asarray(quant.dequantize_page_rows(q, s)) - x)
        assert (err <= np.asarray(s)[..., None] / 2 + 1e-12).all()
        assert q.shape == x.shape and q.dtype == jnp.int8
        assert s.shape == x.shape[:-1] and s.dtype == jnp.float32

    @given(rows_st)
    @settings(max_examples=60, deadline=None)
    def test_scale_floor_and_code_range(x):
        q, s = quant.quantize_page_rows(jnp.asarray(x))
        assert (np.asarray(s) >= quant.SCALE_EPS / quant.INT8_QMAX).all()
        assert np.abs(np.asarray(q, np.int32)).max(initial=0) <= 127

    @given(rows_st)
    @settings(max_examples=60, deadline=None)
    def test_requantization_idempotent(x):
        """The COW rewrite path requantizes rows dequantized from a donor
        page; codes and scales must be bit-stable across that round trip."""
        q1, s1 = quant.quantize_page_rows(jnp.asarray(x))
        q2, s2 = quant.quantize_page_rows(quant.dequantize_page_rows(q1, s1))
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))

    @given(hnp.arrays(np.float32, st.tuples(st.integers(1, 4),
                                            st.integers(1, 32)),
                      elements=st.floats(-1e4, 1e4, width=32,
                                         allow_nan=False)))
    @settings(max_examples=60, deadline=None)
    def test_fp8_roundtrip_monotone_bounded(x):
        """E4M3 round trip: saturating (never NaN), error <= 1/8 relative
        within the finite range (2^-3 mantissa step), codes == values."""
        y = np.asarray(quant.from_fp8(quant.to_fp8_codes(jnp.asarray(x))))
        assert np.isfinite(y).all()
        cl = np.clip(x, -quant.FP8_MAX, quant.FP8_MAX)
        assert (np.abs(y - cl) <= np.abs(cl) / 8 + 2**-10).all()


# ---------------------------------------------------------------------------
# fp8 code table + saturation
# ---------------------------------------------------------------------------

def test_fp8_saturates_instead_of_nan():
    for v in (1000.0, -1000.0, 448.0, -448.0):
        out = float(quant.from_fp8(quant.to_fp8(jnp.float32(v))))
        assert out == np.clip(v, -quant.FP8_MAX, quant.FP8_MAX)


def test_from_fp8_table_matches_astype_all_256_codes():
    """The uint8->f32 lookup table must be bit-identical to the ml_dtypes
    widening convert for every code, NaN patterns included."""
    codes = jnp.arange(256, dtype=jnp.uint8)
    via_table = np.asarray(quant.from_fp8(codes))
    via_astype = np.asarray(
        jax.lax.bitcast_convert_type(codes, quant.FP8_DTYPE).astype(
            jnp.float32))
    np.testing.assert_array_equal(via_table.view(np.uint32),
                                  via_astype.view(np.uint32))


def test_fp8_codes_roundtrip_through_storage_dtype():
    x = jnp.asarray(np.linspace(-500, 500, 97), jnp.float32)
    codes = quant.to_fp8_codes(x)
    assert codes.dtype == quant.FP8_STORAGE_DTYPE
    np.testing.assert_array_equal(
        np.asarray(quant.from_fp8(codes)),
        np.asarray(quant.from_fp8(quant.to_fp8(x))))


# ---------------------------------------------------------------------------
# kv_dtype specs + page accounting
# ---------------------------------------------------------------------------

def test_kv_dtype_specs():
    s = quant.kv_dtype_spec("int8")
    assert (s.itemsize, s.scale_bytes_per_row, s.quantized) == (1, 4, True)
    s = quant.kv_dtype_spec("fp8")
    assert (s.itemsize, s.scale_bytes_per_row) == (1, 0)
    assert s.pool_dtype == quant.FP8_STORAGE_DTYPE
    assert quant.kv_dtype_spec("native", jnp.bfloat16).itemsize == 2
    with pytest.raises(ValueError):
        quant.kv_dtype_spec("int4")
    with pytest.raises(ValueError):
        quant.kv_dtype_spec("native")          # needs the model dtype


def test_page_bytes_ratios():
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    fp32 = page_bytes(cfg, 16, 4, 0)
    int8 = page_bytes(cfg, 16, 1, 4)
    fp8 = page_bytes(cfg, 16, 1, 0)
    assert fp32 == 4 * fp8                     # fp8 is scale-free: exact 4x
    assert fp32 / int8 >= 2.0                  # scales cost < half the win
    assert int8 > fp8                          # the f32 scales are counted


def test_int8_matmul_backcompat_reexports():
    """`kernels.int8_matmul` keeps exporting the quantizers it now shares
    with the KV pools, and they are literally the same functions."""
    from repro.kernels.int8_matmul import quantize_cols, quantize_rows
    assert quantize_rows is quant.quantize_rows
    assert quantize_cols is quant.quantize_cols


# ---------------------------------------------------------------------------
# Quantized paged kernel vs references
# ---------------------------------------------------------------------------

def _ragged_case(rng, B=4, H=8, K=2, d=32, ps=8, P=3, N=12):
    q = jnp.asarray(rng.normal(size=(B, H, d)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, K, ps, d)), jnp.float32)
    lengths = np.array([1, 8, 13, 24], np.int32)[:B]
    pt = np.zeros((B, P), np.int64)
    ids = list(range(1, N))
    rng.shuffle(ids)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // ps)):
            pt[b, j] = ids.pop()
    return q, pk, pv, jnp.asarray(pt, jnp.int32), jnp.asarray(lengths)


def test_quant_kernel_matches_mirror_ref_and_fp32_bound():
    from repro.kernels.paged_gqa_decode import (
        paged_gqa_decode, paged_gqa_decode_quant,
        paged_gqa_decode_quant_mirror_ref, paged_gqa_decode_quant_ref)
    rng = np.random.default_rng(0)
    q, pk, pv, pt, lengths = _ragged_case(rng)
    qk, ks = quant.quantize_page_rows(pk)
    qv, vs = quant.quantize_page_rows(pv)
    out = paged_gqa_decode_quant(q, qk, qv, ks, vs, pt, lengths,
                                 backend="interpret")
    mirror = paged_gqa_decode_quant_mirror_ref(q, qk, qv, ks, vs, pt, lengths)
    fast = paged_gqa_decode_quant_ref(q, qk, qv, ks, vs, pt, lengths)
    fp32 = paged_gqa_decode(q, pk, pv, pt, lengths, backend="interpret")
    assert float(jnp.abs(out - mirror).max()) < 1e-6
    assert float(jnp.abs(out - fast).max()) < 1e-5
    assert float(jnp.abs(out - fp32).max()) < 0.05    # pinned quant error


def test_fp32_kernel_accepts_fp8_code_pools():
    """`paged_gqa_decode` on uint8 E4M3 code pools == the same pools
    decoded to f32 first (ref backend decodes via the lookup table)."""
    from repro.kernels.paged_gqa_decode import paged_gqa_decode
    rng = np.random.default_rng(1)
    q, pk, pv, pt, lengths = _ragged_case(rng)
    ck, cv = quant.to_fp8_codes(pk), quant.to_fp8_codes(pv)
    out = paged_gqa_decode(q, ck, cv, pt, lengths, backend="ref")
    dec = paged_gqa_decode(q, quant.from_fp8(ck), quant.from_fp8(cv), pt,
                           lengths, backend="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(dec), atol=1e-6)


# ---------------------------------------------------------------------------
# Mixed-dtype serving regression
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _run_tokens(m, params, prompts, kv_dtype, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 64)
    kw.setdefault("max_pages_per_slot", 8)
    kw.setdefault("chunk_steps", 4)
    kw.setdefault("attn_backend", "ref")
    cb = PagedContinuousBatcher(m, params, kv_dtype=kv_dtype, **kw)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=8))
    return cb, {r.rid: list(map(int, r.tokens)) for r in cb.run()}


def test_quantized_serving_matches_fp32_greedy(small):
    """The regression that matters: int8 and fp8 batchers reproduce the
    fp32 batcher's greedy tokens exactly on the reduced config (ragged
    lengths, slot reuse across admissions)."""
    cfg, m, params = small
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 17, 9, 26)]
    _, ref = _run_tokens(m, params, prompts, "fp32")
    for dt in ("int8", "fp8"):
        cb, got = _run_tokens(m, params, prompts, dt)
        assert got == ref, f"{dt} greedy tokens diverged from fp32"
        assert cb.ledger.allocator.n_allocated == 0


def test_quantized_prefix_sharing_matches_fp32(small):
    """Shared pages stay quantized through radix reuse + COW splits."""
    cfg, m, params = small
    rng = np.random.default_rng(8)
    base = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    prompts = [np.concatenate([base, rng.integers(0, cfg.vocab_size, j)
                               .astype(np.int32)]) for j in (3, 7, 11)]
    _, ref = _run_tokens(m, params, prompts, "fp32")
    for dt in ("int8", "fp8"):
        cb, got = _run_tokens(m, params, prompts, dt, prefix_cache=True,
                              max_pages_per_slot=12)
        assert got == ref, f"{dt} prefix-sharing tokens diverged from fp32"
        assert cb.stats.prefix_hits > 0


def test_quantized_serving_telemetry(small):
    from repro.obs.telemetry import Telemetry
    cfg, m, params = small
    tel = Telemetry(enabled=True)
    cb, _ = _run_tokens(m, params,
                        [np.arange(12, dtype=np.int32) % cfg.vocab_size],
                        "int8", telemetry=tel)
    assert tel.counter("quant.dequant_pages").value > 0
    phys = tel.gauge("serve.paged.kv_bytes_physical")
    logi = tel.gauge("serve.paged.kv_bytes_logical")
    assert phys.max_value > 0
    assert phys.max_value % cb.page_bytes == 0
    assert logi.max_value >= phys.max_value
