"""Hypothesis property tests for preemptive priority scheduling: random
priority/arrival interleavings through the paged batcher never leak or
double-free pages, and every preempted-and-requeued request still emits the
exact tokens of an uncontended run."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve import PagedContinuousBatcher, Request  # noqa: E402
from repro.serve.scheduler import AdmissionQueue  # noqa: E402

# ---------------------------------------------------------------------------
# AdmissionQueue vs a sorted shadow (host-only, cheap)
# ---------------------------------------------------------------------------

entry_st = st.lists(st.integers(0, 3), min_size=1, max_size=40)


@given(entry_st)
@settings(max_examples=80, deadline=None)
def test_admission_queue_matches_stable_sort(priorities):
    """Pop order == stable sort by descending priority (FIFO in a class)."""
    q = AdmissionQueue()
    reqs = [Request(rid=i, tokens=np.arange(2), priority=p)
            for i, p in enumerate(priorities)]
    for r in reqs:
        q.push(r)
    expect = [r.rid for r in sorted(reqs, key=lambda r: -r.priority)]
    assert [q.pop().rid for _ in range(len(reqs))] == expect


# ---------------------------------------------------------------------------
# Full-batcher preemption safety (model-backed, kept deliberately small:
# three prompt lengths x two budgets bound the prefill trace count)
# ---------------------------------------------------------------------------

_LENS = (6, 10, 14)
_NEWS = (3, 5)
_MODEL = None
_REFS = {}


def _model():
    global _MODEL
    if _MODEL is None:
        import jax
        import jax.numpy as jnp
        from repro.configs import get_arch, reduced
        from repro.models import build_model
        cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
        m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
        _MODEL = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _MODEL


def _prompt(cfg, L):
    return (np.arange(L) * 7 + 3) % cfg.vocab_size


def _batcher(m, params, num_pages):
    return PagedContinuousBatcher(
        m, params, num_slots=2, page_size=8, num_pages=num_pages,
        max_pages_per_slot=8, chunk_steps=2, attn_backend="ref")


def _reference(L, n):
    """Uncontended greedy tokens for the (prompt length, budget) pair."""
    if (L, n) not in _REFS:
        cfg, m, params = _model()
        cb = _batcher(m, params, num_pages=32)
        cb.submit(Request(rid=0, tokens=_prompt(cfg, L), max_new_tokens=n))
        (r,) = cb.run()
        _REFS[(L, n)] = list(r.output)
    return _REFS[(L, n)]


req_st = st.lists(
    st.tuples(st.integers(0, len(_LENS) - 1),    # prompt length pick
              st.integers(0, len(_NEWS) - 1),    # decode budget pick
              st.integers(0, 2)),                # priority class
    min_size=1, max_size=5)
sched_st = st.lists(st.integers(0, 3), max_size=10)


@given(req_st, sched_st)
@settings(max_examples=10, deadline=None)
def test_preemption_never_leaks_and_outputs_stay_exact(picks, schedule):
    """Drive submissions and decode chunks in a random interleaving over a
    pool too small for two worst-case requests (so priority arrivals
    preempt). Invariants: every request finishes, the allocator drains to
    zero (a double free would raise inside PageAllocator), the occupancy
    trace integrates to zero, and each request's tokens — preempted or not
    — are bit-identical to its uncontended run."""
    cfg, m, params = _model()
    reqs = [Request(rid=i, tokens=_prompt(cfg, _LENS[li]),
                    max_new_tokens=_NEWS[ni], priority=p)
            for i, (li, ni, p) in enumerate(picks)]
    expect = {r.rid: _reference(_LENS[li], _NEWS[ni])
              for r, (li, ni, _) in zip(reqs, picks)}

    cb = _batcher(m, params, num_pages=6)        # 5 usable pages: contended
    pending = list(reqs)
    done = []
    for op in schedule:
        if op and pending:
            cb.submit(pending.pop(0))
        elif cb.queue or any(s is not None for s in cb.slots):
            cb._admit(done)
            cb._decode_chunk(done)
    for r in pending:
        cb.submit(r)
    done += cb.run()

    assert len(done) == len(reqs)
    assert cb.ledger.allocator.n_allocated == 0
    assert cb.ledger.allocator.n_free == cb.num_pages - 1
    assert sum(cb.ledger.trace.ev_dneeded) == 0
    assert cb.stats.pages_allocated == cb.stats.pages_freed
    for r in done:
        assert list(r.output) == expect[r.rid], \
            f"rid={r.rid} preemptions={r.preemptions}"
