"""Paged KV-cache serving path: exact ragged-slot decode, device-resident
chunk loop (no per-step recompilation), page-granular Stage-I traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.models.transformer import init_paged_cache
from repro.serve import (BatchedServer, PagedContinuousBatcher, Request,
                         ServeConfig)
from repro.serve import engine as engine_mod
from repro.serve import paged as paged_mod


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _batcher(m, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 32)
    kw.setdefault("max_pages_per_slot", 8)
    kw.setdefault("chunk_steps", 4)
    kw.setdefault("attn_backend", "ref")
    return PagedContinuousBatcher(m, params, **kw)


# ---------------------------------------------------------------------------
# Ragged-slot exactness (the regression the dense batcher's docstring hack
# used to paper over): a mixed-length batch through the shared paged cache
# must reproduce isolated single-sequence greedy decode token-for-token.
# ---------------------------------------------------------------------------

def test_mixed_length_batch_matches_single_sequence_decode(small):
    cfg, m, params = small
    rng = np.random.default_rng(0)
    prompts = [np.arange(10) % cfg.vocab_size,
               rng.integers(0, cfg.vocab_size, 23),
               rng.integers(0, cfg.vocab_size, 5),
               rng.integers(0, cfg.vocab_size, 17),
               rng.integers(0, cfg.vocab_size, 31)]
    new = [6, 9, 4, 12, 7]
    srv = BatchedServer(m, params, ServeConfig(max_len=64))
    refs = [np.asarray(srv.generate(
        {"tokens": jnp.asarray(p[None, :], jnp.int32)},
        max_new_tokens=n)["tokens"][0]) for p, n in zip(prompts, new)]

    cb = _batcher(m, params)
    for i, (p, n) in enumerate(zip(prompts, new)):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=n))
    done = cb.run()
    assert len(done) == 5
    assert cb.stats.peak_active_slots == 2        # overlapping lifetimes
    for r in done:
        np.testing.assert_array_equal(np.asarray(r.output), refs[r.rid])


def test_eos_frees_slot_and_pages_early(small):
    cfg, m, params = small
    prompt = np.arange(8) % cfg.vocab_size
    probe = _batcher(m, params)
    probe.submit(Request(rid=0, tokens=prompt, max_new_tokens=3))
    eos = probe.run()[0].output[1]
    cb = _batcher(m, params, num_slots=1)
    cb.submit(Request(rid=1, tokens=prompt, max_new_tokens=10, eos_id=eos))
    done = cb.run()
    assert len(done[0].output) <= 3
    assert cb.ledger.allocator.n_allocated == 0
    assert cb.stats.pages_freed == cb.stats.pages_allocated > 0


def test_moe_arch_through_paged_batcher():
    cfg = reduced(get_arch("olmoe-1b-7b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(1))
    cb = _batcher(m, params)
    cb.submit(Request(rid=0, tokens=np.arange(9) % cfg.vocab_size,
                      max_new_tokens=5))
    done = cb.run()
    assert len(done) == 1 and len(done[0].output) == 5


def test_window_bounded_archs_rejected():
    cfg = reduced(get_arch("recurrentgemma-2b"))
    with pytest.raises(NotImplementedError):
        init_paged_cache(cfg, 2, 8, 8, 4)


# ---------------------------------------------------------------------------
# Compile discipline: the chunk loop and the BatchedServer scan compile once
# ---------------------------------------------------------------------------

def test_chunk_loop_compiles_once_across_chunks_and_admissions(small):
    cfg, m, params = small
    cb = _batcher(m, params)
    rng = np.random.default_rng(2)
    for i in range(6):
        cb.submit(Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, 6 + i),
                          max_new_tokens=5 + i % 4))
    n0 = paged_mod.loop_compile_count()
    done = cb.run()
    assert len(done) == 6
    assert cb.stats.chunks > 2                  # several host round-trips...
    assert paged_mod.loop_compile_count() - n0 == 1   # ...one compilation


def test_generate_loop_compiles_once_across_calls(small):
    cfg, m, params = small
    srv = BatchedServer(m, params, ServeConfig(max_len=64, max_new_tokens=8))
    batch = {"tokens": jnp.asarray(
        (np.arange(20) % cfg.vocab_size).reshape(2, 10), jnp.int32)}
    srv.generate(batch)
    n0 = engine_mod.loop_compile_count()
    o1 = srv.generate(batch)
    o2 = srv.generate(batch)
    assert engine_mod.loop_compile_count() == n0   # no per-call re-trace
    np.testing.assert_array_equal(o1["tokens"], o2["tokens"])


# ---------------------------------------------------------------------------
# Page-granular Stage-I artifact
# ---------------------------------------------------------------------------

def test_trace_is_page_granular_and_feeds_stage2(small):
    cfg, m, params = small
    cb = _batcher(m, params)
    rng = np.random.default_rng(3)
    for i in range(5):
        cb.submit(Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, 5 + 3 * i),
                          max_new_tokens=4 + i))
    cb.run()
    bundle = cb.occupancy_bundle()
    tr = bundle.traces["kv"]
    t, n, o = tr.as_arrays()
    # every level is an integer number of pages; drained at the end
    assert (np.asarray(n) % cb.page_bytes == 0).all()
    assert int(n[-1]) == 0
    assert sum(tr.ev_dneeded) == 0
    assert tr.peak_needed() == cb.stats.peak_pages * cb.page_bytes
    assert tr.peak_total() <= tr.capacity
    # Stage-II consumes the bundle unchanged
    from repro.core.explorer import sweep
    tbl = sweep(bundle, mem_name="kv", capacities_mib=[16], banks=[1, 4])
    assert len(tbl.rows) == 2
    assert tbl.best().result.e_total > 0


def test_admission_time_retirement_does_not_poison_next_chunk(small):
    """A request satisfied by its prefill token (max_new_tokens=1) retires
    host-side before any chunk runs; its slot's device state must not leak
    into the neighbouring slot's decode (the liveness mask is pushed from
    the host before every chunk)."""
    cfg, m, params = small
    p1 = np.arange(10) % cfg.vocab_size
    p2 = (np.arange(14) * 3) % cfg.vocab_size
    srv = BatchedServer(m, params, ServeConfig(max_len=64))
    ref = np.asarray(srv.generate(
        {"tokens": jnp.asarray(p2[None, :], jnp.int32)},
        max_new_tokens=7)["tokens"][0])
    cb = _batcher(m, params)
    cb.submit(Request(rid=0, tokens=p1, max_new_tokens=1))
    cb.submit(Request(rid=1, tokens=p2, max_new_tokens=7))
    done = cb.run()
    assert len(done) == 2
    assert len(next(r for r in done if r.rid == 0).output) == 1
    np.testing.assert_array_equal(
        np.asarray(next(r for r in done if r.rid == 1).output), ref)
    assert cb.ledger.allocator.n_allocated == 0


def test_admission_blocks_until_pages_available(small):
    """FCFS backpressure: a pool too small for two concurrent requests must
    serialize them rather than fail mid-stream."""
    cfg, m, params = small
    cb = _batcher(m, params, num_slots=2, num_pages=7, max_pages_per_slot=6,
                  page_size=8)
    # each request worst-cases at 5 pages (33 tokens prompt + 7 new)
    for i in range(2):
        cb.submit(Request(rid=i, tokens=np.arange(33) % cfg.vocab_size,
                          max_new_tokens=8))
    done = cb.run()
    assert len(done) == 2
    assert cb.stats.peak_active_slots == 1
    assert cb.ledger.allocator.n_allocated == 0
