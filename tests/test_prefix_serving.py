"""Prefix-sharing serving path: bit-exact shared-prefix decode, COW splits,
LRU eviction under pressure, dual logical/physical Stage-I traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serve import (BatchedServer, PagedContinuousBatcher, Request,
                         ServeConfig)
from repro.serve import paged as paged_mod


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _batcher(m, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 64)
    kw.setdefault("max_pages_per_slot", 8)
    kw.setdefault("chunk_steps", 4)
    kw.setdefault("attn_backend", "ref")
    kw.setdefault("prefix_cache", True)
    return PagedContinuousBatcher(m, params, **kw)


def _shared_prompts(cfg, seed=0):
    """Ragged batch: three prompts sharing a 21-token prefix (mid-page for
    page_size=8) plus one unshared prompt — the ragged-slot harness of
    test_paged_serving, with sharing structure."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, 21)
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, k)])
               for k in (9, 5, 13)]
    prompts.append(rng.integers(0, cfg.vocab_size, 11))
    return prompts, [7, 9, 6, 8]


# ---------------------------------------------------------------------------
# Exactness regression: a batch with shared prefixes is bit-identical to the
# same requests decoded in isolation with no sharing
# ---------------------------------------------------------------------------

def test_shared_prefix_batch_is_bit_identical_to_isolated_decode(small):
    cfg, m, params = small
    prompts, new = _shared_prompts(cfg)

    # isolation = a fresh batcher per request: the index is empty, so no
    # sharing can occur, but the arithmetic (fixed-width suffix prefill,
    # paged decode) is identical — the clean no-sharing reference
    iso = []
    for p, n in zip(prompts, new):
        b = _batcher(m, params, collect_logits=True)
        b.submit(Request(rid=0, tokens=p, max_new_tokens=n))
        (r,) = b.run()
        assert b.stats.prefix_hits == 0
        iso.append(r)

    cb = _batcher(m, params, collect_logits=True)
    for i, (p, n) in enumerate(zip(prompts, new)):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=n))
    done = cb.run()
    assert len(done) == 4
    assert cb.stats.prefix_hits == 2              # two later shared prompts
    assert cb.stats.prefix_tokens_reused > 0
    for r in done:
        ref = iso[r.rid]
        np.testing.assert_array_equal(np.asarray(r.output),
                                      np.asarray(ref.output))
        np.testing.assert_array_equal(np.stack(r.logits),
                                      np.stack(ref.logits))


def test_shared_prefix_tokens_match_dense_reference(small):
    """Greedy tokens also agree with the dense BatchedServer harness (the
    PR-4 ragged-slot reference)."""
    cfg, m, params = small
    prompts, new = _shared_prompts(cfg)
    srv = BatchedServer(m, params, ServeConfig(max_len=64))
    refs = [np.asarray(srv.generate(
        {"tokens": jnp.asarray(p[None, :], jnp.int32)},
        max_new_tokens=n)["tokens"][0]) for p, n in zip(prompts, new)]
    cb = _batcher(m, params)
    for i, (p, n) in enumerate(zip(prompts, new)):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=n))
    done = cb.run()
    for r in done:
        np.testing.assert_array_equal(np.asarray(r.output), refs[r.rid])


# ---------------------------------------------------------------------------
# Sharing mechanics
# ---------------------------------------------------------------------------

def test_identical_prompts_share_pages_and_cow_split(small):
    """Two identical prompts: the second reuses the cached run page-for-page
    (suffix = 1 recomputed token + COW of the boundary page), and physical
    occupancy stays below logical."""
    cfg, m, params = small
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 30)   # mid-page boundary
    cb = _batcher(m, params)
    for i in range(2):
        cb.submit(Request(rid=i, tokens=prompt, max_new_tokens=6))
    done = cb.run()
    assert len(done) == 2
    np.testing.assert_array_equal(done[0].output, done[1].output)
    assert cb.stats.prefix_hits == 1
    # match is page-granular: 3 full pages of the 30-token prompt, plus the
    # 5 valid rows of the cached partial page (29 of 30 tokens reused)
    assert cb.stats.prefix_tokens_reused == 29
    assert cb.stats.cow_splits >= 1
    bundle = cb.occupancy_bundle()
    phys, logi = bundle.traces["kv"], bundle.traces["kv_logical"]
    assert phys.peak_needed() < logi.peak_needed()
    assert phys.peak_needed() % cb.page_bytes == 0


def test_retired_run_stays_cached_and_hits_later(small):
    """The cache outlives the request: occupancy flips to obsolete at
    retirement, and a later identical prompt still hits."""
    cfg, m, params = small
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 24)
    cb = _batcher(m, params, num_slots=1)
    cb.submit(Request(rid=0, tokens=prompt, max_new_tokens=4))
    cb.run()
    t, n, o = cb.ledger.trace.as_arrays()
    assert int(n[-1]) == 0                         # no slot references...
    assert int(o[-1]) > 0                          # ...but the cache holds
    cb.submit(Request(rid=1, tokens=prompt, max_new_tokens=4))
    (r1,) = cb.run()
    assert cb.stats.prefix_hits == 1
    assert cb.stats.prefix_tokens_reused == 23     # 24-token prompt, S-1 cap


def test_eviction_under_page_pressure(small):
    """Distinct prompts through a pool that cannot hold every cached run:
    LRU leaves are evicted, requests still complete, nothing referenced is
    freed (the run would crash on a corrupted table otherwise)."""
    cfg, m, params = small
    cb = _batcher(m, params, num_slots=1, num_pages=12, max_pages_per_slot=8)
    rng = np.random.default_rng(3)
    for i in range(5):
        cb.submit(Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, 25),
                          max_new_tokens=5))
    done = cb.run()
    assert len(done) == 5
    assert cb.stats.evicted_pages > 0
    assert cb.ledger.allocator.n_allocated <= cb.num_pages - 1


def test_prefix_trace_feeds_stage2_unchanged(small):
    """The physical-occupancy TraceBundle is consumed by the Stage-II sweep
    with no adaptation; the logical trace rides along."""
    cfg, m, params = small
    prompts, new = _shared_prompts(cfg, seed=4)
    cb = _batcher(m, params)
    for i, (p, n) in enumerate(zip(prompts, new)):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=n))
    cb.run()
    bundle = cb.occupancy_bundle()
    from repro.core.explorer import sweep
    tbl = sweep(bundle, mem_name="kv", capacities_mib=[16], banks=[1, 4])
    assert len(tbl.rows) == 2
    assert tbl.best().result.e_total > 0
    # integrals: physical needed <= logical everywhere
    phys = bundle.traces["kv"].time_integral(bundle.total_time, use="needed")
    logi = bundle.traces["kv_logical"].time_integral(bundle.total_time,
                                                     use="needed")
    assert phys <= logi


def test_chunk_loop_still_compiles_once_with_prefix_cache(small):
    cfg, m, params = small
    cb = _batcher(m, params)
    prompts, new = _shared_prompts(cfg, seed=5)
    for i, (p, n) in enumerate(zip(prompts, new)):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=n))
    n0 = paged_mod.loop_compile_count()
    done = cb.run()
    assert len(done) == 4
    assert cb.stats.chunks > 1
    assert paged_mod.loop_compile_count() - n0 == 1


def test_prefix_cache_rejects_non_full_stacks():
    cfg = reduced(get_arch("recurrentgemma-2b"))
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    with pytest.raises(NotImplementedError):
        PagedContinuousBatcher(m, None, prefix_cache=True)
