"""Hypothesis property tests on the prefix-sharing allocator, radix index
and dual-trace ledger (the host half of the prefix-reuse subsystem),
mirroring test_paged_alloc_props.py."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.paged import OutOfPages, pages_for  # noqa: E402
from repro.serve.prefix import (RadixPrefixIndex,  # noqa: E402
                                SharedKVLedger, SharedPageAllocator)

PAGE_BYTES = 4096
PS = 4                                   # page size [tokens] for index tests


# ---------------------------------------------------------------------------
# SharedPageAllocator: refcounts vs a shadow reference count
# ---------------------------------------------------------------------------

# op stream: +n = alloc n, 0 = retain a random live page, -k = release from
# a random live handle batch
ops_st = st.lists(st.integers(-6, 6), min_size=1, max_size=100)


@given(st.integers(2, 48), ops_st, st.randoms(use_true_random=False))
@settings(max_examples=80, deadline=None)
def test_shared_allocator_refcounts(num_pages, ops, rnd):
    """refcount == number of live references taken through the API; pages
    free exactly when the last reference drops; pool conservation (free +
    allocated == num_pages - 1) holds after every op."""
    a = SharedPageAllocator(num_pages)
    shadow = {}                           # page -> reference count
    for op in ops:
        live = sorted(shadow)
        if op > 0:
            try:
                pages = a.alloc(op)
            except OutOfPages:
                assert op > a.n_free
                continue
            for p in pages:
                assert p not in shadow          # no double allocation
                assert p != 0                   # null page reserved
                shadow[p] = 1
        elif op == 0 and live:
            p = live[rnd.randrange(len(live))]
            a.retain([p])
            shadow[p] += 1
        elif op < 0 and live:
            p = live[rnd.randrange(len(live))]
            k = min(-op, shadow[p])
            freed = a.release([p] * k)
            shadow[p] -= k
            if shadow[p] == 0:
                assert freed == [p]             # freed at zero refs...
                del shadow[p]
            else:
                assert freed == []              # ...and only at zero
        for p, c in shadow.items():
            assert a.refcount(p) == c
        assert a.n_allocated == len(shadow)
        assert a.n_free + a.n_allocated == num_pages - 1
    # full drain restores the pool
    for p, c in list(shadow.items()):
        a.release([p] * c)
    assert a.n_allocated == 0 and a.n_free == num_pages - 1


def test_shared_allocator_rejects_foreign_retain_release():
    a = SharedPageAllocator(8)
    pages = a.alloc(2)
    with pytest.raises(ValueError):
        a.retain([0])
    with pytest.raises(ValueError):
        a.release([7 if 7 not in pages else 6])
    a.release(pages)
    with pytest.raises(ValueError):
        a.release(pages)


# ---------------------------------------------------------------------------
# RadixPrefixIndex: token-exact cache contents + page-granular matching
# ---------------------------------------------------------------------------

runs_st = st.lists(
    st.lists(st.integers(0, 3), min_size=1, max_size=4 * PS + 3),
    min_size=1, max_size=12)


def _fresh_index(num_pages=256):
    alloc = SharedPageAllocator(num_pages)
    return RadixPrefixIndex(PS, alloc), alloc


@given(runs_st)
@settings(max_examples=60, deadline=None)
def test_index_preserves_inserted_token_runs(token_runs):
    """Every root-to-leaf path of the index spells a prefix of some inserted
    run (token-exact cache contents), and probing an inserted run matches
    it back page-for-page."""
    idx, alloc = _fresh_index()
    inserted = []
    for toks in token_runs:
        toks = np.asarray(toks)
        pages = alloc.alloc(pages_for(len(toks), PS))
        idx.insert(toks, pages)
        inserted.append([int(t) for t in toks])
    for path in idx.runs():
        assert any(run[:len(path)] == path for run in inserted), \
            (path, inserted)
    for run in inserted:
        m = idx.probe(np.asarray(run))
        matched = m.tokens(PS)
        # the full run (or a sibling sharing its full length) is cached
        assert matched == len(run)


@given(runs_st, st.integers(0, 5))
@settings(max_examples=60, deadline=None)
def test_index_probe_is_longest_common_prefix(token_runs, salt):
    """probe() == page-granular longest common prefix against the best
    inserted run, never exceeding the probe limit."""
    idx, alloc = _fresh_index()
    inserted = []
    for toks in token_runs[:-1]:
        toks = np.asarray(toks)
        idx.insert(toks, alloc.alloc(pages_for(len(toks), PS)))
        inserted.append([int(t) for t in toks])
    q = token_runs[-1] + [salt]
    limit = max(len(q) - 1, 0)
    m = idx.probe(np.asarray(q), limit=limit)
    got = m.tokens(PS)
    best = max((len(_lcp(run, q[:limit])) for run in inserted), default=0)
    # full pages always match; the tail only when the boundary page exists
    assert (best // PS) * PS <= got <= best
    assert got <= limit


def _lcp(a, b):
    out = []
    for x, y in zip(a, b):
        if x != y:
            break
        out.append(x)
    return out


# ---------------------------------------------------------------------------
# SharedKVLedger: dual-trace invariants under random request streams
# ---------------------------------------------------------------------------

stream_st = st.lists(
    st.tuples(st.integers(0, 3),                  # slot id
              st.integers(1, 3 * PS + 2),         # prompt length [tokens]
              st.integers(0, 2),                  # which shared vocabulary
              st.integers(0, 2 * PS)),            # decode tokens
    min_size=1, max_size=24)


@given(stream_st)
@settings(max_examples=50, deadline=None)
def test_ledger_dual_trace_invariants(stream):
    """Drive admission/decode/COW/retire through the ledger the way the
    batcher does. At every step: physical needed <= logical, needed ==
    unique slot-referenced pages, refcounts == table references + index
    references, and everything drains when the last slot retires."""
    led = SharedKVLedger(512, PAGE_BYTES, PS, num_slots=4,
                        max_pages_per_slot=8)
    t = 0.0
    live = {}                                     # slot -> ctx
    for slot, plen, vocab, dec in stream:
        t += 1.0
        if slot in live:
            led.retire(slot, t)
            del live[slot]
            continue
        toks = np.asarray([vocab] * plen)         # heavy sharing by design
        match = led.index.probe(toks, limit=plen - 1)
        fresh_n = pages_for(plen, PS) - len(match.pages)
        led.admit(slot, fresh_n, t, shared=match.pages)
        led.insert_run(toks, led.slot_pages[slot], t)
        ctx = plen
        for _ in range(dec):
            t += 0.1
            idx = ctx // PS
            pages = led.slot_pages[slot]
            if idx < len(pages):
                if led.allocator.refcount(pages[idx]) > 1:
                    led.cow(slot, idx, t)
            else:
                led.grow(slot, idx + 1, t)
            ctx += 1
        live[slot] = ctx
        _check_ledger(led)
    for slot in list(live):
        t += 1.0
        led.retire(slot, t)
    _check_ledger(led)
    # logical drains to zero; physical needed too (cache may stay obsolete)
    _, n, o = led.trace.as_arrays()
    _, ln, _ = led.logical.as_arrays()
    assert int(n[-1]) == 0
    assert int(ln[-1]) == 0
    assert int(o[-1]) == led.allocator.n_allocated * PAGE_BYTES


def _check_ledger(led):
    sref = set()
    logical = 0
    table_refs = {}
    for pages in led.slot_pages.values():
        sref.update(pages)
        logical += len(pages)
        for p in pages:
            table_refs[p] = table_refs.get(p, 0) + 1
    index_pages = led.index.pages()
    for p in set(list(table_refs) + index_pages):
        assert led.allocator.refcount(p) == \
            table_refs.get(p, 0) + index_pages.count(p), p
    t, n, o = led.trace.as_arrays()
    _, ln, _ = led.logical.as_arrays()
    phys_needed = int(n[-1]) if len(n) else 0
    assert phys_needed == len(sref) * PAGE_BYTES
    assert phys_needed <= (int(ln[-1]) if len(ln) else 0)
    assert (int(ln[-1]) if len(ln) else 0) == logical * PAGE_BYTES
    assert (int(o[-1]) if len(o) else 0) == \
        (led.allocator.n_allocated - len(sref)) * PAGE_BYTES
    assert phys_needed % PAGE_BYTES == 0


@given(stream_st, st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_eviction_never_frees_referenced_pages(stream, want):
    """LRU eviction frees only index-exclusive pages: every page a slot
    references survives, and the freed count never exceeds the cache-only
    population."""
    led = SharedKVLedger(512, PAGE_BYTES, PS, num_slots=4,
                        max_pages_per_slot=8)
    t = 0.0
    for slot, plen, vocab, _ in stream:
        t += 1.0
        if slot in led.slot_pages:
            led.retire(slot, t)
            continue
        toks = np.asarray([vocab] * plen)
        match = led.index.probe(toks, limit=plen - 1)
        led.admit(slot, pages_for(plen, PS) - len(match.pages), t,
                  shared=match.pages)
        led.insert_run(toks, led.slot_pages[slot], t)
    slot_refs = set()
    for pages in led.slot_pages.values():
        slot_refs.update(pages)
    cache_only = led.allocator.n_allocated - len(slot_refs)
    freed = led.evict_for(want, t + 1.0)
    assert freed <= cache_only
    for pages in led.slot_pages.values():
        for p in pages:
            assert led.allocator.refcount(p) >= 1   # still allocated
    _check_ledger(led)


def test_cow_requires_shared_page_and_preserves_cache():
    """A COW split leaves the original page cached (token-exact for future
    probes) while the slot gets a private copy."""
    led = SharedKVLedger(64, PAGE_BYTES, PS, num_slots=2,
                        max_pages_per_slot=8)
    toks = np.asarray([7] * (PS + 2))              # partial last page
    m0 = led.index.probe(toks, limit=len(toks) - 1)
    led.admit(0, pages_for(len(toks), PS), 1.0, shared=m0.pages)
    led.insert_run(toks, led.slot_pages[0], 1.0)
    boundary = led.slot_pages[0][1]
    assert led.allocator.refcount(boundary) == 2   # slot + index
    new = led.cow(0, 1, 2.0)
    assert new != boundary
    assert led.allocator.refcount(boundary) == 1   # index keeps original
    assert led.allocator.refcount(new) == 1
    # the cached run still probes back token-exact
    m1 = led.index.probe(toks, limit=len(toks) - 1)
    assert m1.tokens(PS) == len(toks) - 1
    assert m1.pages == [led.slot_pages[0][0]]
    assert m1.tail_page == boundary
    with pytest.raises(ValueError):
        led.cow(0, 1, 3.0)                         # now private: no COW
