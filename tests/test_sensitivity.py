"""Drowsy retention + policy sensitivity (paper Sec. V future work)."""
import numpy as np
import pytest

from repro.core.gating import Policy, evaluate
from repro.core.sensitivity import (DROWSY_LEAK_FRACTION, evaluate_drowsy,
                                    policy_sensitivity)

MIB = 2**20


def _trace():
    d = np.array([1e-3, 1e-3] * 16)
    occ = np.array([100 * MIB, 1 * MIB] * 16, np.int64)
    return d, occ


def test_drowsy_bounded_by_on_and_off():
    d, occ = _trace()
    kw = dict(capacity=128 * MIB, banks=8, n_reads=100, n_writes=100)
    off_only = evaluate(d, occ, policy=Policy.aggressive(), **kw)
    none = evaluate(d, occ, policy=Policy.none(), **kw)
    # with a conservative off-threshold that forbids gating, drowsy must land
    # between always-on and off-only
    dr = evaluate_drowsy(d, occ, capacity=128 * MIB, banks=8,
                         n_reads=100, n_writes=100, off_multiple=1e9)
    assert off_only.e_total <= dr.e_total <= none.e_total
    assert dr.n_off == 0 and dr.n_drowsy > 0
    # drowsy leakage is the retention fraction of the idle leakage
    idle_leak_full = none.e_leak - (
        evaluate(d, occ, policy=Policy.aggressive(), **kw).e_leak)
    assert dr.e_leak_drowsy == pytest.approx(
        idle_leak_full * DROWSY_LEAK_FRACTION, rel=0.35)


def test_drowsy_prefers_off_for_long_idles():
    d, occ = _trace()
    dr = evaluate_drowsy(d, occ, capacity=128 * MIB, banks=8,
                         n_reads=0, n_writes=0, off_multiple=1.0)
    assert dr.n_off > 0
    assert dr.e_leak_drowsy == 0.0 or dr.n_drowsy >= 0


def test_sensitivity_monotone_in_threshold():
    d, occ = _trace()
    sens = policy_sensitivity(d, occ, capacity=128 * MIB, banks=8,
                              n_reads=100, n_writes=100)
    th = list(sens["threshold"].values())
    assert all(b >= a - 1e-12 for a, b in zip(th, th[1:]))   # monotone up
    sw = sens["sw_scale"]
    assert sw[100.0] >= sw[0.1]
    # drowsy degrades more slowly than off-only as the threshold grows
    assert sens["drowsy"][1e5] < sens["threshold"][1e5]
