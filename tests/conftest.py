import os

# keep tests on 1 CPU device; the dry-run (and only it) uses 512
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
