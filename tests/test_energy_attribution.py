"""Streaming BankEnergyMeter invariants (ISSUE 10).

The hard guarantees, pinned both by always-on seeded-random tests and by
hypothesis props (skipped when hypothesis is unavailable, same convention
as test_trace_props.py):

  * exactness — `meter.finalize()` is bit-identical (f64 `==`, not
    isclose) to the offline scalar reference `gating.evaluate` on the
    identical trace, across all four policies, including traces with
    duplicate timestamps and out-of-order delivery;
  * structure — the online machine's per-segment activity equals
    `gating.bank_timeline`'s and its transition count the reference's;
  * conservation — per-request charges plus the explicit floor equal the
    live total (the floor is accumulated independently, not as the
    remainder, so this genuinely cross-checks the split);
  * monotone non-negative charges;
  * permutation invariance — reordering event delivery within a fixed
    trace changes nothing.
"""
import numpy as np
import pytest

from repro.core.cacti import characterize
from repro.core.gating import Policy, bank_timeline, evaluate
from repro.obs.energy import BankEnergyMeter
from repro.sim.trace import OccupancyTrace

MIB = 2**20
POLICIES = ("none", "aggressive", "conservative", "drowsy")


def _random_events(rng, n, capacity):
    """Tagged (t, dn, do, rid, tenant) stream with duplicate timestamps
    and both growth and frees, occupancy kept within [0, capacity]."""
    ts = np.sort(rng.uniform(0.0, 2.0, n))
    for i in range(1, n, 5):              # force duplicate timestamps
        ts[i] = ts[i - 1]
    evs, occ = [], 0
    for i in range(n):
        if occ and rng.random() < 0.35:
            dn = -int(rng.integers(1, occ + 1))
        else:
            dn = int(rng.integers(0, max((capacity - occ) // 3, 2)))
        do = int(rng.integers(0, 4096))
        occ += dn
        evs.append((float(ts[i]), dn, do, f"r{i % 4}", f"tenant{i % 2}"))
    return evs


def _feed(meter, evs, *, order=None):
    idx = range(len(evs)) if order is None else order
    for i in idx:
        t, dn, do, rid, ten = evs[i]
        meter.record(t, dn, do, rid=rid, tenant=ten, cause="admission")


def _reference(evs, end, capacity, banks, policy):
    tr = OccupancyTrace("kv", capacity)
    for t, dn, do, _, _ in evs:
        tr.event(t, dn, do)
    dur, occ = tr.occupancy_series(end, use="needed")
    return dur, occ, evaluate(dur, occ, capacity=capacity, banks=banks,
                              policy=policy, n_reads=0, n_writes=0)


@pytest.mark.parametrize("policy", POLICIES)
def test_streaming_bit_identical_to_offline(policy):
    rng = np.random.default_rng(hash(policy) % 2**32)
    for trial in range(25):
        C = int(rng.choice([MIB, 4 * MIB, 8 * MIB]))
        B = int(rng.choice([2, 4, 8, 16]))
        pol = Policy.by_name(policy)
        evs = _random_events(rng, int(rng.integers(3, 60)), C)
        m = BankEnergyMeter(C, B, policy=pol)
        _feed(m, evs)
        end = evs[-1][0] + float(rng.uniform(0.0, 0.5))
        dur, occ, ref = _reference(evs, end, C, B, pol)
        got = m.finalize(end)
        # bit-identical f64, not isclose
        assert got.e_leak == ref.e_leak
        assert got.e_sw == ref.e_sw
        assert got.e_total == ref.e_total
        assert got.n_transitions == ref.n_transitions
        assert got.gated_bank_seconds == ref.gated_bank_seconds
        assert got.drowsy_bank_seconds == ref.drowsy_bank_seconds
        if pol.gate:
            t0s, d2, act = m.activity_series(end)
            tl = bank_timeline(dur, occ, capacity=C, banks=B,
                               alpha=pol.alpha)
            assert np.array_equal(d2, dur)
            assert np.array_equal(act, tl["active_banks"])
        # live sequential accumulation matches to float roundoff and its
        # discrete pieces exactly
        live = m.energy_j(end)
        assert np.isclose(live, ref.e_leak + ref.e_sw, rtol=1e-9, atol=0)


@pytest.mark.parametrize("policy", POLICIES)
def test_attribution_conservation_and_monotonicity(policy):
    rng = np.random.default_rng(42)
    for trial in range(15):
        C, B = 4 * MIB, 8
        evs = _random_events(rng, 40, C)
        m = BankEnergyMeter(C, B, policy=policy)
        prev = {}
        for k, (t, dn, do, rid, ten) in enumerate(evs):
            m.record(t, dn, do, rid=rid, tenant=ten, cause="decode_growth")
            if k % 10 == 9:               # watermark: charges only grow
                cur = m.request_energy_j(t)
                for r, j in cur.items():
                    assert j >= 0.0
                    assert j >= prev.get(r, 0.0) - 1e-18
                prev = cur
        end = evs[-1][0] + 0.25
        live = m.energy_j(end)
        req = m.request_energy_j(end)
        floor = m.floor_j(end)
        # conservation: per-request + floor == live total (floor is not a
        # remainder — it is accumulated charge-by-charge)
        assert np.isclose(sum(req.values()) + floor, live,
                          rtol=1e-9, atol=1e-18)
        # tenants partition the per-request charges
        ten = m.tenant_energy_j(end)
        assert np.isclose(sum(ten.values()), sum(req.values()),
                          rtol=1e-9, atol=1e-18)


def test_permutation_invariance_of_totals():
    rng = np.random.default_rng(3)
    C, B = 4 * MIB, 8
    evs = _random_events(rng, 30, C)
    end = evs[-1][0] + 0.1
    for policy in POLICIES:
        base = BankEnergyMeter(C, B, policy=policy)
        _feed(base, evs)
        ref = base.finalize(end)
        want = (base.energy_j(end), base.request_energy_j(end),
                base.floor_j(end))
        for _ in range(3):
            m = BankEnergyMeter(C, B, policy=policy)
            _feed(m, evs, order=rng.permutation(len(evs)))
            got = m.finalize(end)
            assert (got.e_leak, got.e_sw, got.n_transitions) == \
                   (ref.e_leak, ref.e_sw, ref.n_transitions)
            assert np.isclose(m.energy_j(end), want[0], rtol=1e-9)
            for r, j in m.request_energy_j(end).items():
                assert np.isclose(j, want[1][r], rtol=1e-9)
            assert np.isclose(m.floor_j(end), want[2], rtol=1e-9)


def test_wake_causes_and_stall_windows():
    # a square wave with gaps long past break-even: every rise is a wake
    C, B = 8 * MIB, 8
    ch = characterize(C, B)
    gap = 10.0 * ch.break_even_s + 1.0
    m = BankEnergyMeter(C, B, policy="aggressive")
    t, wakes = 0.0, 0
    causes = ["admission", "decode_growth", "cow", "spec_rollback"]
    for k, cause in enumerate(causes):
        m.record(t, 6 * MIB, 0, rid=f"r{k}", tenant="t0", cause=cause)
        t += 0.5
        m.record(t, -6 * MIB, 0, rid=f"r{k}", cause=None)
        t += gap
        wakes += 1
    end = t
    w = m.wake_counts(end)
    # the first rise comes out of the initial all-on state: no wake; every
    # later rise re-wakes gated banks under its recorded cause
    assert sum(w.values()) >= len(causes) - 1
    for cause in causes[1:]:
        assert w.get(cause, 0) >= 1
    assert m.stall_s(end) > 0.0
    m.note_prewake()
    assert m.wake_counts(end).get("prewake") == 1
    # exactness still holds on this synthetic trace
    res = m.finalize(end)
    assert res.n_transitions > 0


def test_zero_delta_weight_events_do_not_perturb_energy():
    # holdings-only updates (fully shared admits) must not split segments
    C, B = 4 * MIB, 4
    m1 = BankEnergyMeter(C, B, policy="conservative")
    m2 = BankEnergyMeter(C, B, policy="conservative")
    ev = [(0.0, MIB), (1.0, MIB), (2.0, -2 * MIB)]
    for t, dn in ev:
        m1.record(t, dn, 0, rid="a", tenant="t")
        m2.record(t, dn, 0, rid="a", tenant="t")
    m2.record(0.5, 0, 0, rid="b", tenant="u", weight_delta=MIB)
    m2.record(1.5, 0, 0, rid="b", tenant="u", weight_delta=-MIB)
    end = 3.0
    r1, r2 = m1.finalize(end), m2.finalize(end)
    assert (r1.e_leak, r1.e_sw) == (r2.e_leak, r2.e_sw)
    assert np.isclose(m1.energy_j(end), m2.energy_j(end), rtol=1e-12)
    # ... but they do shift attribution toward the sharer
    assert m2.request_energy("b", end) > 0.0
    assert m2.request_energy("a", end) < m1.request_energy("a", end)


def test_bank_intervals_cover_timeline():
    C, B = 4 * MIB, 4
    m = BankEnergyMeter(C, B, policy="drowsy")
    rng = np.random.default_rng(9)
    for t, dn, do, rid, ten in _random_events(rng, 30, C):
        m.record(t, dn, do, rid=rid, tenant=ten)
    end = 3.0
    iv = m.bank_intervals(end)
    assert iv, "no intervals"
    for b, state, a, e in iv:
        assert 0 <= b < B
        assert state in ("active", "idle", "drowsy", "gated")
        assert e >= a
    # per bank the intervals tile [first-activity, end] without overlap
    for b in range(B):
        rows = sorted((a, e) for bb, _, a, e in iv if bb == b)
        for (a0, e0), (a1, e1) in zip(rows, rows[1:]):
            assert a1 >= e0 - 1e-12


# ---------------------------------------------------------------- hypothesis
# (guarded import, NOT module-level importorskip: the deterministic tests
# above must run even without hypothesis installed)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                        # pragma: no cover
    HAVE_HYPOTHESIS = False


def _clamped(ev):
    """Sorted, occupancy-clamped event stream from raw hypothesis draws."""
    ts, dns, dos = ev
    order = np.argsort(ts, kind="stable")
    occ, out = 0, []
    for i in order:
        dn = max(dns[i], -occ)
        occ += dn
        out.append((float(ts[i]), int(dn), int(dos[i]),
                    f"r{i % 3}", f"tenant{i % 2}"))
    return out


if HAVE_HYPOTHESIS:
    events_st = st.integers(min_value=2, max_value=50).flatmap(
        lambda n: st.tuples(
            st.lists(st.floats(0.0, 5.0, allow_nan=False), min_size=n,
                     max_size=n),
            st.lists(st.integers(-2 * MIB, 2 * MIB), min_size=n, max_size=n),
            st.lists(st.integers(0, 4096), min_size=n, max_size=n)))

    @given(events_st, st.sampled_from(POLICIES),
           st.sampled_from([2, 4, 8, 16]), st.floats(0.5, 1.0))
    @settings(max_examples=60, deadline=None)
    def test_prop_streaming_exact(ev, policy, banks, alpha):
        evs = _clamped(ev)
        C = 4 * MIB
        pol = Policy.by_name(policy, alpha)
        m = BankEnergyMeter(C, banks, policy=pol)
        _feed(m, evs)
        end = evs[-1][0] + 0.1
        _, _, ref = _reference(evs, end, C, banks, pol)
        got = m.finalize(end)
        assert got.e_leak == ref.e_leak
        assert got.e_sw == ref.e_sw
        assert got.n_transitions == ref.n_transitions
        assert got.gated_bank_seconds == ref.gated_bank_seconds

    @given(events_st, st.sampled_from(POLICIES),
           st.randoms(use_true_random=False))
    @settings(max_examples=40, deadline=None)
    def test_prop_conservation_and_permutation(ev, policy, rnd):
        evs = _clamped(ev)
        C, B = 4 * MIB, 8
        m = BankEnergyMeter(C, B, policy=policy)
        _feed(m, evs)
        end = evs[-1][0] + 0.1
        live = m.energy_j(end)
        req = m.request_energy_j(end)
        assert all(j >= 0.0 for j in req.values())
        assert np.isclose(sum(req.values()) + m.floor_j(end), live,
                          rtol=1e-9, atol=1e-18)
        order = list(range(len(evs)))
        rnd.shuffle(order)
        m2 = BankEnergyMeter(C, B, policy=policy)
        _feed(m2, evs, order=order)
        assert m2.finalize(end).e_total == m.finalize(end).e_total
        assert np.isclose(m2.energy_j(end), live, rtol=1e-9, atol=1e-18)
else:                                                      # pragma: no cover
    @pytest.mark.skip(reason="property tests need hypothesis "
                             "(pip install .[test])")
    def test_prop_streaming_exact():
        pass
