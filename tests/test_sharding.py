"""Sharding rules engine: divisibility fallback, mesh-free constraints."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, reduced
from repro.models.common import PTpl
from repro.models.meshctx import constrain, current_mesh, use_mesh
from repro.models.sharding import (SERVE_RULES, TRAIN_RULES, batch_spec,
                                   spec_for)


def _mesh(shape=(2, 2), axes=("data", "model")):
    return jax.make_mesh(shape, axes, devices=jax.devices()[:1] * 0 or None) \
        if False else jax.make_mesh((1, 1), axes)


def test_spec_for_divisible_dims():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # weight (D, F): embed -> data, mlp -> model (both divisible by 1)
    s = spec_for(("embed", "mlp"), (64, 128), mesh, TRAIN_RULES)
    assert s == P("data", "model")


def test_spec_for_indivisible_falls_back_to_replicate():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate a 16-way axis via a fake mesh-like object
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    s = spec_for(("heads",), (28,), FakeMesh(), TRAIN_RULES)
    assert s == P(None)                      # 28 % 16 != 0 -> replicate
    s = spec_for(("qkv_out",), (3584,), FakeMesh(), TRAIN_RULES)
    assert s == P("model")                   # 3584 % 16 == 0


def test_spec_for_no_axis_reuse_within_tensor():
    class FakeMesh:
        shape = {"data": 4, "model": 4}
    # both dims want "model" (vocab then mlp); second must not reuse it
    s = spec_for(("vocab", "mlp"), (64, 64), FakeMesh(), TRAIN_RULES)
    assert s == P("model", None)


def test_batch_spec_prefers_pod_data_in_train():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    assert batch_spec(FakeMesh(), 256, "train") == P(("pod", "data"))
    assert batch_spec(FakeMesh(), 2, "train") == P(None)   # 2 % 32 != 0


def test_constrain_is_noop_without_mesh():
    assert current_mesh() is None
    x = jnp.ones((4, 4))
    y = constrain(x, P("data", None))
    assert (y == x).all()


def test_constrain_drops_missing_axes_and_indivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        x = jnp.ones((4, 4))
        # "pod" doesn't exist on this mesh; must not raise
        y = constrain(x, P(("pod", "data"), None))
        assert (y == x).all()


def test_template_shardings_cover_full_tree():
    from repro.models import build_model
    from repro.models.sharding import template_shardings
    cfg = reduced(get_arch("qwen2-7b"))
    m = build_model(cfg, compute_dtype=jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tpl = m.template()
    sh = template_shardings(tpl, mesh, TRAIN_RULES)
    n_tpl = len(jax.tree.leaves(tpl, is_leaf=lambda x: isinstance(x, PTpl)))
    n_sh = len(jax.tree.leaves(sh))
    assert n_tpl == n_sh


def test_cache_specs_structure_matches_cache():
    from repro.models.transformer import cache_specs, init_cache
    cfg = reduced(get_arch("recurrentgemma-2b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cache = jax.eval_shape(lambda: init_cache(cfg, 4, 64))
    specs = cache_specs(cfg, 4, 64, mesh)
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, cache)) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs, is_leaf=lambda x: isinstance(x, P)))
