"""Telemetry end-to-end: disabled registries record nothing, instrumented
paged serving produces SLO percentiles, and the Perfetto exporter emits
valid Chrome-trace JSON whose counter track integrates to exactly the
Stage-I occupancy trace."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.obs import (Telemetry, chrome_trace_events, counter_integral,
                       export_chrome_trace, noop_registry)
from repro.serve import PagedContinuousBatcher, Request
from repro.serve import engine as engine_mod
from repro.serve import paged as paged_mod


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("tinyllama-1.1b"), layers=2)
    m = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _batcher(m, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 32)
    kw.setdefault("max_pages_per_slot", 8)
    kw.setdefault("chunk_steps", 4)
    kw.setdefault("attn_backend", "ref")
    return PagedContinuousBatcher(m, params, **kw)


def _run(cb, cfg, n_req=3, n_new=6):
    rng = np.random.default_rng(0)
    for i in range(n_req):
        cb.submit(Request(rid=i,
                          tokens=rng.integers(0, cfg.vocab_size, 9 + 5 * i),
                          max_new_tokens=n_new))
    return cb.run()


# ---------------------------------------------------------------------------
# Disabled path + compile-count shims
# ---------------------------------------------------------------------------

def test_disabled_registry_records_nothing():
    tel = Telemetry(enabled=False)
    tel.counter("c").inc(5)
    tel.gauge("g").set(3)
    tel.histogram("h").observe(1.0)
    with tel.span("s", k=1):
        pass
    tel.add_span("s2", 0.0, 1.0)
    snap = tel.snapshot()
    assert snap["counters"]["c"] == 0
    assert snap["gauges"]["g"] == {"value": 0, "max": 0}
    assert snap["histograms"]["h"]["count"] == 0
    assert tel.spans == []


def test_batcher_without_registry_stays_silent(small):
    cfg, m, params = small
    cb = _batcher(m, params)                 # telemetry=None -> shared noop
    assert cb.tel is noop_registry()
    n_spans = len(cb.tel.spans)
    done = _run(cb, cfg)
    assert len(done) == 3
    assert len(cb.tel.spans) == n_spans
    assert cb.slo_summary().n_requests == 0
    assert cb.stats.ttft_p99_s == 0.0
    snap = cb.tel.snapshot()
    assert all(v == 0 for v in snap["counters"].values())


def test_loop_compile_count_shims_still_monotonic(small):
    cfg, m, params = small
    n0 = paged_mod.loop_compile_count()
    cb = _batcher(m, params)
    _run(cb, cfg, n_req=1)
    assert paged_mod.loop_compile_count() - n0 == 1
    assert isinstance(engine_mod.loop_compile_count(), int)


# ---------------------------------------------------------------------------
# Instrumented run: counters, SLOs, exporter golden format
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def instrumented(small):
    cfg, m, params = small
    tel = Telemetry(enabled=True)
    cb = _batcher(m, params, telemetry=tel)
    done = _run(cb, cfg)
    return tel, cb, done


def test_instrumented_counters_match_stats(instrumented):
    tel, cb, done = instrumented
    snap = tel.snapshot()["counters"]
    st = cb.stats
    assert snap["serve.paged.admitted"] == st.admitted == len(done)
    assert snap["serve.paged.retired"] == st.finished
    assert snap["serve.paged.decode_steps"] == st.decode_steps
    assert snap["serve.paged.pages_allocated"] == st.pages_allocated
    assert snap["serve.paged.pages_freed"] == st.pages_freed
    assert snap["serve.paged.chunks"] == st.chunks
    assert tel.snapshot()["gauges"]["serve.paged.pages_in_use"]["value"] == 0


def test_slo_percentiles_published(instrumented):
    tel, cb, done = instrumented
    s = cb.slo_summary()
    assert s.n_requests == len(done)
    for v in (s.ttft_p50_s, s.ttft_p99_s, s.e2e_p99_s, s.tbt_p50_s):
        assert math.isfinite(v) and v > 0
    assert s.ttft_p50_s <= s.ttft_p99_s <= s.e2e_p99_s
    # mirrored into the stats dataclass for report consumers
    assert cb.stats.ttft_p99_s == s.ttft_p99_s
    assert cb.stats.tbt_p50_s == s.tbt_p50_s


def test_request_timelines_on_sim_clock(instrumented):
    tel, cb, done = instrumented
    for r in done:
        tl = r.timeline
        assert tl is not None
        assert tl.submit_t <= tl.admit_t <= tl.first_token_t <= tl.finish_t
        assert len(tl.token_ts) == len(r.output)
        assert (np.diff(tl.token_ts) >= 0).all()


def test_chrome_trace_export_golden_format(instrumented, tmp_path):
    tel, cb, done = instrumented
    bundle = cb.occupancy_bundle()
    end = bundle.total_time
    path = tmp_path / "trace.json"
    obj = export_chrome_trace(str(path), tel, traces=bundle.traces.values(),
                              end_time=end, other_data={"k": 1})
    # the written file is valid JSON and matches the returned object
    assert json.loads(path.read_text()) == json.loads(json.dumps(obj))
    evs = obj["traceEvents"]
    assert obj["displayTimeUnit"] == "ms" and obj["otherData"] == {"k": 1}
    assert len(evs) > 0
    for e in evs:
        assert {"ph", "pid", "name"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0
    # span coverage: request lifecycle lanes + per-slot prefills + chunks
    names = {e["name"] for e in evs if e["ph"] in ("X", "i")}
    assert {"request", "prefill", "decode_chunk"} <= names
    req_lanes = {e["tid"] for e in evs
                 if e["ph"] != "M" and e["pid"] == 2}
    assert len(req_lanes) == len(done)
    # counter events are time-sorted and the reconstructed integral equals
    # the occupancy trace's own time integral (nothing lost in export)
    cts = [e["ts"] for e in evs if e["ph"] == "C"]
    assert cts == sorted(cts) and len(cts) > 0
    got = counter_integral(evs, "kv occupancy [B]", end * 1e6)
    want = bundle.traces["kv"].time_integral(end, use="needed") * 1e6
    assert got == pytest.approx(want, rel=1e-9)


def test_chrome_trace_zero_duration_spans_are_instants():
    tel = Telemetry(enabled=True)
    tel.add_span("cow", 1.0, 1.0, slot=0)
    evs = chrome_trace_events(tel)
    ev = [e for e in evs if e["name"] == "cow"][0]
    assert ev["ph"] == "i" and ev["s"] == "t"
