"""Hypothesis property suite: batched engine == scalar Stage-II references.

Random traces (including empty / single-segment / always-idle draws), all
three policies, the prune-then-exact flow, and the jnp/Pallas backends
against the float64 numpy one.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.candidates import (Candidate, evaluate_candidates,  # noqa: E402
                                   make_grid)
from repro.core.gating import Policy, evaluate  # noqa: E402
from repro.core.sensitivity import evaluate_drowsy  # noqa: E402
from repro.kernels.bank_energy import (exact_bank_stats,  # noqa: E402
                                       exact_bank_stats_np)

MIB = 2**20

trace_st = st.integers(min_value=0, max_value=120).flatmap(
    lambda n: st.tuples(
        st.lists(st.floats(1e-6, 5.0), min_size=n, max_size=n),
        st.lists(st.integers(0, 256 * MIB), min_size=n, max_size=n)))

cb_st = st.tuples(st.sampled_from([16, 48, 128, 256]),
                  st.sampled_from([1, 2, 5, 8, 32]))


@given(trace_st, cb_st, st.floats(0.05, 1.0),
       st.sampled_from([0.5, 1.0, 5.0, 1e3]))
@settings(max_examples=60, deadline=None)
def test_batched_equals_scalar_gate(trace, cb, alpha, mgm):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    c_mib, b = cb
    cands = [Candidate(c_mib * MIB, b, alpha, "gate", mgm),
             Candidate(c_mib * MIB, b, alpha, "none")]
    res = evaluate_candidates(d, occ, cands, n_reads=10, n_writes=20)
    for i, c in enumerate(cands):
        pol = (Policy.none(alpha) if c.policy == "none"
               else Policy("g", alpha, True, mgm))
        ref = evaluate(d, occ, capacity=c.capacity, banks=c.banks,
                       policy=pol, n_reads=10, n_writes=20)
        assert int(res.n_off[i]) == ref.n_transitions
        assert res.e_total[i] == pytest.approx(ref.e_total, rel=1e-6)
        assert res.e_leak[i] == pytest.approx(ref.e_leak, rel=1e-6,
                                              abs=1e-18)
        assert res.e_sw[i] == pytest.approx(ref.e_sw, rel=1e-6, abs=1e-18)


@given(trace_st, cb_st, st.sampled_from([0.5, 1.0, 1e2, 1e5]))
@settings(max_examples=60, deadline=None)
def test_batched_equals_scalar_drowsy(trace, cb, mult):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    c_mib, b = cb
    res = evaluate_candidates(
        d, occ, [Candidate(c_mib * MIB, b, 0.9, "drowsy", mult)],
        n_reads=10, n_writes=20)
    ref = evaluate_drowsy(d, occ, capacity=c_mib * MIB, banks=b,
                          n_reads=10, n_writes=20, off_multiple=mult)
    assert int(res.n_off[0]) == ref.n_off
    assert int(res.n_drowsy[0]) == ref.n_drowsy
    assert res.e_total[0] == pytest.approx(ref.e_total, rel=1e-6)


@given(trace_st)
@settings(max_examples=25, deadline=None)
def test_prune_preserves_argmin(trace):
    d, occ = np.asarray(trace[0]), np.asarray(trace[1], np.int64)
    cands = make_grid([c * MIB for c in (64, 128, 256)], (1, 4, 16),
                      policies=("gate", "drowsy"))
    full = evaluate_candidates(d, occ, cands, n_reads=5, n_writes=5)
    pruned = evaluate_candidates(d, occ, cands, n_reads=5, n_writes=5,
                                 prune=True)
    assert full.e_total[full.argmin()] == pytest.approx(
        pruned.e_total[pruned.argmin()], rel=1e-12)


@given(trace_st, st.sampled_from([1, 4, 32]))
@settings(max_examples=25, deadline=None)
def test_jnp_backend_matches_numpy(trace, b):
    """f32 jnp path vs the exact f64 path — loose tolerance by design."""
    d = np.asarray(trace[0])
    occ = np.asarray(trace[1], np.int64)
    usable = np.array([0.9 * (128 * MIB / b)])
    nb = np.array([float(b)])
    th = np.array([1e-4])
    ref = exact_bank_stats_np(d, occ, usable, nb, th)
    out = np.asarray(exact_bank_stats(d, occ, usable, nb, th, backend="ref"))
    np.testing.assert_allclose(out, ref, rtol=5e-3, atol=5e-3)
