"""Decoder-only LM covering dense / GQA / MQA / MoE / SSM / hybrid / VLM
families, with three entry points:

    loss(params, batch)                    — training objective
    prefill(params, batch, cache_len)      — full-sequence forward + cache fill
    decode_step(params, cache, tokens)     — one token against the cache

The layer stack is grouped by the config's block pattern and scanned with
`lax.scan` over pattern repetitions (stacked params), keeping HLO size and
compile time O(pattern) instead of O(depth) — essential for 62-/88-layer
archs in the dry-run. A non-divisible tail (e.g. recurrentgemma 26 = 3×8 + 2)
is applied unstacked.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.kernels import quant
from repro.models.common import (PTpl, abstract_params, apply_norm, apply_rope,
                                 cross_entropy, embed_template, embed_tokens,
                                 init_params, lm_logits, norm_template,
                                 stack_tpl)
from repro.models.meshctx import constrain


# ---------------------------------------------------------------------------
# Block templates
# ---------------------------------------------------------------------------

def block_template(cfg, kind: str) -> dict:
    if kind in ("full", "local", "chunked"):
        t = {"norm1": norm_template(cfg), "attn": attn.attn_template(cfg),
             "norm2": norm_template(cfg)}
        t["ffn"] = (moe_mod.moe_template(cfg) if cfg.moe is not None
                    else ffn_mod.ffn_template(cfg))
        return t
    if kind == "rglru":
        return {"norm1": norm_template(cfg), "rec": rglru_mod.rglru_template(cfg),
                "norm2": norm_template(cfg), "ffn": ffn_mod.ffn_template(cfg)}
    if kind == "ssm":
        return {"norm1": norm_template(cfg), "ssm": ssm_mod.ssm_template(cfg)}
    raise ValueError(kind)


def lm_template(cfg) -> dict:
    pat = cfg.block_pattern
    n_rep = cfg.num_layers // len(pat)
    tail_kinds = cfg.layer_kinds()[n_rep * len(pat):]
    t: Dict[str, Any] = {"embed": embed_template(cfg)}
    t["blocks"] = [stack_tpl(block_template(cfg, k), n_rep) for k in pat]
    t["tail"] = [block_template(cfg, k) for k in tail_kinds]
    t["final_norm"] = norm_template(cfg)
    if cfg.frontend is not None:
        # stub modality projector: precomputed frontend embeddings -> d_model
        t["projector"] = {
            "w": PTpl((cfg.d_model, cfg.d_model), ("embed", "mlp")),
            "b": PTpl((cfg.d_model,), ("embed",), "zeros"),
        }
    return t


# ---------------------------------------------------------------------------
# Block application — full-sequence mode
# ---------------------------------------------------------------------------

def _attend_full_seq(cfg, kind: str, p: dict, x: jax.Array,
                     positions: jax.Array, kv_block: int,
                     unroll: bool = False):
    """Self-attention over a full sequence; returns (out, (k, v)).

    Sequence-parallel attention (Perf iteration A1/B1): query rows shard over
    the "model" axis while the (GQA-small) K/V replicate across it — all
    score/softmax/AV math is then local to each chip, eliminating the
    per-kv-block all-reduce flood that plain head-misaligned TP produces
    (qwen2's 28 heads don't divide a 16-way axis; 4096-row sequences do).
    This is the TP reading of the paper's GQA observation: shared K/V is
    small enough to replicate.
    """
    q, k, v = attn.project_qkv(cfg, p, x, x)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if kind == "full":
        bspec = ("pod", "data")
        q = constrain(q, P(bspec, "model", None, None))
        k = constrain(k, P(bspec, None, None, None))
        v = constrain(v, P(bspec, None, None, None))
        o = attn.blocked_attention(q, k, v, causal=True, kv_block=kv_block,
                                   unroll=unroll)
        o = constrain(o, P(bspec, "model", None, None))
    elif kind == "local":
        o = attn.local_attention(q, k, v, cfg.local_window)
    else:
        o = attn.chunked_attention(q, k, v, cfg.local_window)
    o = o.reshape(*x.shape[:2], cfg.q_dim)
    return o @ p["wo"].astype(x.dtype), (k, v)


def apply_block(cfg, kind: str, p: dict, x: jax.Array, positions: jax.Array,
                kv_block: int, unroll: bool = False):
    """Returns (x_out, aux_loss, kv_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    if kind in ("full", "local", "chunked"):
        h, kv = _attend_full_seq(cfg, kind, p["attn"],
                                 apply_norm(cfg, p["norm1"], x), positions,
                                 kv_block, unroll)
        x = x + h
        y = apply_norm(cfg, p["norm2"], x)
        if cfg.moe is not None:
            f, aux = moe_mod.apply_moe(cfg, p["ffn"], y)
        else:
            f = ffn_mod.apply_ffn(cfg, p["ffn"], y)
        x = x + f
    elif kind == "rglru":
        x = x + rglru_mod.apply_rglru(cfg, p["rec"],
                                      apply_norm(cfg, p["norm1"], x))
        x = x + ffn_mod.apply_ffn(cfg, p["ffn"],
                                  apply_norm(cfg, p["norm2"], x))
    elif kind == "ssm":
        x = x + ssm_mod.apply_ssm(cfg, p["ssm"],
                                  apply_norm(cfg, p["norm1"], x))
    else:
        raise ValueError(kind)
    # Perf iteration B5: for pure full-attention stacks, keep the residual
    # stream sequence-sharded over "model" (Megatron-SP style) — norms, FFN
    # rows and attention all operate on local sequence shards, so per-layer
    # collectives shrink to (B, S/tp, D)-sized partial reductions.
    if cfg.block_pattern == ("full",):
        x = constrain(x, P(("pod", "data"), "model", None))
    else:
        x = constrain(x, P(("pod", "data"), None, None))
    return x, aux, kv


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _slot_cache_len(cfg, kind: str, cache_len: int) -> int:
    if kind in ("local", "chunked"):
        return min(cfg.local_window, cache_len)
    return cache_len


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree: one entry per pattern slot (stacked n_rep) plus
    unstacked tail entries and a scalar position."""
    pat = cfg.block_pattern
    n_rep = cfg.num_layers // len(pat)
    tail_kinds = cfg.layer_kinds()[n_rep * len(pat):]

    def slot(kind, stack: Optional[int]):
        def maybe_stack(a):
            return a if stack is None else jnp.broadcast_to(a, (stack,) + a.shape)
        if kind in ("full", "local", "chunked"):
            T = _slot_cache_len(cfg, kind, cache_len)
            z = jnp.zeros((batch, T, cfg.num_kv_heads, cfg.head_dim), dtype)
            return {"k": maybe_stack(z), "v": maybe_stack(z)}
        if kind == "rglru":
            c = rglru_mod.init_rglru_cache(cfg, batch, dtype)
        else:
            c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        return jax.tree.map(maybe_stack, c)

    return {
        "slots": [slot(k, n_rep) for k in pat],
        "tail": [slot(k, None) for k in tail_kinds],
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg, batch: int, cache_len: int, mesh,
                dtype=jnp.bfloat16):
    """PartitionSpec pytree for the decode cache.

    Policy: shard the batch dim over "data" (and "pod" when present and
    divisible); for KV tensors additionally shard kv_heads over "model" when
    divisible, else head_dim, else the sequence dim (context parallelism for
    long_500k's batch=1). Recurrent states shard their width over "model".
    """
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, cache_len, dtype))

    def ax_ok(name, d):
        return name in mesh.shape and d % mesh.shape[name] == 0

    def spec_of(leaf):
        shp = leaf.shape
        spec = [None] * len(shp)
        # find the batch dim: first dim equal to `batch` (after optional stack)
        dims = list(range(len(shp)))
        bi = None
        for i in dims:
            if shp[i] == batch and (i == 0 or shp[0] != batch):
                bi = i
                break
        if shp and shp[0] == batch:
            bi = 0
        if bi is not None:
            if ax_ok("data", shp[bi]):
                spec[bi] = "data"
        if len(shp) >= 2 and leaf.dtype != jnp.int32:
            # KV caches: (..., B, T, K, h). Prefer kv-heads over "model"; when
            # they don't divide (GQA with few kv heads), shard the SEQUENCE
            # dim instead — decode attention then computes partial softmax
            # sums locally and all-reduces only (B,K,G)-sized statistics,
            # instead of all-gathering the whole cache (Perf iteration C1).
            if len(shp) >= 4 and shp[-2] == cfg.num_kv_heads \
                    and shp[-1] == cfg.head_dim:
                if ax_ok("model", shp[-2]):
                    spec[-2] = "model"
                elif ax_ok("model", shp[-3]):
                    spec[-3] = "model"     # sequence (context parallel)
                elif ax_ok("model", shp[-1]):
                    spec[-1] = "model"
                elif spec[bi] != "data" and ax_ok("data", shp[-3]):
                    spec[-3] = "data"      # shard seq when batch can't shard
            else:
                # recurrent states: shard trailing width over model
                if ax_ok("model", shp[-1]):
                    spec[-1] = "model"
        return P(*spec)

    return jax.tree.map(spec_of, shapes)


# ---------------------------------------------------------------------------
# Paged decode caches
# ---------------------------------------------------------------------------
#
# Serving allocates KV memory at *page* granularity instead of dense max_len
# slabs: K/V rows live in global per-layer page pools of shape
# (num_pages, K, page_size, head_dim), every slot owns an ordered row of an
# int32 page table (num_slots, max_pages) plus a true per-slot position, and
# attention gathers through the table (kernels/paged_gqa_decode). Page 0 is
# reserved as the null page: retired/inactive slots point their whole table
# at it, so their masked lanes write and read harmless garbage.

PAGED_NULL_PAGE = 0


def _pool_cast(x: jax.Array, dtype) -> jax.Array:
    """Cast KV rows to a pool dtype. fp8 pools store E4M3 bit codes in
    uint8 (see `quant.FP8_STORAGE_DTYPE`), and the cast saturates because
    E4M3 overflows to NaN, not inf."""
    if jnp.dtype(dtype) == quant.FP8_STORAGE_DTYPE:
        return quant.to_fp8_codes(x)
    if jnp.dtype(dtype) == jnp.dtype(quant.FP8_DTYPE):
        return quant.to_fp8(x)
    return x.astype(dtype)


def init_paged_cache(cfg, num_slots: int, num_pages: int, page_size: int,
                     max_pages_per_slot: int, dtype=jnp.bfloat16,
                     kv_dtype: Optional[str] = None) -> dict:
    """Paged decode state: per-layer page pools shared by all slots, one page
    table + true position per slot. Recurrent (SSM / RG-LRU) blocks keep
    their fixed-size per-slot state dense, batched over slots — only
    attention KV grows with context, so only it is paged. Sliding-window /
    chunked layers are bounded by construction and not supported here.

    `kv_dtype` selects the page storage format (see `kernels.quant`):
    None/"native" keeps pages in `dtype`; "int8" stores int8 payload pools
    plus per-row float32 scale pools ("ks"/"vs"); "fp8" stores scale-free
    E4M3 pools. Recurrent state always stays in `dtype`."""
    pat = cfg.block_pattern
    if any(k in ("local", "chunked") for k in pat):
        raise NotImplementedError(
            "paged decode supports full-attention (+ssm/rglru) stacks; "
            "window-bounded layers gain nothing from paging")
    spec = quant.kv_dtype_spec(kv_dtype or "native", native=dtype)
    n_rep = cfg.num_layers // len(pat)
    tail_kinds = cfg.layer_kinds()[n_rep * len(pat):]

    def slot(kind, stack: Optional[int]):
        def maybe_stack(a):
            return a if stack is None else jnp.broadcast_to(a, (stack,) + a.shape)
        if kind == "full":
            z = jnp.zeros((num_pages, cfg.num_kv_heads, page_size,
                           cfg.head_dim), spec.pool_dtype)
            e = {"kp": maybe_stack(z), "vp": maybe_stack(z)}
            if spec.has_scales:
                zs = jnp.zeros((num_pages, cfg.num_kv_heads, page_size),
                               jnp.float32)
                e["ks"] = maybe_stack(zs)
                e["vs"] = maybe_stack(zs)
            return e
        if kind == "rglru":
            c = rglru_mod.init_rglru_cache(cfg, num_slots, dtype)
        else:
            c = ssm_mod.init_ssm_cache(cfg, num_slots, dtype)
        return jax.tree.map(maybe_stack, c)

    return {
        "slots": [slot(k, n_rep) for k in pat],
        "tail": [slot(k, None) for k in tail_kinds],
        "pos": jnp.zeros((num_slots,), jnp.int32),
        "page_table": jnp.full((num_slots, max_pages_per_slot),
                               PAGED_NULL_PAGE, jnp.int32),
        "active": jnp.zeros((num_slots,), bool),
    }


def write_prefill_to_pages(cfg, paged: dict, dense: dict, slot,
                           page_ids: jax.Array) -> dict:
    """Admission: map a batch=1 dense prefill cache into slot `slot` of a
    paged cache — the prompt's KV rows are scattered into the slot's
    freshly-allocated pages and the page-table row is rewritten; nothing is
    re-prefilled. The dense cache_len must equal len(page_ids) * page_size."""
    pat = cfg.block_pattern
    n_rep = cfg.num_layers // len(pat)
    tail_kinds = cfg.layer_kinds()[n_rep * len(pat):]
    npg = page_ids.shape[0]

    def one(kind, entry, d_entry, stacked: bool):
        if kind == "full":
            kp = entry["kp"]
            ps = kp.shape[-2]

            def to_pages(x):
                # (..., 1, npg*ps, K, d) -> (..., npg, K, ps, d)
                if stacked:
                    n, T, K, d = x.shape[0], x.shape[2], x.shape[3], x.shape[4]
                    return x.reshape(n, npg, ps, K, d).transpose(0, 1, 3, 2, 4)
                T, K, d = x.shape[1], x.shape[2], x.shape[3]
                return x.reshape(npg, ps, K, d).transpose(0, 2, 1, 3)

            def scatter(pool, x):
                return (pool.at[:, page_ids].set(x) if stacked
                        else pool.at[page_ids].set(x))

            if "ks" in entry:                    # int8: quantize per row
                def put_q(pool, spool, dense_kv):
                    q8, s = quant.quantize_page_rows(to_pages(
                        dense_kv.astype(jnp.float32)))
                    return scatter(pool, q8), scatter(spool, s)

                kp_n, ks_n = put_q(kp, entry["ks"], d_entry["k"])
                vp_n, vs_n = put_q(entry["vp"], entry["vs"], d_entry["v"])
                return {"kp": kp_n, "vp": vp_n, "ks": ks_n, "vs": vs_n}

            def put(pool, dense_kv):
                return scatter(pool, to_pages(_pool_cast(dense_kv,
                                                         pool.dtype)))

            return {"kp": put(kp, d_entry["k"]), "vp": put(entry["vp"],
                                                           d_entry["v"])}
        # recurrent state: write the single prefilled sequence into slot row
        if stacked:
            return jax.tree.map(
                lambda s, d: s.at[:, slot].set(d[:, 0].astype(s.dtype)),
                entry, d_entry)
        return jax.tree.map(
            lambda s, d: s.at[slot].set(d[0].astype(s.dtype)),
            entry, d_entry)

    out = dict(paged)
    out["slots"] = [one(k, e, de, True) for k, e, de in
                    zip(pat, paged["slots"], dense["slots"])]
    out["tail"] = [one(k, e, de, False) for k, e, de in
                   zip(tail_kinds, paged["tail"], dense["tail"])]
    row = jnp.full((paged["page_table"].shape[1],), PAGED_NULL_PAGE,
                   jnp.int32).at[:npg].set(page_ids.astype(jnp.int32))
    out["page_table"] = paged["page_table"].at[slot].set(row)
    out["pos"] = paged["pos"].at[slot].set(dense["pos"].astype(jnp.int32))
    out["active"] = paged["active"].at[slot].set(True)
    return out


# Retirement needs no device call: the decode loop flips `active` in-scan,
# the batcher zeroes its host page-table mirror (pushed before each chunk),
# and re-admission overwrites pos/active/table — pool pages are only
# reachable through tables, so they never need clearing.


# ---------------------------------------------------------------------------
# Prefix sharing: gather / COW-write / page-copy helpers + suffix prefill
# ---------------------------------------------------------------------------
#
# Prefix reuse needs no kernel change — shared pages are reached through the
# same page-table indirection as private ones. The device-side verbs are:
#   gather_prefix_pages          pages -> dense (1, m, K, h) prefix KV
#   prefix_tail_rows             last j rows of a gathered prefix (the
#                                partially-matched page's valid rows)
#   write_shared_prefill_to_pages  head + suffix KV -> fresh pages, table row
#                                = shared pages ++ fresh pages
#   copy_pages                   COW split: clone one page across all layers
# and DecoderLM.prefill_shared runs the *suffix-only* forward against the
# gathered prefix KV (the compute half of "skipping prefill for the matched
# run"). All of it is restricted to pure full-attention stacks: recurrent
# blocks carry position-mixed state that cannot be sliced at a prefix
# boundary.

def _require_pure_full(cfg, what: str) -> None:
    if any(k != "full" for k in cfg.layer_kinds()):
        raise NotImplementedError(
            f"{what} requires a pure full-attention stack; "
            f"{cfg.name} mixes {set(cfg.layer_kinds())}")


def gather_prefix_pages(cfg, paged: dict, page_ids: jax.Array,
                        n_rows: int) -> dict:
    """Collect the first `n_rows` KV rows stored in `page_ids` (table order)
    as a dense prefix pytree {"slots": [{"k","v"}...], "tail": [...]} with
    leaves (n_rep, 1, n_rows, K, h) / (1, n_rows, K, h). Rows come back
    exactly as stored (post-RoPE, pool dtype); int8 pools dequantize with
    their per-row scales and fp8 pools decode their E4M3 codes, both
    returning float32 rows."""
    _require_pure_full(cfg, "gather_prefix_pages")

    def take(pool, spool, stacked: bool):
        fp8 = pool.dtype == quant.FP8_STORAGE_DTYPE
        if stacked:
            x = pool[:, page_ids]                      # (n, npg, K, ps, h)
            if spool is not None:
                x = quant.dequantize_page_rows(x, spool[:, page_ids])
            elif fp8:
                x = quant.from_fp8(x)
            n, npg, K, ps, h = x.shape
            x = x.transpose(0, 1, 3, 2, 4).reshape(n, npg * ps, K, h)
            return x[:, None, :n_rows]                 # (n, 1, rows, K, h)
        x = pool[page_ids]                             # (npg, K, ps, h)
        if spool is not None:
            x = quant.dequantize_page_rows(x, spool[page_ids])
        elif fp8:
            x = quant.from_fp8(x)
        npg, K, ps, h = x.shape
        x = x.transpose(0, 2, 1, 3).reshape(npg * ps, K, h)
        return x[None, :n_rows]                        # (1, rows, K, h)

    return {
        "slots": [{"k": take(e["kp"], e.get("ks"), True),
                   "v": take(e["vp"], e.get("vs"), True)}
                  for e in paged["slots"]],
        "tail": [{"k": take(e["kp"], e.get("ks"), False),
                  "v": take(e["vp"], e.get("vs"), False)}
                 for e in paged["tail"]],
    }


def prefix_tail_rows(prefix: dict, j: int) -> dict:
    """Last `j` rows of a gathered prefix — the valid head of the boundary
    page a COW admission rewrites into its private copy (j == 0 -> empty)."""
    def cut(a, stacked: bool):
        return a[:, :, a.shape[2] - j:] if stacked else a[:, a.shape[1] - j:]
    return {
        "slots": [{"k": cut(e["k"], True), "v": cut(e["v"], True)}
                  for e in prefix["slots"]],
        "tail": [{"k": cut(e["k"], False), "v": cut(e["v"], False)}
                 for e in prefix["tail"]],
    }


def write_shared_prefill_to_pages(cfg, paged: dict, suffix: dict, head: dict,
                                  slot, shared_ids: jax.Array,
                                  fresh_ids: jax.Array) -> dict:
    """Prefix-hit admission: map `shared_ids` read-only into the slot's
    table, then write `head` (j rows re-owned from the partially-matched
    page) followed by `suffix` (the freshly computed suffix KV) page-aligned
    into `fresh_ids`. Sets pos = |shared|*ps + j + |suffix| and activates
    the slot. With empty `shared_ids`/`head` this degenerates to a plain
    paged admission of a full prefill. int8 pools requantize the written
    rows per row — idempotent for `head` rows that were dequantized from
    the donor's pages, so shared pages stay quantized and bit-stable."""
    _require_pure_full(cfg, "write_shared_prefill_to_pages")
    n_shared = shared_ids.shape[0]
    npg_f = fresh_ids.shape[0]

    def put(pool, spool, head_x, suf_x, stacked: bool):
        ps = pool.shape[-2]
        quantized = spool is not None
        cast = ((lambda a: a.astype(jnp.float32)) if quantized
                else (lambda a: _pool_cast(a, pool.dtype)))
        if stacked:
            rows = jnp.concatenate([cast(head_x[:, 0]), cast(suf_x[:, 0])],
                                   axis=1)
            n, r, K, h = rows.shape
            rows = jnp.pad(rows, ((0, 0), (0, npg_f * ps - r),
                                  (0, 0), (0, 0)))
            x = rows.reshape(n, npg_f, ps, K, h).transpose(0, 1, 3, 2, 4)
            if quantized:
                q8, s = quant.quantize_page_rows(x)
                return (pool.at[:, fresh_ids].set(q8),
                        spool.at[:, fresh_ids].set(s))
            return pool.at[:, fresh_ids].set(x), None
        rows = jnp.concatenate([cast(head_x[0]), cast(suf_x[0])], axis=0)
        r, K, h = rows.shape
        rows = jnp.pad(rows, ((0, npg_f * ps - r), (0, 0), (0, 0)))
        x = rows.reshape(npg_f, ps, K, h).transpose(0, 2, 1, 3)
        if quantized:
            q8, s = quant.quantize_page_rows(x)
            return pool.at[fresh_ids].set(q8), spool.at[fresh_ids].set(s)
        return pool.at[fresh_ids].set(x), None

    def entry_out(e, hd, sf, stacked: bool):
        kp, ks = put(e["kp"], e.get("ks"), hd["k"], sf["k"], stacked)
        vp, vs = put(e["vp"], e.get("vs"), hd["v"], sf["v"], stacked)
        ne = {"kp": kp, "vp": vp}
        if ks is not None:
            ne["ks"], ne["vs"] = ks, vs
        return ne

    ps = paged["slots"][0]["kp"].shape[-2] if paged["slots"] \
        else paged["tail"][0]["kp"].shape[-2]
    j = (head["slots"][0]["k"].shape[2] if head["slots"]
         else head["tail"][0]["k"].shape[1])
    s_suf = (suffix["slots"][0]["k"].shape[2] if suffix["slots"]
             else suffix["tail"][0]["k"].shape[1])

    out = dict(paged)
    out["slots"] = [
        entry_out(e, hd, sf, True)
        for e, hd, sf in zip(paged["slots"], head["slots"], suffix["slots"])]
    out["tail"] = [
        entry_out(e, hd, sf, False)
        for e, hd, sf in zip(paged["tail"], head["tail"], suffix["tail"])]
    row = jnp.full((paged["page_table"].shape[1],), PAGED_NULL_PAGE,
                   jnp.int32)
    row = row.at[:n_shared].set(shared_ids.astype(jnp.int32))
    row = row.at[n_shared:n_shared + npg_f].set(fresh_ids.astype(jnp.int32))
    out["page_table"] = paged["page_table"].at[slot].set(row)
    out["pos"] = paged["pos"].at[slot].set(
        jnp.int32(n_shared * ps + j + s_suf))
    out["active"] = paged["active"].at[slot].set(True)
    return out


def copy_pages(cfg, paged: dict, src: jax.Array, dst: jax.Array) -> dict:
    """COW split: duplicate page `src` into `dst` across every layer pool
    (one jitted call, scalars traced — compiles once per pool geometry).
    Quantized pools copy payload and per-row scale pools alike, so COW
    clones stay quantized bit-for-bit."""
    _require_pure_full(cfg, "copy_pages")
    out = dict(paged)
    out["slots"] = [{key: a.at[:, dst].set(a[:, src]) for key, a in e.items()}
                    for e in paged["slots"]]
    out["tail"] = [{key: a.at[dst].set(a[src]) for key, a in e.items()}
                   for e in paged["tail"]]
    return out


# ---------------------------------------------------------------------------
# Block application — decode mode
# ---------------------------------------------------------------------------

def apply_block_decode(cfg, kind: str, p: dict, x: jax.Array, cache: dict,
                       pos: jax.Array):
    """x: (B,1,D). Returns (x_out, new_cache)."""
    if kind in ("full", "local", "chunked"):
        y = apply_norm(cfg, p["norm1"], x)
        q, k, v = attn.project_qkv(cfg, p["attn"], y, y)
        if cfg.pos_emb == "rope":
            posv = pos[None] if pos.ndim == 0 else pos
            q = apply_rope(q, jnp.broadcast_to(posv, (x.shape[0], 1)),
                           cfg.rope_theta)
            k = apply_rope(k, jnp.broadcast_to(posv, (x.shape[0], 1)),
                           cfg.rope_theta)
        T = cache["k"].shape[1]
        if kind == "full":
            widx = pos
        else:
            widx = pos % jnp.int32(T)
        ck, cv = attn.cache_write(cache["k"], cache["v"], k, v, widx)
        valid = attn.decode_valid_mask(kind, T, pos, cfg.local_window)
        o = attn.decode_attention(q, ck, cv, valid)
        o = o.reshape(x.shape[0], 1, cfg.q_dim) @ p["attn"]["wo"].astype(x.dtype)
        x = x + o
        y2 = apply_norm(cfg, p["norm2"], x)
        if cfg.moe is not None:
            f, _ = moe_mod.apply_moe(cfg, p["ffn"], y2)
        else:
            f = ffn_mod.apply_ffn(cfg, p["ffn"], y2)
        x = x + f
        return x, {"k": ck, "v": cv}
    if kind == "rglru":
        h, new_c = rglru_mod.apply_rglru_decode(
            cfg, p["rec"], apply_norm(cfg, p["norm1"], x), cache)
        x = x + h
        x = x + ffn_mod.apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["norm2"], x))
        return x, new_c
    if kind == "ssm":
        h, new_c = ssm_mod.apply_ssm_decode(
            cfg, p["ssm"], apply_norm(cfg, p["norm1"], x), cache)
        return x + h, new_c
    raise ValueError(kind)


def apply_block_decode_paged(cfg, kind: str, p: dict, x: jax.Array,
                             cache: dict, pos: jax.Array,
                             page_table: jax.Array,
                             attn_backend: str = "auto"):
    """Paged decode block. x: (B,1,D); pos: (B,) true per-slot positions;
    page_table: (B, P). Returns (x_out, new_cache).

    Full-attention blocks write the new K/V row through the page table
    (inactive slots resolve to the null page) and attend with the paged GQA
    kernel at exact per-slot lengths — no max-length mask. Recurrent blocks
    are position-independent and reuse the dense decode path."""
    if kind == "full":
        from repro.kernels.paged_gqa_decode import (paged_gqa_decode,
                                                    paged_gqa_decode_quant)
        y = apply_norm(cfg, p["norm1"], x)
        q, k, v = attn.project_qkv(cfg, p["attn"], y, y)
        if cfg.pos_emb == "rope":
            q = apply_rope(q, pos[:, None], cfg.rope_theta)
            k = apply_rope(k, pos[:, None], cfg.rope_theta)
        kp, vp = cache["kp"], cache["vp"]
        ps = kp.shape[-2]
        P = page_table.shape[1]
        B = x.shape[0]
        pidx = page_table[jnp.arange(B), jnp.clip(pos // ps, 0, P - 1)]
        off = pos % ps
        if "ks" in cache:
            # int8 pages: quantize the appended row per (slot, kv head) and
            # attend with the fused in-register-dequant kernel. Per-row
            # scales make the append local — rows already in the page keep
            # their codes and scales.
            ks, vs = cache["ks"], cache["vs"]
            qk, sk = quant.quantize_page_rows(k[:, 0].astype(jnp.float32))
            qv, sv = quant.quantize_page_rows(v[:, 0].astype(jnp.float32))
            kp = kp.at[pidx, :, off].set(qk)
            vp = vp.at[pidx, :, off].set(qv)
            ks = ks.at[pidx, :, off].set(sk)
            vs = vs.at[pidx, :, off].set(sv)
            o = paged_gqa_decode_quant(q[:, 0], kp, vp, ks, vs, page_table,
                                       pos + 1, backend=attn_backend)
            new_entry = {"kp": kp, "vp": vp, "ks": ks, "vs": vs}
        else:
            kp = kp.at[pidx, :, off].set(_pool_cast(k[:, 0], kp.dtype))
            vp = vp.at[pidx, :, off].set(_pool_cast(v[:, 0], vp.dtype))
            o = paged_gqa_decode(q[:, 0], kp, vp, page_table, pos + 1,
                                 backend=attn_backend)
            new_entry = {"kp": kp, "vp": vp}
        o = o.reshape(B, 1, cfg.q_dim) @ p["attn"]["wo"].astype(x.dtype)
        x = x + o
        y2 = apply_norm(cfg, p["norm2"], x)
        if cfg.moe is not None:
            f, _ = moe_mod.apply_moe(cfg, p["ffn"], y2)
        else:
            f = ffn_mod.apply_ffn(cfg, p["ffn"], y2)
        x = x + f
        return x, new_entry
    return apply_block_decode(cfg, kind, p, x, cache, pos)


def apply_block_verify_paged(cfg, p: dict, x: jax.Array, cache: dict,
                             pos: jax.Array, page_table: jax.Array,
                             attn_backend: str = "auto"):
    """Speculative-verification block. x: (B, V, D) — the V = spec_k + 1
    window rows per slot; pos: (B,) true per-slot context lengths *before*
    the window; page_table: (B, P). Returns (x_out, new_cache).

    Writes all V K/V rows through the page table at positions
    pos .. pos + V - 1 (inactive slots resolve to the null page), then
    scores every window row in one `paged_gqa_verify` call — one pass over
    the resident pages instead of V sequential decode calls. Rows past the
    eventually-accepted count are garbage the next round overwrites before
    reading; `pos` itself is owned by the caller."""
    from repro.kernels.paged_gqa_verify import paged_gqa_verify
    if "ks" in cache:
        raise NotImplementedError(
            "speculative verification does not support int8 KV pages: "
            "per-row scales of rolled-back rows would need requant-stable "
            "rewrites; use native/fp16/bf16/fp8 kv_dtype")
    B, V = x.shape[:2]
    y = apply_norm(cfg, p["norm1"], x)
    q, k, v = attn.project_qkv(cfg, p["attn"], y, y)
    positions = pos[:, None] + jnp.arange(V, dtype=jnp.int32)[None, :]
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kp, vp = cache["kp"], cache["vp"]
    ps = kp.shape[-2]
    P = page_table.shape[1]
    pidx = page_table[jnp.arange(B)[:, None],
                      jnp.clip(positions // ps, 0, P - 1)]      # (B, V)
    off = positions % ps
    kp = kp.at[pidx, :, off].set(_pool_cast(k, kp.dtype))
    vp = vp.at[pidx, :, off].set(_pool_cast(v, vp.dtype))
    o = paged_gqa_verify(q, kp, vp, page_table, pos, backend=attn_backend)
    o = o.reshape(B, V, cfg.q_dim) @ p["attn"]["wo"].astype(x.dtype)
    x = x + o
    y2 = apply_norm(cfg, p["norm2"], x)
    if cfg.moe is not None:
        f, _ = moe_mod.apply_moe(cfg, p["ffn"], y2)
    else:
        f = ffn_mod.apply_ffn(cfg, p["ffn"], y2)
    x = x + f
    return x, {"kp": kp, "vp": vp}


def _apply_block_shared_prefill(cfg, p: dict, x: jax.Array,
                                positions: jax.Array, pk: jax.Array,
                                pv: jax.Array, kv_block: int,
                                unroll: bool = False,
                                pad_to: Optional[int] = None):
    """Full-attention block over suffix rows against a cached prefix.

    x: (1, S_suf, D) suffix activations; positions: (1, S_suf) absolute
    positions; pk/pv: (1, m, K, h) prefix KV exactly as stored (post-RoPE).
    Attention runs over concat(prefix, suffix) keys with `q_offset = m`.

    `pad_to` fixes the attention width: keys/values are zero-padded (and
    causally masked) to that many positions and contracted as one block.
    With a fixed width, row i's online-softmax reduction tree depends only
    on tokens <= i — so the KV a request computes is **bit-identical**
    whether its prefix rows came from its own prefill or a donor with a
    different continuation. That invariance is what makes prefix-cache
    hits exact; without `pad_to` the reduction width (and hence float
    rounding) varies with total sequence length. Returns (x_out, (k, v))
    with suffix-only KV."""
    m = pk.shape[1]
    y = apply_norm(cfg, p["norm1"], x)
    q, k, v = attn.project_qkv(cfg, p["attn"], y, y)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kk = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
    vv = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
    if pad_to is not None:
        T = kk.shape[1]
        assert pad_to >= T, (pad_to, T)
        pad = ((0, 0), (0, pad_to - T), (0, 0), (0, 0))
        kk, vv = jnp.pad(kk, pad), jnp.pad(vv, pad)
        kv_block = pad_to
    o = attn.blocked_attention(q, kk, vv, causal=True, q_offset=m,
                               kv_block=kv_block, unroll=unroll)
    o = o.reshape(*x.shape[:2], cfg.q_dim)
    x = x + o @ p["attn"]["wo"].astype(x.dtype)
    y2 = apply_norm(cfg, p["norm2"], x)
    if cfg.moe is not None:
        f, _ = moe_mod.apply_moe(cfg, p["ffn"], y2)
    else:
        f = ffn_mod.apply_ffn(cfg, p["ffn"], y2)
    return x + f, (k, v)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass
class DecoderLM:
    cfg: Any
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"            # none | full | dots
    kv_block: int = 1024
    # unroll lax.scan loops (layer stack + attention kv blocks). The dry-run
    # sets this so compiled.cost_analysis() counts every iteration's
    # FLOPs/bytes/collectives — HLO cost analysis visits loop bodies once.
    unroll: bool = False

    # ------------------------------------------------------------- params
    def template(self) -> dict:
        return lm_template(self.cfg)

    def init(self, rng: jax.Array) -> dict:
        return init_params(self.template(), rng)

    def abstract(self, dtype_override: Optional[str] = None):
        return abstract_params(self.template(), dtype_override)

    # ------------------------------------------------------------ helpers
    def _maybe_remat(self, fn):
        if self.remat == "none":
            return fn
        if self.remat == "dots":
            pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            return jax.checkpoint(fn, policy=pol)
        return jax.checkpoint(fn)

    def _stack_forward(self, params: dict, x: jax.Array,
                       positions: jax.Array):
        """Scan over pattern groups + unstacked tail. Returns (x, aux)."""
        cfg = self.cfg
        pat = cfg.block_pattern
        n_rep = cfg.num_layers // len(pat)
        kvb = self.kv_block

        def group(x, slot_params):
            aux = jnp.zeros((), jnp.float32)
            for i, kind in enumerate(pat):
                x, a, _ = apply_block(cfg, kind, slot_params[i], x, positions,
                                      kvb, self.unroll)
                aux = aux + a
            return x, aux

        group = self._maybe_remat(group)

        def body(carry, slot_params):
            x, aux = carry
            x, a = group(x, slot_params)
            return (x, aux + a), None

        if n_rep > 0:
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), tuple(params["blocks"]),
                unroll=n_rep if self.unroll else 1)
        else:
            aux = jnp.zeros((), jnp.float32)
        tail_kinds = cfg.layer_kinds()[n_rep * len(pat):]
        for tp, kind in zip(params["tail"], tail_kinds):
            x, a, _ = apply_block(cfg, kind, tp, x, positions, kvb,
                                  self.unroll)
            aux = aux + a
        return x, aux

    def _embed_inputs(self, params: dict, batch: dict) -> Tuple[jax.Array, jax.Array]:
        """tokens (+ optional prefix embeds) -> (x (B,S,D), positions (B,S))."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S_tok = tokens.shape
        pos_tok = jnp.broadcast_to(jnp.arange(S_tok), (B, S_tok))
        n_pfx = 0
        if cfg.frontend is not None and "prefix_embeds" in batch:
            n_pfx = batch["prefix_embeds"].shape[1]
            pos_tok = pos_tok + n_pfx
        x = embed_tokens(cfg, params["embed"], tokens, pos_tok,
                         self.compute_dtype)
        if n_pfx:
            pr = batch["prefix_embeds"].astype(self.compute_dtype)
            pr = pr @ params["projector"]["w"].astype(self.compute_dtype) \
                + params["projector"]["b"].astype(self.compute_dtype)
            x = jnp.concatenate([pr, x], axis=1)
            positions = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(n_pfx), (B, n_pfx)), pos_tok],
                axis=1)
        else:
            positions = pos_tok
        return x, positions

    # -------------------------------------------------------------- loss
    def loss(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x = constrain(x, P(("pod", "data"), None, None))
        x, aux = self._stack_forward(params, x, positions[0])
        x = apply_norm(cfg, params["final_norm"], x)
        n_pfx = x.shape[1] - batch["tokens"].shape[1]
        if n_pfx:
            x = x[:, n_pfx:, :]
        logits = lm_logits(cfg, params["embed"], x[:, :-1, :])
        labels = batch.get("labels", batch["tokens"])[:, 1:]
        return cross_entropy(logits, labels) + aux

    # ------------------------------------------------------------ prefill
    def prefill(self, params: dict, batch: dict, cache_len: int):
        """Returns (last-position logits, filled cache)."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        S = x.shape[1]
        B = x.shape[0]
        pat = cfg.block_pattern
        n_rep = cfg.num_layers // len(pat)
        kvb = self.kv_block
        cache = init_cache(cfg, B, cache_len, self.compute_dtype)

        # full-sequence forward, capturing per-layer kv / states
        def run_block(x, kind, p, slot_cache):
            if kind in ("full", "local", "chunked"):
                x, _, (k, v) = apply_block(cfg, kind, p, x, positions[0], kvb,
                                           self.unroll)
                T = slot_cache["k"].shape[1]
                if kind == "full" or S <= T:
                    k_w = k[:, :T]
                    v_w = v[:, :T]
                    ck = jax.lax.dynamic_update_slice_in_dim(
                        slot_cache["k"], k_w.astype(slot_cache["k"].dtype), 0, 1)
                    cv = jax.lax.dynamic_update_slice_in_dim(
                        slot_cache["v"], v_w.astype(slot_cache["v"].dtype), 0, 1)
                else:
                    # ring: last T positions at slots (S-T+i) % T
                    kw = k[:, -T:].astype(slot_cache["k"].dtype)
                    vw = v[:, -T:].astype(slot_cache["v"].dtype)
                    idx = (S - T + jnp.arange(T)) % T
                    ck = slot_cache["k"].at[:, idx].set(kw)
                    cv = slot_cache["v"].at[:, idx].set(vw)
                return x, {"k": ck, "v": cv}
            if kind == "rglru":
                y = apply_norm(cfg, p["norm1"], x)
                h_out, final = _rglru_prefill(cfg, p["rec"], y)
                x = x + h_out
                x = x + ffn_mod.apply_ffn(cfg, p["ffn"],
                                          apply_norm(cfg, p["norm2"], x))
                new_c = {"h": final["h"],
                         "conv": final["conv"].astype(slot_cache["conv"].dtype)}
                return x, new_c
            # ssm
            y = apply_norm(cfg, p["norm1"], x)
            h_out, final = _ssm_prefill(cfg, p["ssm"], y)
            x = x + h_out
            new_c = {"state": final["state"],
                     "conv_x": final["conv_x"].astype(slot_cache["conv_x"].dtype),
                     "conv_B": final["conv_B"].astype(slot_cache["conv_B"].dtype),
                     "conv_C": final["conv_C"].astype(slot_cache["conv_C"].dtype)}
            return x, new_c

        def body(x, xs):
            slot_params, slot_caches = xs
            new_caches = []
            for i, kind in enumerate(pat):
                x, nc = run_block(x, kind, slot_params[i], slot_caches[i])
                new_caches.append(nc)
            return x, tuple(new_caches)

        if n_rep > 0:
            x, new_slots = jax.lax.scan(
                body, x, (tuple(params["blocks"]), tuple(cache["slots"])),
                unroll=n_rep if self.unroll else 1)
            cache["slots"] = list(new_slots)
        tail_kinds = cfg.layer_kinds()[n_rep * len(pat):]
        new_tail = []
        for tp, kind, tc in zip(params["tail"], tail_kinds, cache["tail"]):
            x, nc = run_block(x, kind, tp, tc)
            new_tail.append(nc)
        cache["tail"] = new_tail
        cache["pos"] = jnp.asarray(S, jnp.int32)

        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x[:, -1:, :])
        return logits, cache

    # ------------------------------------------------- prefix-hit prefill
    def prefill_shared(self, params: dict, batch: dict, prefix: dict,
                       pad_to: Optional[int] = None):
        """Suffix-only prefill against a cached prompt prefix.

        batch["tokens"]: (1, S_suf) — the prompt tokens *after* the matched
        prefix; `prefix`: the pytree from `gather_prefix_pages` (per-layer
        post-RoPE KV of the matched m tokens). Embeds/ropes the suffix at
        absolute positions [m, m + S_suf) and attends over the concatenated
        keys, so only the suffix's compute is paid — the prefill skip of a
        prefix-cache hit. `pad_to` fixes the attention width for donor-
        independent bit-exactness (see `_apply_block_shared_prefill`); the
        paged batcher passes its slot capacity. Returns (last-position
        logits, suffix KV pytree with leaves (n_rep, 1, S_suf, K, h) ready
        for `write_shared_prefill_to_pages`). Pure full-attention stacks
        only; with an empty prefix (m == 0) this is a full prefill minus
        the dense cache padding.
        """
        cfg = self.cfg
        _require_pure_full(cfg, "prefill_shared")
        if "prefix_embeds" in batch:
            raise NotImplementedError("prefix caching is token-keyed; "
                                      "frontend prefix embeds unsupported")
        tokens = batch["tokens"]
        B, S = tokens.shape
        m = (prefix["slots"][0]["k"].shape[2] if prefix["slots"]
             else prefix["tail"][0]["k"].shape[1])
        positions = jnp.broadcast_to(m + jnp.arange(S), (B, S))
        x = embed_tokens(cfg, params["embed"], tokens, positions,
                         self.compute_dtype)
        pat = cfg.block_pattern
        n_rep = cfg.num_layers // len(pat)
        kvb = self.kv_block

        def body(x, xs):
            slot_params, slot_prefix = xs
            kvs = []
            for i in range(len(pat)):
                x, (k, v) = _apply_block_shared_prefill(
                    cfg, slot_params[i], x, positions, slot_prefix[i]["k"],
                    slot_prefix[i]["v"], kvb, self.unroll, pad_to)
                kvs.append({"k": k, "v": v})
            return x, tuple(kvs)

        if n_rep > 0:
            x, suf_slots = jax.lax.scan(
                body, x, (tuple(params["blocks"]), tuple(prefix["slots"])),
                unroll=n_rep if self.unroll else 1)
            suf_slots = list(suf_slots)
        else:
            suf_slots = []
        suf_tail = []
        for tp, pfx in zip(params["tail"], prefix["tail"]):
            x, (k, v) = _apply_block_shared_prefill(
                cfg, tp, x, positions, pfx["k"], pfx["v"], kvb, self.unroll,
                pad_to)
            suf_tail.append({"k": k, "v": v})

        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x[:, -1:, :])
        return logits, {"slots": suf_slots, "tail": suf_tail}

    # -------------------------------------------------------- decode step
    def decode_step(self, params: dict, cache: dict, tokens: jax.Array):
        """tokens: (B, 1) -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        B = tokens.shape[0]
        x = embed_tokens(cfg, params["embed"], tokens,
                         jnp.broadcast_to(pos, (B, 1)), self.compute_dtype)
        pat = cfg.block_pattern
        n_rep = cfg.num_layers // len(pat)

        def body(x, xs):
            slot_params, slot_caches = xs
            new_caches = []
            for i, kind in enumerate(pat):
                x, nc = apply_block_decode(cfg, kind, slot_params[i], x,
                                           slot_caches[i], pos)
                new_caches.append(nc)
            return x, tuple(new_caches)

        new_cache = dict(cache)
        if n_rep > 0:
            x, new_slots = jax.lax.scan(
                body, x, (tuple(params["blocks"]), tuple(cache["slots"])),
                unroll=n_rep if self.unroll else 1)
            new_cache["slots"] = list(new_slots)
        tail_kinds = cfg.layer_kinds()[n_rep * len(pat):]
        new_tail = []
        for tp, kind, tc in zip(params["tail"], tail_kinds, cache["tail"]):
            x, nc = apply_block_decode(cfg, kind, tp, x, tc, pos)
            new_tail.append(nc)
        new_cache["tail"] = new_tail
        new_cache["pos"] = pos + 1

        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, new_cache

    # ------------------------------------------------- paged decode step
    def decode_step_paged(self, params: dict, cache: dict, tokens: jax.Array,
                          attn_backend: str = "auto"):
        """tokens: (num_slots, 1) against an `init_paged_cache` state.

        Per-slot positions are exact: each slot embeds/ropes at its own
        `pos`, writes its K/V row through its page-table row, and attends
        over exactly `pos + 1` tokens. Inactive slots run masked (null page)
        and their `pos` does not advance."""
        cfg = self.cfg
        pos = cache["pos"]
        page_table = cache["page_table"]
        active = cache["active"]
        x = embed_tokens(cfg, params["embed"], tokens, pos[:, None],
                         self.compute_dtype)
        pat = cfg.block_pattern
        n_rep = cfg.num_layers // len(pat)

        def body(x, xs):
            slot_params, slot_caches = xs
            new_caches = []
            for i, kind in enumerate(pat):
                x, nc = apply_block_decode_paged(
                    cfg, kind, slot_params[i], x, slot_caches[i], pos,
                    page_table, attn_backend)
                new_caches.append(nc)
            return x, tuple(new_caches)

        new_cache = dict(cache)
        if n_rep > 0:
            x, new_slots = jax.lax.scan(
                body, x, (tuple(params["blocks"]), tuple(cache["slots"])),
                unroll=n_rep if self.unroll else 1)
            new_cache["slots"] = list(new_slots)
        tail_kinds = cfg.layer_kinds()[n_rep * len(pat):]
        new_tail = []
        for tp, kind, tc in zip(params["tail"], tail_kinds, cache["tail"]):
            x, nc = apply_block_decode_paged(cfg, kind, tp, x, tc, pos,
                                             page_table, attn_backend)
            new_tail.append(nc)
        new_cache["tail"] = new_tail
        new_cache["pos"] = pos + active.astype(jnp.int32)

        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, new_cache

    # --------------------------------------------- speculative verify step
    def verify_step_paged(self, params: dict, cache: dict, tokens: jax.Array,
                          attn_backend: str = "auto"):
        """tokens: (num_slots, V) — the pending token followed by the
        k = V - 1 drafted candidates — against an `init_paged_cache` state.

        Writes all V K/V rows at positions pos .. pos + V - 1 through the
        page table and scores the whole window in one batched
        `paged_gqa_verify` call; logits[:, v] conditions on tokens[:, :v+1],
        so argmax(logits[:, v]) is the target's greedy continuation after
        consuming candidate v. `pos` is NOT advanced — the speculative
        decode loop owns accept/rollback and moves `pos` by the accepted
        count, which is what makes a rejected suffix roll back for free
        (its rows become garbage past `pos` that the next round overwrites
        before reading). Pure full-attention stacks only: recurrent state
        cannot un-consume a rejected token."""
        cfg = self.cfg
        _require_pure_full(cfg, "verify_step_paged")
        pos = cache["pos"]
        page_table = cache["page_table"]
        B, V = tokens.shape
        positions = pos[:, None] + jnp.arange(V, dtype=jnp.int32)[None, :]
        x = embed_tokens(cfg, params["embed"], tokens, positions,
                         self.compute_dtype)
        pat = cfg.block_pattern
        n_rep = cfg.num_layers // len(pat)

        def body(x, xs):
            slot_params, slot_caches = xs
            new_caches = []
            for i in range(len(pat)):
                x, nc = apply_block_verify_paged(
                    cfg, slot_params[i], x, slot_caches[i], pos, page_table,
                    attn_backend)
                new_caches.append(nc)
            return x, tuple(new_caches)

        new_cache = dict(cache)
        if n_rep > 0:
            x, new_slots = jax.lax.scan(
                body, x, (tuple(params["blocks"]), tuple(cache["slots"])),
                unroll=n_rep if self.unroll else 1)
            new_cache["slots"] = list(new_slots)
        new_tail = []
        for tp, tc in zip(params["tail"], cache["tail"]):
            x, nc = apply_block_verify_paged(cfg, tp, x, tc, pos, page_table,
                                             attn_backend)
            new_tail.append(nc)
        new_cache["tail"] = new_tail

        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, new_cache


def self_spec_draft(model: "DecoderLM", params: dict,
                    skip: int = 2) -> Tuple["DecoderLM", dict]:
    """Self-speculation draft: the target restricted to every `skip`-th
    layer, sharing the target's weights (the stacked block params of the
    single pattern slot are sliced along the repetition axis; embedding,
    final norm and LM head are reused as-is). `skip=1` returns a model
    whose greedy drafts always match the target — a 100%-acceptance oracle
    the bit-identity tests lean on. Single-group block patterns only."""
    cfg = model.cfg
    if len(cfg.block_pattern) != 1:
        raise NotImplementedError(
            "self-speculation slices the stacked params of one pattern "
            f"slot; {cfg.name} has pattern {cfg.block_pattern}")
    if skip < 1:
        raise ValueError(f"skip must be >= 1, got {skip}")
    keep = list(range(0, cfg.num_layers, skip))
    dcfg = dataclasses.replace(cfg, num_layers=len(keep),
                               name=f"{cfg.name}-selfspec{skip}")
    idx = jnp.asarray(keep)
    dparams = dict(params)
    dparams["blocks"] = [jax.tree.map(lambda a: a[idx], params["blocks"][0])]
    dparams["tail"] = []
    draft = DecoderLM(dcfg, compute_dtype=model.compute_dtype,
                      remat=model.remat, kv_block=model.kv_block,
                      unroll=model.unroll)
    return draft, dparams


# ---------------------------------------------------------------------------
# Prefill variants of the recurrent blocks that also return final state
# ---------------------------------------------------------------------------

def _rglru_prefill(cfg, p, x):
    dt_ = x.dtype
    f32 = jnp.float32
    br = jax.nn.gelu(x @ p["w_branch"].astype(dt_))
    u_lin = x @ p["w_rec"].astype(dt_)
    u = rglru_mod._conv_causal(u_lin, p["conv"].astype(dt_))
    uf = u.astype(f32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(f32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(f32))
    a_log = -rglru_mod._C * jax.nn.softplus(p["lam"].astype(f32)) * r
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * a_log), 1e-9)) * (i * uf)
    h, h_last = rglru_mod.rglru_scan(gated, a_log)
    out = (h.astype(dt_) * br) @ p["wo"].astype(dt_)
    cw = cfg.rglru.conv_width
    conv_buf = u_lin[:, -(cw - 1):, :]
    return out, {"h": h_last, "conv": conv_buf}


def _ssm_prefill(cfg, p, x):
    s = cfg.ssm
    b, S, D = x.shape
    di = s.d_inner(D)
    H = s.num_heads(D)
    Pd = s.head_dim
    dt_ = x.dtype
    z = x @ p["wz"].astype(dt_)
    x_lin = x @ p["wx"].astype(dt_)
    B_lin = x @ p["wB"].astype(dt_)
    C_lin = x @ p["wC"].astype(dt_)
    xin = ssm_mod._causal_conv(x_lin, p["conv_x"].astype(dt_))
    Bt = ssm_mod._causal_conv(B_lin, p["conv_B"].astype(dt_))
    Ct = ssm_mod._causal_conv(C_lin, p["conv_C"].astype(dt_))
    dt = jax.nn.softplus((x @ p["wdt"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, S, H, Pd)
    y, final_state = ssm_mod.ssd_chunked(xh, dt, A, Bt, Ct, s.chunk_size)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    out = ssm_mod._gated_out(p, y.reshape(b, S, di), z, dt_)
    cw = s.conv_width
    final = {"state": final_state,
             "conv_x": x_lin[:, -(cw - 1):, :],
             "conv_B": B_lin[:, -(cw - 1):, :],
             "conv_C": C_lin[:, -(cw - 1):, :]}
    return out, final
