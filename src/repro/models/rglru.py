"""RecurrentGemma / Griffin recurrent block: causal conv + RG-LRU gated linear
recurrence. Full-sequence path uses an associative scan (log-depth on TPU);
decode is a single-step recurrence.

RG-LRU (Griffin, arXiv:2402.19427):
    r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import PTpl

_C = 8.0


def rglru_template(cfg) -> dict:
    g = cfg.rglru
    D = cfg.d_model
    w = g.lru_width(D)
    cw = g.conv_width
    return {
        "w_branch": PTpl((D, w), ("embed", "lru")),       # gelu branch
        "w_rec":    PTpl((D, w), ("embed", "lru")),       # conv+LRU branch
        "conv":     PTpl((cw, w), ("conv", "lru"), "normal", 1.0),
        "w_a":      PTpl((w, w), ("lru", "lru")),         # recurrence gate
        "w_i":      PTpl((w, w), ("lru", "lru")),         # input gate
        "lam":      PTpl((w,), ("lru",), "ones"),         # Lambda
        "wo":       PTpl((w, D), ("lru", "embed")),
    }


def _conv_causal(x: jax.Array, w: jax.Array) -> jax.Array:
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def rglru_scan(x: jax.Array, a_log: jax.Array,
               init_h: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    x: gated inputs b_t (B,S,w) fp32; a_log: log a_t (B,S,w) fp32 (<= 0).
    Returns (h (B,S,w), final h (B,w)).
    """
    a = jnp.exp(a_log)
    b = x
    if init_h is not None:
        # fold the carried state into the first step
        b = b.at[:, 0, :].add(a[:, 0, :] * init_h)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h, h[:, -1, :]


def apply_rglru(cfg, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence Griffin recurrent block. x: (B,S,D)."""
    dt_ = x.dtype
    f32 = jnp.float32
    br = jax.nn.gelu(x @ p["w_branch"].astype(dt_))
    u = _conv_causal(x @ p["w_rec"].astype(dt_), p["conv"].astype(dt_))

    uf = u.astype(f32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(f32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(f32))
    a_log = -_C * jax.nn.softplus(p["lam"].astype(f32)) * r
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * a_log), 1e-9)) * (i * uf)
    h, _ = rglru_scan(gated, a_log)
    out = (h.astype(dt_) * br) @ p["wo"].astype(dt_)
    return out


def init_rglru_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    g = cfg.rglru
    w = g.lru_width(cfg.d_model)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, g.conv_width - 1, w), dtype),
    }


def apply_rglru_decode(cfg, p: dict, x: jax.Array, cache: dict):
    """Single-token step. x: (B,1,D)."""
    dt_ = x.dtype
    f32 = jnp.float32
    x1 = x[:, 0, :]
    br = jax.nn.gelu(x1 @ p["w_branch"].astype(dt_))

    u_new = x1 @ p["w_rec"].astype(dt_)
    window = jnp.concatenate([cache["conv"], u_new[:, None, :]], axis=1)
    u = jnp.einsum("bwc,wc->bc", window, p["conv"].astype(dt_))
    new_conv = window[:, 1:, :]

    uf = u.astype(f32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(f32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(f32))
    a_log = -_C * jax.nn.softplus(p["lam"].astype(f32)) * r
    a = jnp.exp(a_log)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * a_log), 1e-9)) * (i * uf)
    h = a * cache["h"] + b
    out = (h.astype(dt_) * br) @ p["wo"].astype(dt_)
    return out[:, None, :], {"h": h, "conv": new_conv}
