"""Logical-axis sharding rules with divisibility fallback.

Every param template leaf carries logical axis names (PTpl.axes). A `Rules`
object maps logical names to an ordered list of mesh-axis candidates; a
candidate is used only when the dim size divides evenly by the mesh axis size
and the mesh axis is not already taken by another dim of the same tensor.
This is what lets e.g. qwen2's 28 query heads compile under 16-way TP — the
packed projection dim (heads*head_dim = 3584) shards even though 28 doesn't.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import PTpl


# Candidate mesh axes per logical axis, in preference order. Entries may be
# tuples (meaning shard over the product of those mesh axes).
TRAIN_RULES = {
    "batch":      [("pod", "data"), ("data",)],
    "seq":        [],                     # sequence kept unsharded in train
    "embed":      [("data",)],            # FSDP: weights sharded over data
    "vocab":      [("model",)],
    "heads":      [("model",)],
    "kv_heads":   [("model",)],
    "head_dim":   [],
    "qkv_out":    [("model",)],           # packed q/k/v projection output
    "mlp":        [("model",)],
    "experts":    [("model",)],
    "layers":     [],
    "seq_table":  [],
    "state":      [],
    "conv":       [],
    "lru":        [("model",)],
    "ssm_inner":  [("model",)],
}

SERVE_RULES = {
    **TRAIN_RULES,
    "batch":      [("data",), ("pod", "data")],
    "embed":      [],                     # no FSDP at serving time
    # decode KV cache: prefer kv-head sharding, fall back to head_dim
    "kv_heads":   [("model",)],
    "head_dim":   [],
    "kv_seq":     [("data",)],            # context parallelism for long decode
}


@dataclass(frozen=True)
class Rules:
    table: dict
    # head_dim may be sharded as a fallback when kv_heads doesn't divide
    kv_head_dim_fallback: bool = True


def axis_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def spec_for(tpl_axes: Sequence[str], shape: Sequence[int], mesh: Mesh,
             rules: dict) -> P:
    used: set = set()
    out = []
    for name, dim in zip(tpl_axes, shape):
        choice = None
        for cand in rules.get(name, []):
            cand_t = cand if isinstance(cand, tuple) else (cand,)
            if any(c in used for c in cand_t):
                continue
            if all(c in mesh.shape for c in cand_t) and dim % axis_size(mesh, cand_t) == 0:
                choice = cand_t if len(cand_t) > 1 else cand_t[0]
                used.update(cand_t)
                break
        out.append(choice)
    return P(*out)


def template_shardings(template, mesh: Mesh, rules: dict):
    """NamedSharding pytree matching a param template pytree."""
    def f(tpl: PTpl):
        return NamedSharding(mesh, spec_for(tpl.axes, tpl.shape, mesh, rules))
    return jax.tree.map(f, template, is_leaf=lambda x: isinstance(x, PTpl))


def template_pspecs(template, mesh: Mesh, rules: dict):
    def f(tpl: PTpl):
        return spec_for(tpl.axes, tpl.shape, mesh, rules)
    return jax.tree.map(f, template, is_leaf=lambda x: isinstance(x, PTpl))


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, global_batch: int, kind: str) -> P:
    """Sharding spec for the leading batch dim of activations/inputs."""
    rules = TRAIN_RULES if kind == "train" else SERVE_RULES
    for cand in rules["batch"]:
        if all(c in mesh.shape for c in cand) and global_batch % axis_size(mesh, cand) == 0:
            return P(cand if len(cand) > 1 else cand[0])
    return P(None)


def constrain(x: jax.Array, mesh: Mesh, spec: P) -> jax.Array:
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
