"""Mamba-2 SSD (state-space duality) block — chunked matmul-form scan for
train/prefill (MXU-friendly), recurrent single-step for decode.

Chunked SSD (Dao & Gu 2024): within a chunk the output is a masked
attention-like quadratic term; across chunks a small recurrence carries the
(H, P, N) state. Both forms are exact — tests check chunked == recurrent.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import PTpl


def ssm_template(cfg) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    N = s.state_dim
    H = s.num_heads(D)
    cw = s.conv_width
    return {
        "wz":  PTpl((D, di), ("embed", "ssm_inner")),
        "wx":  PTpl((D, di), ("embed", "ssm_inner")),
        "wB":  PTpl((D, N), ("embed", "state")),
        "wC":  PTpl((D, N), ("embed", "state")),
        "wdt": PTpl((D, H), ("embed", "heads")),
        "dt_bias": PTpl((H,), ("heads",), "zeros"),
        "A_log": PTpl((H,), ("heads",), "ones"),
        "D": PTpl((H,), ("heads",), "ones"),
        "conv_x": PTpl((cw, di), ("conv", "ssm_inner"), "normal", 1.0),
        "conv_B": PTpl((cw, N), ("conv", "state"), "normal", 1.0),
        "conv_C": PTpl((cw, N), ("conv", "state"), "normal", 1.0),
        "norm": PTpl((di,), ("ssm_inner",), "zeros"),
        "wo":  PTpl((di, D), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (cw,C)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return jax.nn.silu(out)


def _conv_step(x_new: jax.Array, buf: jax.Array, w: jax.Array):
    """Single-token causal conv. x_new (B,C), buf (B,cw-1,C) past inputs."""
    window = jnp.concatenate([buf, x_new[:, None, :]], axis=1)   # (B,cw,C)
    out = jnp.einsum("bwc,wc->bc", window, w)
    new_buf = window[:, 1:, :]
    return jax.nn.silu(out), new_buf


def _gated_out(p, y: jax.Array, z: jax.Array, dtype) -> jax.Array:
    """y * silu(z) -> rmsnorm -> out_proj."""
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["norm"].astype(jnp.float32))
    return (g.astype(dtype)) @ p["wo"].astype(dtype)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Exact chunked SSD.

    x:  (b, S, H, P) head inputs
    dt: (b, S, H) positive step sizes
    A:  (H,) negative decay rates
    B, C: (b, S, N) input/output projections (single group)
    Returns (y (b,S,H,P), final_state (b,H,P,N)).
    """
    b, S, H, Pd = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    f32 = jnp.float32

    xq = x.reshape(b, nc, Q, H, Pd).astype(f32)
    dtq = dt.reshape(b, nc, Q, H).astype(f32)
    Bq = B.reshape(b, nc, Q, N).astype(f32)
    Cq = C.reshape(b, nc, Q, N).astype(f32)

    la = dtq * A[None, None, None, :]             # log decay per step (<= 0)
    cum = jnp.cumsum(la, axis=2)                  # (b,nc,Q,H) from chunk start

    # --- intra-chunk (quadratic, causal-masked) ------------------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (b,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cq, Bq)           # (b,nc,Q,Q)
    M = scores[..., None] * L * dtq[:, :, None, :, :]        # weight dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xq)

    # --- chunk states ---------------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (b,nc,Q,H)
    ZB = Bq[:, :, :, None, :] * (dtq * decay_to_end)[..., None]  # (b,nc,Q,H,N)
    S_c = jnp.einsum("bcqhn,bcqhp->bchpn", ZB, xq)           # (b,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (b,nc,H)

    # --- inter-chunk recurrence (small sequential scan over nc) ---------------
    if init_state is None:
        init_state = jnp.zeros((b, H, Pd, N), f32)

    def body(s_prev, inp):
        dec, s_c = inp                                       # (b,H), (b,H,P,N)
        s_new = s_prev * dec[:, :, None, None] + s_c
        return s_new, s_prev

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)                  # (nc,b,H)
    sc_t = jnp.moveaxis(S_c, 1, 0)                           # (nc,b,H,P,N)
    final_state, s_prevs = jax.lax.scan(body, init_state.astype(f32),
                                        (dec_t, sc_t))
    S_prev = jnp.moveaxis(s_prevs, 0, 1)                     # (b,nc,H,P,N)

    # --- inter-chunk contribution ---------------------------------------------
    Cdec = Cq[:, :, :, None, :] * jnp.exp(cum)[..., None]    # (b,nc,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Cdec, S_prev)

    y = (y_intra + y_inter).reshape(b, S, H, Pd)
    return y, final_state


def ssd_step(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
             B: jax.Array, C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence.

    state (b,H,P,N); x (b,H,P); dt (b,H); B,C (b,N).
    """
    f32 = jnp.float32
    a = jnp.exp(dt.astype(f32) * A[None, :])                 # (b,H)
    upd = (dt.astype(f32)[:, :, None, None]
           * x.astype(f32)[..., None] * B.astype(f32)[:, None, None, :])
    new_state = state * a[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(f32))
    return y, new_state


def apply_ssm(cfg, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence SSD block (train/prefill). x: (B,S,D)."""
    s = cfg.ssm
    b, S, D = x.shape
    di = s.d_inner(D)
    H = s.num_heads(D)
    Pd = s.head_dim
    dt_ = x.dtype

    z = x @ p["wz"].astype(dt_)
    xin = _causal_conv(x @ p["wx"].astype(dt_), p["conv_x"].astype(dt_))
    Bt = _causal_conv(x @ p["wB"].astype(dt_), p["conv_B"].astype(dt_))
    Ct = _causal_conv(x @ p["wC"].astype(dt_), p["conv_C"].astype(dt_))
    dt = jax.nn.softplus((x @ p["wdt"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xin.reshape(b, S, H, Pd)
    y, _ = ssd_chunked(xh, dt, A, Bt, Ct, s.chunk_size)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    return _gated_out(p, y.reshape(b, S, di), z, dt_)


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.num_heads(cfg.d_model)
    return {
        "state": jnp.zeros((batch, H, s.head_dim, s.state_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, s.conv_width - 1, di), dtype),
        "conv_B": jnp.zeros((batch, s.conv_width - 1, s.state_dim), dtype),
        "conv_C": jnp.zeros((batch, s.conv_width - 1, s.state_dim), dtype),
    }


def apply_ssm_decode(cfg, p: dict, x: jax.Array, cache: dict):
    """Single-token step. x: (B,1,D) -> (y (B,1,D), new_cache)."""
    s = cfg.ssm
    b, _, D = x.shape
    di = s.d_inner(D)
    H = s.num_heads(D)
    Pd = s.head_dim
    dt_ = x.dtype
    x1 = x[:, 0, :]

    z = x1 @ p["wz"].astype(dt_)
    xin, cx = _conv_step(x1 @ p["wx"].astype(dt_), cache["conv_x"],
                         p["conv_x"].astype(dt_))
    Bt, cB = _conv_step(x1 @ p["wB"].astype(dt_), cache["conv_B"],
                        p["conv_B"].astype(dt_))
    Ct, cC = _conv_step(x1 @ p["wC"].astype(dt_), cache["conv_C"],
                        p["conv_C"].astype(dt_))
    dt = jax.nn.softplus((x1 @ p["wdt"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xin.reshape(b, H, Pd)
    y, new_state = ssd_step(cache["state"], xh, dt, A, Bt, Ct)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    out = _gated_out(p, y.reshape(b, di), z, dt_)
    new_cache = {"state": new_state, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return out[:, None, :], new_cache
