"""Attention: MHA / GQA / MQA with full, sliding-window (local) and chunked
variants; blocked (flash-style) prefill/train path and single-token decode path.

The blocked jnp implementation is the portable path (and the oracle the Pallas
kernels are tested against); `use_kernels=True` in ops selects the Pallas TPU
kernels at runtime.

Memory note: naive attention at seq 32k would materialize S×S scores; the
blocked path keeps O(S × kv_block) live, which is what lets the 32k prefill
dry-run fit in HBM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import PTpl, apply_rope

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def attn_template(cfg, cross: bool = False) -> dict:
    D, Q, KV = cfg.d_model, cfg.q_dim, cfg.kv_dim
    t = {
        "wq": PTpl((D, Q), ("embed", "qkv_out")),
        "wk": PTpl((D, KV), ("embed", "qkv_out")),
        "wv": PTpl((D, KV), ("embed", "qkv_out")),
        "wo": PTpl((Q, D), ("qkv_out", "embed")),
    }
    if cfg.attn_bias and not cross:
        t["bq"] = PTpl((Q,), ("qkv_out",), "zeros")
        t["bk"] = PTpl((KV,), ("qkv_out",), "zeros")
        t["bv"] = PTpl((KV,), ("qkv_out",), "zeros")
    return t


def project_qkv(cfg, p: dict, xq: jax.Array, xkv: jax.Array):
    """(B,S,D)->(B,S,H,h) and (B,T,D)->(B,T,K,h)."""
    B, S, _ = xq.shape
    T = xkv.shape[1]
    q = xq @ p["wq"].astype(xq.dtype)
    k = xkv @ p["wk"].astype(xq.dtype)
    v = xkv @ p["wv"].astype(xq.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention — full / causal
# ---------------------------------------------------------------------------

def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_offset: int = 0,
                      kv_block: int = 1024, unroll: bool = False) -> jax.Array:
    """Online-softmax attention over KV blocks.

    q: (B, S, H, h); k, v: (B, T, K, h) with H % K == 0 (GQA groups).
    Returns (B, S, H, h). fp32 accumulators; output in q.dtype.
    """
    B, S, H, h = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    kv_block = min(kv_block, T)
    assert T % kv_block == 0, (T, kv_block)
    nb = T // kv_block
    scale = 1.0 / jnp.sqrt(jnp.float32(h))

    # keep operands in the input dtype (bf16 on TPU -> MXU) and accumulate in
    # fp32 — halves the live fp32 working set vs upcasting q and p
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, S, K, G, h)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, i):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, i * kv_block, kv_block, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * kv_block, kv_block, 1)
        s = jnp.einsum("bskgh,btkh->bskgt", qg, ks,
                       preferred_element_type=jnp.float32)
        if causal:
            kv_pos = i * kv_block + jnp.arange(kv_block)
            valid = q_pos[:, None] >= kv_pos[None, :]         # (S, blk)
            s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkh->bskgh", p.astype(q.dtype), vs,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, K, G), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, h), jnp.float32)
    # unroll=True is used by the dry-run so HLO cost analysis sees every
    # block's FLOPs (loop bodies are otherwise counted once)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nb),
                                  unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, h).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked attention (llama4): attention restricted to chunks of size W
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      window: int) -> jax.Array:
    from repro.models.meshctx import constrain
    from jax.sharding import PartitionSpec as P
    B, S, H, h = q.shape
    K = k.shape[2]
    G = H // K
    W = min(window, S)
    assert S % W == 0, (S, W)
    nc = S // W
    scale = 1.0 / jnp.sqrt(jnp.float32(h))
    qc = q.reshape(B, nc, W, K, G, h).astype(jnp.float32) * scale
    kc = k.reshape(B, nc, W, K, h).astype(jnp.float32)
    vc = v.reshape(B, nc, W, K, h).astype(jnp.float32)
    # Perf iteration D1: shard query rows within each chunk over "model",
    # replicate the (GQA-small) K/V — same sequence-parallel scheme as the
    # full-attention path, applied intra-chunk.
    bspec = ("pod", "data")
    qc = constrain(qc, P(bspec, None, "model", None, None, None))
    kc = constrain(kc, P(bspec, None, None, None, None))
    vc = constrain(vc, P(bspec, None, None, None, None))
    s = jnp.einsum("bcskgh,bctkh->bcskgt", qc, kc)
    causal = jnp.arange(W)[:, None] >= jnp.arange(W)[None, :]
    s = jnp.where(causal[None, None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bcskgt,bctkh->bcskgh", p, vc)
    return out.reshape(B, S, H, h).astype(q.dtype)


# ---------------------------------------------------------------------------
# Sliding-window (local) attention: each position sees the last `window` keys
# ---------------------------------------------------------------------------

def local_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: int) -> jax.Array:
    B, S, H, h = q.shape
    K = k.shape[2]
    G = H // K
    W = min(window, S)
    assert S % W == 0, (S, W)
    nc = S // W
    scale = 1.0 / jnp.sqrt(jnp.float32(h))
    qc = q.reshape(B, nc, W, K, G, h).astype(jnp.float32) * scale
    kc = k.reshape(B, nc, W, K, h)
    vc = v.reshape(B, nc, W, K, h)
    # Perf iteration D1 (see chunked_attention)
    from repro.models.meshctx import constrain
    from jax.sharding import PartitionSpec as P
    bspec = ("pod", "data")
    qc = constrain(qc, P(bspec, None, "model", None, None, None))
    kc = constrain(kc, P(bspec, None, None, None, None))
    vc = constrain(vc, P(bspec, None, None, None, None))
    # each q chunk attends to [prev chunk, own chunk] = 2W keys
    zpad = jnp.zeros_like(kc[:, :1])
    kprev = jnp.concatenate([zpad, kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kc], axis=2).astype(jnp.float32)  # (B,nc,2W,K,h)
    v2 = jnp.concatenate([vprev, vc], axis=2).astype(jnp.float32)
    s = jnp.einsum("bcskgh,bctkh->bcskgt", qc, k2)
    q_pos = jnp.arange(W)[:, None]               # within chunk
    kv_pos = jnp.arange(2 * W)[None, :] - W      # relative to chunk start
    valid = (q_pos >= kv_pos) & (q_pos - kv_pos < W)
    # chunk 0 has no previous chunk
    chunk_ok = jnp.ones((nc, 1, 1), bool).at[0].set(False)
    valid2 = valid[None, :, :] & (chunk_ok | (kv_pos >= 0)[None, :, :])
    s = jnp.where(valid2[None, :, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bcskgt,bctkh->bcskgh", p, v2)
    return out.reshape(B, S, H, h).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder): full, non-causal
# ---------------------------------------------------------------------------

def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    kv_block: int = 1024, unroll: bool = False) -> jax.Array:
    return blocked_attention(q, k, v, causal=False, kv_block=kv_block,
                             unroll=unroll)


# ---------------------------------------------------------------------------
# Decode: one new token against a cache
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_mask: jax.Array) -> jax.Array:
    """q: (B, 1, H, h); caches: (B, T, K, h); valid_mask: (B, T) or (T,) bool.

    Plain einsum decode — scores are (B, H, T) which is small even at T=524288.
    """
    from repro.models.meshctx import constrain
    from jax.sharding import PartitionSpec as P
    B, _, H, h = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / jnp.sqrt(jnp.float32(h))
    qg = q.reshape(B, K, G, h).astype(jnp.float32) * scale
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache.astype(jnp.float32))
    # Perf iteration F1: keep scores batch-sharded over "data" and
    # seq-sharded over "model" (matching the cache layout) — on the
    # multi-pod mesh SPMD otherwise batch-gathers the fp32 scores/cache.
    s = constrain(s, P("data", None, None, "model"))
    if valid_mask.ndim == 1:
        valid = valid_mask[None, None, None, :]
    else:
        valid = valid_mask[:, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid, p, 0.0)
    p = constrain(p, P("data", None, None, "model"))
    out = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(jnp.float32))
    out = constrain(out, P("data", None, None, None))
    return out.reshape(B, 1, H, h).astype(q.dtype)


def cache_write(cache_k, cache_v, k, v, write_idx):
    """Functional KV cache update at a dynamic position (ring or linear).

    cache_*: (B, T, K, h); k, v: (B, 1, K, h); write_idx: scalar int.
    """
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                             write_idx, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                             write_idx, axis=1)
    return ck, cv


def decode_valid_mask(kind: str, cache_len: int, pos: jax.Array,
                      window: int = 0) -> jax.Array:
    """Which cache slots are attendable for a query at absolute position `pos`.

    kind=full   : linear cache, slots [0, pos] valid.
    kind=local  : ring cache of size `window` holding the last W positions.
    kind=chunked: ring cache of size `window`; only slots from the current
                  chunk (absolute positions >= pos - pos % W) are valid.
    """
    idx = jnp.arange(cache_len)
    if kind == "full":
        return idx <= pos
    W = window
    assert cache_len == W, (cache_len, W)
    if kind == "local":
        return (idx <= pos) | (pos >= W)
    if kind == "chunked":
        return idx <= (pos % W)
    raise ValueError(kind)
