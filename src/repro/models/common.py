"""Shared model building blocks: param templates, norms, embeddings, RoPE."""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Param templates
#
# A model is described by a pytree of `PTpl` leaves (shape + logical axes +
# init). From one template we derive (a) materialized params, (b) abstract
# ShapeDtypeStructs for the dry-run, and (c) NamedShardings via the rules in
# models/sharding.py. This keeps init / sharding / lowering in lock-step.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PTpl:
    shape: tuple
    axes: tuple                  # logical axis name per dim (len == ndim)
    init: str = "normal"         # normal | zeros | ones | embed
    scale: float = 1.0           # stddev multiplier for "normal"
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_rng(rng: jax.Array, path: str) -> jax.Array:
    # deterministic per-leaf rng: fold in a stable hash of the tree path
    # (crc32, NOT python hash() — that one is salted per process)
    import zlib
    h = np.uint32(zlib.crc32(path.encode()) & 0x7FFFFFFF)
    return jax.random.fold_in(rng, h)


def init_param(tpl: PTpl, rng: jax.Array, path: str) -> jax.Array:
    dtype = jnp.dtype(tpl.dtype)
    if tpl.init == "zeros":
        return jnp.zeros(tpl.shape, dtype)
    if tpl.init == "ones":
        return jnp.ones(tpl.shape, dtype)
    fan_in = tpl.shape[-2] if len(tpl.shape) >= 2 else tpl.shape[-1]
    std = tpl.scale / math.sqrt(max(1, fan_in))
    if tpl.init == "embed":
        std = tpl.scale * 0.02
    x = jax.random.normal(_leaf_rng(rng, path), tpl.shape, jnp.float32) * std
    return x.astype(dtype)


def init_params(template, rng: jax.Array):
    """Materialize a param pytree from a template pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: isinstance(x, PTpl))
    leaves = []
    for path, tpl in flat:
        pstr = jax.tree_util.keystr(path)
        leaves.append(init_param(tpl, rng, pstr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(template, dtype_override: Optional[str] = None):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    def f(tpl: PTpl):
        dt = jnp.dtype(dtype_override or tpl.dtype)
        return jax.ShapeDtypeStruct(tpl.shape, dt)
    return jax.tree.map(f, template, is_leaf=lambda x: isinstance(x, PTpl))


def cast_params(params, dtype):
    def f(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(f, params)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_template(cfg, axes=("embed",)) -> dict:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": PTpl((d,), axes, "ones"),
                "bias": PTpl((d,), axes, "zeros")}
    return {"scale": PTpl((d,), axes, "zeros")}  # rmsnorm stores (scale - 1)


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def stack_tpl(tpl, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for lax.scan over layers) to every template leaf."""
    def f(t: PTpl):
        return PTpl((n,) + t.shape, (axis_name,) + t.axes, t.init, t.scale, t.dtype)
    return jax.tree.map(f, tpl, is_leaf=lambda x: isinstance(x, PTpl))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    *_, s, h, d = x.shape
    freqs = rope_frequencies(d, theta)                       # (d/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_template(cfg) -> dict:
    t = {"tok": PTpl((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), "embed")}
    if cfg.pos_emb == "learned":
        table = min(cfg.max_seq_len, 32768)
        t["pos"] = PTpl((table, cfg.d_model), ("seq_table", "embed"), "embed")
    if not cfg.tie_embeddings:
        t["head"] = PTpl((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                         "normal")
    return t


def embed_tokens(cfg, p: dict, tokens: jax.Array, positions: jax.Array,
                 dtype) -> jax.Array:
    x = p["tok"].astype(dtype)[tokens]
    if cfg.pos_emb == "learned":
        table = p["pos"].shape[0]
        x = x + p["pos"].astype(dtype)[jnp.clip(positions, 0, table - 1)]
    return x


def lm_logits(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["tok"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["head"].astype(x.dtype))
    # mask vocab padding
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        mask = jnp.concatenate([jnp.zeros((cfg.vocab_size,), logits.dtype),
                                jnp.full((pad,), -1e9, logits.dtype)])
        logits = logits + mask
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Mean CE over non-ignored positions; logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
