from repro.models.factory import build_model, batch_struct, cache_struct, concrete_batch  # noqa: F401
from repro.models.transformer import DecoderLM, init_cache, cache_specs  # noqa: F401
from repro.models.encdec import EncDecModel  # noqa: F401
