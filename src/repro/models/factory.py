"""Model factory + input specs: build the right model class for an arch config
and produce either concrete batches (smoke tests) or ShapeDtypeStruct stand-ins
(dry-run) for every (arch × shape) cell.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.encdec import EncDecModel
from repro.models.transformer import DecoderLM, init_cache


def build_model(cfg: ArchConfig, *, compute_dtype=jnp.bfloat16,
                remat: str = "full", kv_block: int = 1024,
                unroll: bool = False):
    if cfg.is_encdec:
        return EncDecModel(cfg, compute_dtype=compute_dtype, remat=remat,
                           kv_block=kv_block, unroll=unroll)
    return DecoderLM(cfg, compute_dtype=compute_dtype, remat=remat,
                     kv_block=kv_block, unroll=unroll)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs) per (arch × shape) — the dry-run contract
# ---------------------------------------------------------------------------

def batch_struct(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for the given cell's step function.

    train  -> kwargs of train_step(params, opt_state, batch)
    prefill-> kwargs of prefill(params, batch)
    decode -> kwargs of decode_step(params, cache, tokens)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if cfg.is_encdec:
        if shape.kind == "train":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        # decode: one token; cache built separately
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    if cfg.frontend is not None:
        n_pfx = cfg.frontend.num_prefix_tokens
        if shape.kind in ("train", "prefill"):
            d: Dict[str, Any] = {
                "prefix_embeds": jax.ShapeDtypeStruct(
                    (B, n_pfx, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S - n_pfx), i32),
            }
            if shape.kind == "train":
                d["labels"] = jax.ShapeDtypeStruct((B, S - n_pfx), i32)
            return d
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def cache_struct(cfg: ArchConfig, shape: ShapeConfig,
                 dtype=jnp.bfloat16) -> Any:
    """Abstract decode cache for decode cells (cache length = shape.seq_len)."""
    assert shape.kind == "decode"
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        from repro.models.encdec import EncDecModel
        m = EncDecModel(cfg, compute_dtype=dtype)
        # memory length: frontend tokens (encoder output len); self cache S
        mem_len = 4096
        return jax.eval_shape(lambda: m.init_cache(B, S, mem_len))
    return jax.eval_shape(lambda: init_cache(cfg, B, S, dtype))


def concrete_batch(cfg: ArchConfig, shape_kind: str, batch: int, seq: int,
                   rng: Optional[jax.Array] = None) -> Dict[str, Any]:
    """Small concrete batch for smoke tests (CPU)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    kt, kf = jax.random.split(rng)
    V = cfg.vocab_size
    if cfg.is_encdec:
        d = {"frames": jax.random.normal(kf, (batch, seq, cfg.d_model),
                                         jnp.float32).astype(jnp.bfloat16),
             "tokens": jax.random.randint(kt, (batch, seq), 0, V, jnp.int32)}
        if shape_kind == "train":
            d["labels"] = d["tokens"]
        return d
    d = {}
    s_tok = seq
    if cfg.frontend is not None:
        n_pfx = cfg.frontend.num_prefix_tokens
        d["prefix_embeds"] = jax.random.normal(
            kf, (batch, n_pfx, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
        s_tok = max(1, seq - n_pfx)
    d["tokens"] = jax.random.randint(kt, (batch, s_tok), 0, V, jnp.int32)
    if shape_kind == "train":
        d["labels"] = d["tokens"]
    return d
