"""Feed-forward variants: SwiGLU, GELU MLP, GeGLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import PTpl


def ffn_template(cfg, d_ff: int = 0) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    if cfg.ffn_kind in ("swiglu", "geglu"):
        return {
            "w_gate": PTpl((D, F), ("embed", "mlp")),
            "w_up":   PTpl((D, F), ("embed", "mlp")),
            "w_down": PTpl((F, D), ("mlp", "embed")),
        }
    return {   # gelu_mlp
        "w_up":   PTpl((D, F), ("embed", "mlp")),
        "b_up":   PTpl((F,), ("mlp",), "zeros"),
        "w_down": PTpl((F, D), ("mlp", "embed")),
        "b_down": PTpl((D,), ("embed",), "zeros"),
    }


def apply_ffn(cfg, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.ffn_kind == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"].astype(dt))
        u = x @ p["w_up"].astype(dt)
        return (g * u) @ p["w_down"].astype(dt)
    if cfg.ffn_kind == "geglu":
        g = jax.nn.gelu(x @ p["w_gate"].astype(dt))
        u = x @ p["w_up"].astype(dt)
        return (g * u) @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)
