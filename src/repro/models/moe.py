"""Capacity-factor token-choice MoE (GShard/Switch style), scatter-based.

Instead of the classic (tokens × experts × capacity) dispatch one-hot einsum —
which is O(T·E·C) memory and unusable at 32k sequence — tokens are scattered
into a per-expert buffer of shape (E, C, D) and gathered back. Under GSPMD the
buffer is sharded over the "model" axis (expert parallelism) so the scatter
lowers to all-to-all style collectives.

Top-k routing with renormalized probabilities, capacity dropping, and a
Switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import PTpl
from repro.models.meshctx import constrain


def moe_template(cfg) -> dict:
    m = cfg.moe
    D, F, E = cfg.d_model, m.d_ff_expert, m.num_experts
    assert cfg.ffn_kind in ("swiglu", "geglu"), "MoE experts use gated FFNs"
    t = {
        "router": PTpl((D, E), ("embed", "experts"), "normal"),
        "w_gate": PTpl((E, D, F), ("experts", "embed", "mlp")),
        "w_up":   PTpl((E, D, F), ("experts", "embed", "mlp")),
        "w_down": PTpl((E, F, D), ("experts", "mlp", "embed")),
    }
    if m.shared_expert:
        t["shared"] = {
            "w_gate": PTpl((D, F), ("embed", "mlp")),
            "w_up":   PTpl((D, F), ("embed", "mlp")),
            "w_down": PTpl((F, D), ("mlp", "embed")),
        }
    return t


def capacity(num_tokens: int, cfg) -> int:
    m = cfg.moe
    c = math.ceil(num_tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)


def _act(cfg):
    return jax.nn.silu if cfg.ffn_kind == "swiglu" else jax.nn.gelu


def apply_moe(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    T = B * S
    C = capacity(T, cfg)
    xf = x.reshape(T, D)

    # ---- routing (fp32) ----------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Switch-style load-balance loss on the top-1 assignment.
    f = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    pm = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * pm) * m.aux_loss_weight

    # ---- dispatch: rank each (token, choice) copy within its expert --------
    # Sort-based ranking (Perf iteration E1): the textbook one-hot cumsum is
    # O(N*E) and lowers to a quadratic-cost reduce-window; a stable argsort by
    # expert id + per-expert start offsets is O(N log N) and gives identical
    # ranks (stable sort preserves token order within an expert).
    eid = top_e.reshape(T * k)                                   # (N,)
    gate = top_p.reshape(T * k).astype(x.dtype)
    src = jnp.repeat(jnp.arange(T), k)                           # (N,)
    N = T * k
    order = jnp.argsort(eid, stable=True)                        # (N,)
    hist = jnp.zeros((E,), jnp.int32).at[eid].add(1)
    starts = jnp.cumsum(hist) - hist                             # (E,) tiny
    rank_sorted = jnp.arange(N, dtype=jnp.int32) - starts[eid[order]]
    pos = jnp.zeros((N,), jnp.int32).at[order].set(rank_sorted)
    keep = pos < C
    slot = jnp.where(keep, eid * C + pos, E * C)                 # drop -> spill row

    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].add(xf[src])
    buf = buf[: E * C].reshape(E, C, D)
    buf = constrain(buf, P("model", None, None))                 # expert parallel

    # ---- expert computation (batched over experts) --------------------------
    act = _act(cfg)
    g = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    eo = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(x.dtype))
    eo = constrain(eo, P("model", None, None))

    # ---- combine: gather expert outputs back to tokens ----------------------
    eo_flat = jnp.concatenate(
        [eo.reshape(E * C, D), jnp.zeros((1, D), x.dtype)], axis=0)
    y = eo_flat[slot] * gate[:, None]                            # gate at combine
    out = jnp.zeros((T, D), x.dtype).at[src].add(y)

    if m.shared_expert:
        sp = p["shared"]
        sg = act(xf @ sp["w_gate"].astype(x.dtype))
        su = xf @ sp["w_up"].astype(x.dtype)
        out = out + (sg * su) @ sp["w_down"].astype(x.dtype)

    return out.reshape(B, S, D), aux
