"""Ambient mesh context so model code can apply sharding constraints without
threading a Mesh through every call. CPU tests run mesh-free (constraints
become no-ops)."""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT: list = []


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    _CURRENT.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _CURRENT.pop()


def current_mesh() -> Optional[Mesh]:
    return _CURRENT[-1] if _CURRENT else None


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity.

    Drops spec entries for mesh axes that don't exist (e.g. "pod" on the
    single-pod mesh) and for dims that don't divide evenly.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    fixed = []
    for dim, entry in zip(x.shape, spec + (None,) * (x.ndim - len(spec))):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in mesh.shape)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if not axes or dim % size != 0:
            fixed.append(None)
        else:
            fixed.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
