"""Encoder-decoder transformer (seamless-m4t backbone). The audio frontend is
a stub: the encoder consumes precomputed frame embeddings (B, S_enc, D).

Entry points mirror DecoderLM: loss / prefill / decode_step, where prefill
runs the encoder once, fills the decoder self-attention cache, and caches the
cross-attention K/V projected from the encoder memory.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models.common import (abstract_params, apply_norm, apply_rope,
                                 cross_entropy, embed_template, embed_tokens,
                                 init_params, lm_logits, norm_template,
                                 stack_tpl)
from repro.models.meshctx import constrain


def enc_block_template(cfg) -> dict:
    return {"norm1": norm_template(cfg), "attn": attn.attn_template(cfg),
            "norm2": norm_template(cfg), "ffn": ffn_mod.ffn_template(cfg)}


def dec_block_template(cfg) -> dict:
    return {"norm1": norm_template(cfg), "self_attn": attn.attn_template(cfg),
            "norm_c": norm_template(cfg),
            "cross_attn": attn.attn_template(cfg, cross=True),
            "norm2": norm_template(cfg), "ffn": ffn_mod.ffn_template(cfg)}


def encdec_template(cfg) -> dict:
    from repro.models.common import PTpl
    return {
        "embed": embed_template(cfg),
        "enc_pos": PTpl((min(cfg.max_seq_len, 32768), cfg.d_model),
                        ("seq_table", "embed"), "embed"),
        "encoder": stack_tpl(enc_block_template(cfg), cfg.encoder_layers),
        "enc_norm": norm_template(cfg),
        "decoder": stack_tpl(dec_block_template(cfg), cfg.num_layers),
        "final_norm": norm_template(cfg),
    }


@dataclass
class EncDecModel:
    cfg: Any
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"
    kv_block: int = 1024
    unroll: bool = False          # dry-run: unroll scans for cost analysis

    def template(self) -> dict:
        return encdec_template(self.cfg)

    def init(self, rng: jax.Array) -> dict:
        return init_params(self.template(), rng)

    def abstract(self, dtype_override: Optional[str] = None):
        return abstract_params(self.template(), dtype_override)

    # ----------------------------------------------------------- encoder
    def encode(self, params: dict, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        dt = self.compute_dtype
        B, S, _ = frames.shape
        table = params["enc_pos"].shape[0]
        pos = jnp.clip(jnp.arange(S), 0, table - 1)
        x = frames.astype(dt) + params["enc_pos"].astype(dt)[pos]
        x = constrain(x, P(("pod", "data"), None, None))

        def block(x, p):
            y = apply_norm(cfg, p["norm1"], x)
            q, k, v = attn.project_qkv(cfg, p["attn"], y, y)
            o = attn.blocked_attention(q, k, v, causal=False,
                                       kv_block=self.kv_block,
                                       unroll=self.unroll)
            o = o.reshape(B, S, cfg.q_dim) @ p["attn"]["wo"].astype(dt)
            x = x + o
            x = x + ffn_mod.apply_ffn(cfg, p["ffn"],
                                      apply_norm(cfg, p["norm2"], x))
            return constrain(x, P(("pod", "data"), None, None))

        if self.remat != "none":
            block = jax.checkpoint(block)

        def body(x, p):
            return block(x, p), None

        x, _ = jax.lax.scan(body, x, params["encoder"],
                            unroll=self.cfg.encoder_layers if self.unroll
                            else 1)
        return apply_norm(cfg, params["enc_norm"], x)

    # ----------------------------------------------------------- decoder
    def _dec_block(self, p, x, memory, positions, causal_offset=0):
        cfg = self.cfg
        dt = self.compute_dtype
        B, S, _ = x.shape
        y = apply_norm(cfg, p["norm1"], x)
        q, k, v = attn.project_qkv(cfg, p["self_attn"], y, y)
        o = attn.blocked_attention(q, k, v, causal=True,
                                   q_offset=causal_offset,
                                   kv_block=self.kv_block,
                                   unroll=self.unroll)
        x = x + o.reshape(B, S, cfg.q_dim) @ p["self_attn"]["wo"].astype(dt)
        y = apply_norm(cfg, p["norm_c"], x)
        qc, kc, vc = attn.project_qkv(cfg, p["cross_attn"], y, memory)
        oc = attn.cross_attention(qc, kc, vc, kv_block=self.kv_block,
                                  unroll=self.unroll)
        x = x + oc.reshape(B, S, cfg.q_dim) @ p["cross_attn"]["wo"].astype(dt)
        x = x + ffn_mod.apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["norm2"], x))
        return constrain(x, P(("pod", "data"), None, None)), (k, v, kc, vc)

    # -------------------------------------------------------------- loss
    def loss(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        dt = self.compute_dtype
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = embed_tokens(cfg, params["embed"], tokens, positions, dt)

        blk = self._dec_block
        if self.remat != "none":
            blk = jax.checkpoint(blk, static_argnums=())

        def body(x, p):
            x, _ = blk(p, x, memory, positions)
            return x, None

        x, _ = jax.lax.scan(body, x, params["decoder"],
                            unroll=self.cfg.num_layers if self.unroll else 1)
        del blk
        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x[:, :-1, :])
        labels = batch.get("labels", tokens)[:, 1:]
        return cross_entropy(logits, labels)

    # ------------------------------------------------------------ prefill
    def init_cache(self, batch: int, cache_len: int, mem_len: int):
        cfg = self.cfg
        L = cfg.num_layers
        z = jnp.zeros((L, batch, cache_len, cfg.num_kv_heads, cfg.head_dim),
                      self.compute_dtype)
        zc = jnp.zeros((L, batch, mem_len, cfg.num_kv_heads, cfg.head_dim),
                       self.compute_dtype)
        return {"k": z, "v": z, "ck": zc, "cv": zc,
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params: dict, batch: dict, cache_len: int):
        cfg = self.cfg
        dt = self.compute_dtype
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        mem_len = memory.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = embed_tokens(cfg, params["embed"], tokens, positions, dt)
        cache = self.init_cache(B, cache_len, mem_len)

        def body(x, p):
            x, (k, v, kc, vc) = self._dec_block(p, x, memory, positions)
            return x, (k.astype(dt), v.astype(dt), kc.astype(dt),
                       vc.astype(dt))

        x, (ks, vs, cks, cvs) = jax.lax.scan(
            body, x, params["decoder"],
            unroll=self.cfg.num_layers if self.unroll else 1)
        T = cache_len
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], ks[:, :, :T], 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vs[:, :, :T], 0, axis=2)
        cache["ck"] = cks
        cache["cv"] = cvs
        cache["pos"] = jnp.asarray(S, jnp.int32)
        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x[:, -1:, :])
        return logits, cache

    # -------------------------------------------------------- decode step
    def decode_step(self, params: dict, cache: dict, tokens: jax.Array):
        cfg = self.cfg
        dt = self.compute_dtype
        pos = cache["pos"]
        B = tokens.shape[0]
        x = embed_tokens(cfg, params["embed"], tokens,
                         jnp.broadcast_to(pos, (B, 1)), dt)
        T = cache["k"].shape[2]
        mem_len = cache["ck"].shape[2]

        def body(x, xs):
            p, k_slot, v_slot, ck_slot, cv_slot = xs
            y = apply_norm(cfg, p["norm1"], x)
            q, k, v = attn.project_qkv(cfg, p["self_attn"], y, y)
            nk, nv = attn.cache_write(k_slot, v_slot, k, v, pos)
            valid = attn.decode_valid_mask("full", T, pos)
            o = attn.decode_attention(q, nk, nv, valid)
            x = x + o.reshape(B, 1, cfg.q_dim) @ p["self_attn"]["wo"].astype(dt)
            y = apply_norm(cfg, p["norm_c"], x)
            qc = (y @ p["cross_attn"]["wq"].astype(dt)).reshape(
                B, 1, cfg.num_heads, cfg.head_dim)
            oc = attn.decode_attention(qc, ck_slot, cv_slot,
                                       jnp.ones((mem_len,), bool))
            x = x + oc.reshape(B, 1, cfg.q_dim) @ p["cross_attn"]["wo"].astype(dt)
            x = x + ffn_mod.apply_ffn(cfg, p["ffn"],
                                      apply_norm(cfg, p["norm2"], x))
            return x, (nk, nv)

        x, (nks, nvs) = jax.lax.scan(
            body, x, (params["decoder"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]),
            unroll=self.cfg.num_layers if self.unroll else 1)
        new_cache = dict(cache)
        new_cache["k"] = nks
        new_cache["v"] = nvs
        new_cache["pos"] = pos + 1
        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, new_cache
