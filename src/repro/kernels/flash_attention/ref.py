"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q: (B, H, S, d); k, v: (B, K, T, d). Naive full-precision attention."""
    B, H, S, d = q.shape
    K, T = k.shape[1], k.shape[2]
    group = H // K
    qf = q.astype(jnp.float32) / math.sqrt(d)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", qf, kf)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, vf)
    return out.astype(q.dtype)
