"""Flash attention (prefill) Pallas TPU kernel with GQA head sharing.

TPU adaptation of the paper's central observation: GQA shrinks the KV working
set, so a query-head group shares one K/V block load HBM->VMEM (the index_map
maps q-head h to kv-head h * K / H), raising arithmetic intensity by the group
size. Grid (B, H, nq, nk) with nk innermost — TPU grids execute sequentially
per core, so the online-softmax running state lives in VMEM scratch across nk
steps; causal blocks above the diagonal are skipped with pl.when.

Block shapes are 128-aligned for the MXU; accumulation is fp32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * block_q
    k_start = ik * block_k
    # skip blocks entirely above the causal diagonal
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(jnp.asarray(run))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, S, d); k, v: (B, K, T, d) with H % K == 0. Returns (B,H,S,d)."""
    B, H, S, d = q.shape
    K, T = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    nq, nk = S // block_q, T // block_k
    scale = 1.0 / math.sqrt(d)
    group = H // K

    grid = (B, H, nq, nk)
    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda b, h, iq, ik: (b, h, iq, 0))
    k_spec = pl.BlockSpec((1, 1, block_k, d),
                          lambda b, h, iq, ik: (b, h // group, ik, 0))
    v_spec = pl.BlockSpec((1, 1, block_k, d),
                          lambda b, h, iq, ik: (b, h // group, ik, 0))
    o_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda b, h, iq, ik: (b, h, iq, 0))

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[q_spec, k_spec, v_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
