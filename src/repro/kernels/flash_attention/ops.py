"""Jit'd public wrapper for flash attention: Pallas on TPU, interpret-mode
Pallas for validation, jnp fallback elsewhere."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "backend", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, backend: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """backend: auto | pallas | interpret | ref."""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if backend == "ref":
        return flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_kernel(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k,
                                  interpret=(backend == "interpret"))
