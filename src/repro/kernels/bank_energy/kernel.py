"""TRAPTI Stage-II trace analytics as a Pallas TPU kernel.

This is the paper's Eq. (1)/(4)/(5) inner loop — bank activity, active
bank-seconds (the leakage integral) and bank on/off transition counts — over
(trace segments x candidate configurations). Offline DSE sweeps evaluate
thousands of (C, B, alpha) candidates against million-segment traces, so the
kernel blocks the segment arrays into VMEM tiles; the TPU grid is sequential
per core, which makes cross-tile carries (previous segment's bank activity,
for transition counting) and output accumulation safe.

Under contiguous packing, banks fill lowest-first, so the number of on/off
toggles between consecutive segments is exactly |B_act(k) - B_act(k-1)| —
transition counting needs no per-bank state.

Two kernels share the (n_candidates, n_segment_blocks) grid layout, segment
blocks innermost:

  * `bank_energy_kernel`     — the cheap lower-bound stats (bank-seconds +
    toggle count); carries only the previous segment's activity.
  * `exact_bank_stats_kernel` — exact per-bank idle-run extraction for the
    batched Stage-II evaluator: per tile it rebuilds each bank's on/off
    series (bmax x block_s), finds run ends at rises of the series via an
    in-tile prefix-max of exceed end-times, and classifies each run against
    the candidate's break-even threshold. Cross-tile state (per-bank last
    required time, previous on/off value, elapsed time) lives in VMEM/SMEM
    scratch, which is safe because the TPU grid is sequential per core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bank_kernel(dur_ref, occ_ref, usable_ref, nb_ref, out_ref, prev_sc, *,
                 num_seg_blocks: int):
    s = pl.program_id(1)

    dur = dur_ref[...]                        # (1, BS)
    occ = occ_ref[...]                        # (1, BS)
    usable = usable_ref[0, 0]
    nbanks = nb_ref[0, 0]

    act = jnp.clip(jnp.ceil(occ / usable), 0.0, nbanks)   # (1, BS)

    @pl.when(s == 0)
    def _first():
        prev_sc[0] = act[0, 0]
        out_ref[...] = jnp.zeros_like(out_ref)

    bank_seconds = jnp.sum(act * dur)
    shifted = jnp.concatenate(
        [jnp.full((1, 1), prev_sc[0], act.dtype), act[:, :-1]], axis=1)
    transitions = jnp.sum(jnp.abs(act - shifted))
    prev_sc[0] = act[0, -1]

    out_ref[0, 0] += bank_seconds
    out_ref[0, 1] += transitions


def _cummax_lanes(x: jax.Array) -> jax.Array:
    """Inclusive prefix-max along the last axis via log-doubling shifts —
    only concat/max, which lower cleanly inside a Pallas kernel. Assumes
    x >= 0 (0.0 is the identity used for the shifted-in prefix)."""
    n = x.shape[-1]
    shift = 1
    while shift < n:
        pad = jnp.zeros(x.shape[:-1] + (shift,), x.dtype)
        x = jnp.maximum(x, jnp.concatenate([pad, x[..., :-shift]], axis=-1))
        shift *= 2
    return x


def _exact_kernel(dur_ref, occ_ref, us_ref, nb_ref, th_ref, out_ref,
                  last_exc_t, prev_exc, tbase, *, bmax: int,
                  num_seg_blocks: int):
    s = pl.program_id(1)

    dur = dur_ref[...]                        # (1, BS)
    occ = occ_ref[...]                        # (1, BS)
    usable = us_ref[0, 0]
    nbanks = nb_ref[0, 0]
    threshold = th_ref[0, 0]

    act = jnp.clip(jnp.ceil(occ / usable), 0.0, nbanks)       # (1, BS)
    bank = jax.lax.broadcasted_iota(jnp.float32, (bmax, 1), 0)
    exceed = act > bank                                       # (bmax, BS)
    bankmask = bank < nbanks                                  # (bmax, 1)

    @pl.when(s == 0)
    def _first():
        out_ref[...] = jnp.zeros_like(out_ref)
        last_exc_t[...] = jnp.zeros_like(last_exc_t)
        # pre-trace state counts as ON so segment 0 never closes a run
        prev_exc[...] = jnp.ones_like(prev_exc)
        tbase[0] = 0.0

    t0 = tbase[0]
    cumend = t0 + jnp.cumsum(dur[0])                          # (BS,)
    cumstart = cumend - dur[0]

    carry_t = last_exc_t[...]                                 # (bmax, 1)
    last_in = _cummax_lanes(jnp.where(exceed, cumend[None, :], 0.0))
    run_start = jnp.maximum(
        jnp.concatenate([carry_t, last_in[:, :-1]], axis=1), carry_t)
    prev = jnp.concatenate(
        [prev_exc[...] > 0.5, exceed[:, :-1]], axis=1)
    is_rise = exceed & ~prev
    run_dur = cumstart[None, :] - run_start
    long = run_dur >= threshold
    rise_long = is_rise & long & bankmask
    rise_short = is_rise & ~long & bankmask

    zero = jnp.zeros_like(run_dur)
    out_ref[0, 0] += jnp.sum(act * dur)
    out_ref[0, 1] += jnp.sum(rise_long.astype(jnp.float32))
    out_ref[0, 2] += jnp.sum(jnp.where(rise_long, run_dur, zero))
    out_ref[0, 3] += jnp.sum(rise_short.astype(jnp.float32))
    out_ref[0, 4] += jnp.sum(jnp.where(rise_short, run_dur, zero))

    new_last = jnp.maximum(carry_t, last_in[:, -1:])          # (bmax, 1)
    t_end = t0 + jnp.sum(dur)
    last_exc_t[...] = new_last
    prev_exc[...] = exceed[:, -1:].astype(jnp.float32)
    tbase[0] = t_end

    @pl.when(s == num_seg_blocks - 1)
    def _flush():
        # close the still-open idle run of every bank idle at trace end
        tail_dur = t_end - new_last                           # (bmax, 1)
        tail_idle = ~exceed[:, -1:] & bankmask
        tail_long = tail_idle & (tail_dur >= threshold)
        tail_short = tail_idle & ~tail_long
        zero1 = jnp.zeros_like(tail_dur)
        out_ref[0, 1] += jnp.sum(tail_long.astype(jnp.float32))
        out_ref[0, 2] += jnp.sum(jnp.where(tail_long, tail_dur, zero1))
        out_ref[0, 3] += jnp.sum(tail_short.astype(jnp.float32))
        out_ref[0, 4] += jnp.sum(jnp.where(tail_short, tail_dur, zero1))


def exact_bank_stats_kernel(durations: jax.Array, occupancy: jax.Array,
                            usable: jax.Array, nbanks: jax.Array,
                            threshold: jax.Array, *, bmax: int,
                            block_s: int = 2048,
                            interpret: bool = False) -> jax.Array:
    """durations/occupancy: (S,) f32, S % block_s == 0 (pad durations with 0
    and occupancy with its last value — padding adds no time and no rises);
    usable/nbanks/threshold: (C,) f32; bmax: static max bank count.

    Returns (C, 5): [active bank-seconds, idle runs >= threshold, their
    seconds, idle runs < threshold, their seconds] — the exact Eq. (2)-(5)
    observables, same contract as `exact_bank_stats_np`.
    """
    S = durations.shape[0]
    C = usable.shape[0]
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    nsb = S // block_s
    bmax_p = max(8, -(-bmax // 8) * 8)       # pad sublanes; masked via nbanks

    dur2 = durations.reshape(nsb, block_s).astype(jnp.float32)
    occ2 = occupancy.reshape(nsb, block_s).astype(jnp.float32)
    us2 = usable.reshape(C, 1).astype(jnp.float32)
    nb2 = nbanks.reshape(C, 1).astype(jnp.float32)
    th2 = threshold.reshape(C, 1).astype(jnp.float32)

    kern = functools.partial(_exact_kernel, bmax=bmax_p, num_seg_blocks=nsb)
    return pl.pallas_call(
        kern,
        grid=(C, nsb),
        in_specs=[
            pl.BlockSpec((1, block_s), lambda c, s: (s, 0)),
            pl.BlockSpec((1, block_s), lambda c, s: (s, 0)),
            pl.BlockSpec((1, 1), lambda c, s: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, s: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, s: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 5), lambda c, s: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, 5), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bmax_p, 1), jnp.float32),     # last exceed end-time
            pltpu.VMEM((bmax_p, 1), jnp.float32),     # previous on/off (0/1)
            pltpu.SMEM((1,), jnp.float32),            # elapsed time
        ],
        interpret=interpret,
    )(dur2, occ2, us2, nb2, th2)


def bank_energy_kernel(durations: jax.Array, occupancy: jax.Array,
                       usable: jax.Array, nbanks: jax.Array, *,
                       block_s: int = 2048,
                       interpret: bool = False) -> jax.Array:
    """durations/occupancy: (S,) f32 (S % block_s == 0 — pad durations with 0
    and occupancy with its last value); usable/nbanks: (C,) f32.

    Returns (C, 2): [:, 0] = integral of B_act dt, [:, 1] = on/off toggles.
    """
    S = durations.shape[0]
    C = usable.shape[0]
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    nsb = S // block_s

    dur2 = durations.reshape(nsb, block_s).astype(jnp.float32)
    occ2 = occupancy.reshape(nsb, block_s).astype(jnp.float32)
    us2 = usable.reshape(C, 1).astype(jnp.float32)
    nb2 = nbanks.reshape(C, 1).astype(jnp.float32)

    kern = functools.partial(_bank_kernel, num_seg_blocks=nsb)
    return pl.pallas_call(
        kern,
        grid=(C, nsb),
        in_specs=[
            pl.BlockSpec((1, block_s), lambda c, s: (s, 0)),
            pl.BlockSpec((1, block_s), lambda c, s: (s, 0)),
            pl.BlockSpec((1, 1), lambda c, s: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, s: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda c, s: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, 2), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(dur2, occ2, us2, nb2)
