"""TRAPTI Stage-II trace analytics as a Pallas TPU kernel.

This is the paper's Eq. (1)/(4)/(5) inner loop — bank activity, active
bank-seconds (the leakage integral) and bank on/off transition counts — over
(trace segments x candidate configurations). Offline DSE sweeps evaluate
thousands of (C, B, alpha) candidates against million-segment traces, so the
kernel blocks the segment arrays into VMEM tiles; the TPU grid is sequential
per core, which makes cross-tile carries (previous segment's bank activity,
for transition counting) and output accumulation safe.

Under contiguous packing, banks fill lowest-first, so the number of on/off
toggles between consecutive segments is exactly |B_act(k) - B_act(k-1)| —
transition counting needs no per-bank state.

Grid: (n_candidates, n_segment_blocks), segment blocks innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bank_kernel(dur_ref, occ_ref, usable_ref, nb_ref, out_ref, prev_sc, *,
                 num_seg_blocks: int):
    s = pl.program_id(1)

    dur = dur_ref[...]                        # (1, BS)
    occ = occ_ref[...]                        # (1, BS)
    usable = usable_ref[0, 0]
    nbanks = nb_ref[0, 0]

    act = jnp.clip(jnp.ceil(occ / usable), 0.0, nbanks)   # (1, BS)

    @pl.when(s == 0)
    def _first():
        prev_sc[0] = act[0, 0]
        out_ref[...] = jnp.zeros_like(out_ref)

    bank_seconds = jnp.sum(act * dur)
    shifted = jnp.concatenate(
        [jnp.full((1, 1), prev_sc[0], act.dtype), act[:, :-1]], axis=1)
    transitions = jnp.sum(jnp.abs(act - shifted))
    prev_sc[0] = act[0, -1]

    out_ref[0, 0] += bank_seconds
    out_ref[0, 1] += transitions


def bank_energy_kernel(durations: jax.Array, occupancy: jax.Array,
                       usable: jax.Array, nbanks: jax.Array, *,
                       block_s: int = 2048,
                       interpret: bool = False) -> jax.Array:
    """durations/occupancy: (S,) f32 (S % block_s == 0 — pad durations with 0
    and occupancy with its last value); usable/nbanks: (C,) f32.

    Returns (C, 2): [:, 0] = integral of B_act dt, [:, 1] = on/off toggles.
    """
    S = durations.shape[0]
    C = usable.shape[0]
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    nsb = S // block_s

    dur2 = durations.reshape(nsb, block_s).astype(jnp.float32)
    occ2 = occupancy.reshape(nsb, block_s).astype(jnp.float32)
    us2 = usable.reshape(C, 1).astype(jnp.float32)
    nb2 = nbanks.reshape(C, 1).astype(jnp.float32)

    kern = functools.partial(_bank_kernel, num_seg_blocks=nsb)
    return pl.pallas_call(
        kern,
        grid=(C, nsb),
        in_specs=[
            pl.BlockSpec((1, block_s), lambda c, s: (s, 0)),
            pl.BlockSpec((1, block_s), lambda c, s: (s, 0)),
            pl.BlockSpec((1, 1), lambda c, s: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, s: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda c, s: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, 2), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(dur2, occ2, us2, nb2)
