"""Jit'd wrapper: pad the trace, run the analytics, derive Eq. (2)-(5) energy
terms for a whole (C, B, alpha) candidate grid at once."""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bank_energy.kernel import bank_energy_kernel
from repro.kernels.bank_energy.ref import bank_energy_ref


def _pad(durations, occupancy, block_s: int):
    S = durations.shape[0]
    Sp = max(block_s, ((S + block_s - 1) // block_s) * block_s)
    pad = Sp - S
    if pad:
        durations = jnp.concatenate(
            [durations, jnp.zeros((pad,), durations.dtype)])
        last = occupancy[-1] if S else jnp.zeros((), occupancy.dtype)
        occupancy = jnp.concatenate(
            [occupancy, jnp.full((pad,), last, occupancy.dtype)])
    return durations, occupancy


@functools.partial(jax.jit, static_argnames=("backend", "block_s"))
def bank_activity_stats(durations, occupancy, usable, nbanks, *,
                        backend: str = "auto", block_s: int = 2048):
    """(C, 2): [active bank-seconds, on/off transition count] per candidate."""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "ref")
    durations = jnp.asarray(durations, jnp.float32)
    occupancy = jnp.asarray(occupancy, jnp.float32)
    usable = jnp.asarray(usable, jnp.float32)
    nbanks = jnp.asarray(nbanks, jnp.float32)
    if backend == "ref":
        return bank_energy_ref(durations, occupancy, usable, nbanks)
    d, o = _pad(durations, occupancy, block_s)
    return bank_energy_kernel(d, o, usable, nbanks, block_s=block_s,
                              interpret=(backend == "interpret"))


def candidate_grid(capacities_bytes: Sequence[int], banks: Sequence[int],
                   alpha: float) -> Tuple[np.ndarray, np.ndarray, list]:
    """Flatten a (C x B) sweep into the kernel's candidate arrays."""
    usable, nb, meta = [], [], []
    for c in capacities_bytes:
        for b in banks:
            usable.append(alpha * c / b)
            nb.append(float(b))
            meta.append((int(c), int(b)))
    return np.asarray(usable, np.float32), np.asarray(nb, np.float32), meta
