"""Backend dispatch for the Stage-II trace analytics.

Two entry points, each evaluating a whole (C, B, alpha) candidate grid in
one call:

  * `bank_activity_stats` — cheap lower-bound stats (bank-seconds, toggles).
  * `exact_bank_stats`    — exact idle-run stats for the batched evaluator.

Backends: "numpy" (float64, bit-exact vs the scalar reference — the default
on CPU hosts), "ref" (jnp, jit), "pallas" (TPU kernel, the default when a
TPU is attached), "interpret" (Pallas interpret mode, for tests).

Precision: occupancy is byte-valued and reaches 10^8 for the paper's
128 MiB arrays — beyond float32's exact-integer range (2^24), so an f32
cast drops sub-16-byte deltas and can flip ceil() at bank boundaries. The
f32 paths therefore normalize occupancy and usable to KiB before the kernel
(keeping the common KiB-granular occupancies exactly representable up to
2^34 bytes; the ratio, and hence bank activity, is unchanged because the
rescale is a power of two), and "auto" on CPU routes to the float64 numpy
path, which is exact for any byte value.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bank_energy.kernel import (bank_energy_kernel,
                                              exact_bank_stats_kernel)
from repro.kernels.bank_energy.ref import (bank_energy_np, bank_energy_ref,
                                           exact_bank_stats_np,
                                           exact_bank_stats_ref)

KIB = 1024.0


def _resolve(backend: str) -> str:
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "numpy"


def _pad(durations, occupancy, block_s: int):
    S = durations.shape[0]
    Sp = max(block_s, ((S + block_s - 1) // block_s) * block_s)
    pad = Sp - S
    if pad:
        durations = jnp.concatenate(
            [durations, jnp.zeros((pad,), durations.dtype)])
        last = occupancy[-1] if S else jnp.zeros((), occupancy.dtype)
        occupancy = jnp.concatenate(
            [occupancy, jnp.full((pad,), last, occupancy.dtype)])
    return durations, occupancy


@functools.partial(jax.jit, static_argnames=("backend", "block_s"))
def _bank_activity_stats_jit(durations, occupancy, usable, nbanks, *,
                             backend: str, block_s: int):
    durations = jnp.asarray(durations, jnp.float32)
    occupancy = jnp.asarray(occupancy, jnp.float32) / KIB
    usable = jnp.asarray(usable, jnp.float32) / KIB
    nbanks = jnp.asarray(nbanks, jnp.float32)
    if backend == "ref":
        return bank_energy_ref(durations, occupancy, usable, nbanks)
    d, o = _pad(durations, occupancy, block_s)
    return bank_energy_kernel(d, o, usable, nbanks, block_s=block_s,
                              interpret=(backend == "interpret"))


def bank_activity_stats(durations, occupancy, usable, nbanks, *,
                        backend: str = "auto", block_s: int = 2048):
    """(C, 2): [active bank-seconds, on/off transition count] per candidate."""
    backend = _resolve(backend)
    if backend == "numpy":
        return bank_energy_np(durations, occupancy, usable, nbanks)
    return _bank_activity_stats_jit(durations, occupancy, usable, nbanks,
                                    backend=backend, block_s=block_s)


@functools.partial(jax.jit,
                   static_argnames=("bmax", "backend", "block_s"))
def _exact_bank_stats_jit(durations, occupancy, usable, nbanks, threshold, *,
                          bmax: int, backend: str, block_s: int):
    durations = jnp.asarray(durations, jnp.float32)
    occupancy = jnp.asarray(occupancy, jnp.float32) / KIB
    usable = jnp.asarray(usable, jnp.float32) / KIB
    nbanks = jnp.asarray(nbanks, jnp.float32)
    threshold = jnp.asarray(threshold, jnp.float32)
    if backend == "ref":
        return exact_bank_stats_ref(durations, occupancy, usable, nbanks,
                                    threshold, bmax=bmax)
    d, o = _pad(durations, occupancy, block_s)
    return exact_bank_stats_kernel(d, o, usable, nbanks, threshold,
                                   bmax=bmax, block_s=block_s,
                                   interpret=(backend == "interpret"))


def exact_bank_stats(durations, occupancy, usable, nbanks, threshold, *,
                     backend: str = "auto", block_s: int = 2048):
    """(C, 5) exact idle-run stats per candidate: [active bank-seconds,
    idle runs >= threshold, their seconds, idle runs < threshold, their
    seconds]. See `exact_bank_stats_np` for the reference semantics."""
    backend = _resolve(backend)
    if backend == "numpy":
        return exact_bank_stats_np(durations, occupancy, usable, nbanks,
                                   threshold)
    n_cand, n_seg = len(np.asarray(usable)), len(np.asarray(durations))
    if n_cand == 0 or n_seg == 0:
        return np.zeros((n_cand, 5), np.float32)
    bmax = int(np.max(np.asarray(nbanks)))
    return _exact_bank_stats_jit(durations, occupancy, usable, nbanks,
                                 threshold, bmax=bmax, backend=backend,
                                 block_s=block_s)


def candidate_grid(capacities_bytes: Sequence[int], banks: Sequence[int],
                   alpha: float) -> Tuple[np.ndarray, np.ndarray, list]:
    """Flatten a (C x B) sweep into the kernel's candidate arrays."""
    usable, nb, meta = [], [], []
    for c in capacities_bytes:
        for b in banks:
            usable.append(alpha * (c / b))
            nb.append(float(b))
            meta.append((int(c), int(b)))
    return np.asarray(usable, np.float64), np.asarray(nb, np.float64), meta
