"""References for the bank-energy analytics kernels.

Two computations, three implementations each:

  * lower-bound stats  — [active bank-seconds, activity toggles] per
    candidate; `bank_energy_ref` (jnp f32) and `bank_energy_np` (numpy f64).
  * exact stats        — the full Eq. (2)-(5) observables per candidate:
    [active bank-seconds, #idle runs >= threshold, their seconds,
    #idle runs < threshold, their seconds]; `exact_bank_stats_ref` (jnp)
    and `exact_bank_stats_np` (numpy f64, the bit-exact CPU path).

Exact idle-run extraction is segment-parallel: with `exceed[b, k] = (bank b
required in segment k)`, an idle run of bank b ends just before every rise
of `exceed`, its start time is the running maximum of end-times of exceeding
segments, and the run duration falls out of one prefix-sum/prefix-max pass —
no per-bank or per-run Python loops, vectorized over all candidates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

STAT_COLS = 5      # [act_seconds, n_long, long_seconds, n_short, short_seconds]


def bank_energy_ref(durations: jax.Array, occupancy: jax.Array,
                    usable: jax.Array, nbanks: jax.Array) -> jax.Array:
    """Same contract as bank_energy_kernel: returns (C, 2)."""
    d = durations.astype(jnp.float32)[None, :]          # (1, S)
    o = occupancy.astype(jnp.float32)[None, :]
    u = usable.astype(jnp.float32)[:, None]             # (C, 1)
    b = nbanks.astype(jnp.float32)[:, None]
    act = jnp.clip(jnp.ceil(o / u), 0.0, b)             # (C, S)
    seconds = jnp.sum(act * d, axis=1)
    trans = jnp.sum(jnp.abs(act[:, 1:] - act[:, :-1]), axis=1)
    return jnp.stack([seconds, trans], axis=1)


def bank_energy_np(durations, occupancy, usable, nbanks, *,
                   toggles: bool = True) -> np.ndarray:
    """float64 numpy twin of `bank_energy_ref` — the default CPU path.

    Byte-valued occupancies beyond float32's exact-integer range (2^24 ~
    16.8 MB) silently lose their low bits under an f32 cast, which flips
    ceil() at bank boundaries; float64 carries byte exactness to 2^53.

    Occupancy levels repeat heavily in real traces (slot-quantized KV), so
    the expensive ceil(occ / usable) runs once per *unique* level; the
    leakage integral becomes one BLAS matvec against per-level duration
    sums, and toggles are gathered at level-change positions only.
    `toggles=False` skips that gather and zeros column 1 — the
    lower-bound-only mode used for pruning.
    """
    d = np.asarray(durations, np.float64)
    u = np.asarray(usable, np.float64)[:, None]
    b = np.asarray(nbanks, np.float64)[:, None]
    n_cand, n_seg = len(u), len(d)
    if n_seg == 0:
        return np.zeros((n_cand, 2))
    uniq, uinv = np.unique(np.asarray(occupancy, np.float64),
                           return_inverse=True)
    act_u = np.minimum(np.ceil(uniq[None, :] / u), b)       # (n, U)
    d_by_level = np.bincount(uinv, weights=d, minlength=len(uniq))
    seconds = act_u @ d_by_level
    if not toggles:
        return np.stack([seconds, np.zeros(n_cand)], axis=1)
    chg = np.flatnonzero(uinv[1:] != uinv[:-1])
    trans = np.abs(act_u[:, uinv[chg + 1]]
                   - act_u[:, uinv[chg]]).sum(axis=1)
    return np.stack([seconds, trans], axis=1)


# ---------------------------------------------------------------------------
# Exact per-candidate idle-run stats
# ---------------------------------------------------------------------------

def exact_bank_stats_np(durations, occupancy, usable, nbanks, threshold, *,
                        max_elems: int = 1 << 18) -> np.ndarray:
    """Exact Stage-II observables for N candidates in float64 numpy.

    Returns (N, 5): [active bank-seconds, idle runs >= threshold (count),
    their total seconds, idle runs < threshold (count), their seconds].

    Event-based: bank b toggles exactly when the activity series crosses
    level b, so a transition a -> a' contributes |a' - a| crossing events
    for levels [min, max). With virtual all-ON levels before and after the
    trace, every (bank, idle-run) pair is one (down, up) crossing pair, and
    within each (candidate, level) group — and therefore globally, groups
    having even length — sorted events alternate down/up. One flatten +
    argsort over all candidates' events and a bincount per class replace
    the per-candidate/per-bank loops; total work scales with the number of
    actual bank on/off events, not with (N x B x S).

    Run durations come from the same `cumsum(durations)` values the scalar
    `banking.idle_runs` uses, so counts and run-second sums match the
    per-candidate reference bit-for-bit. Candidates are chunked so each
    chunk's (N_chunk x P) temporaries stay under `max_elems` elements —
    small enough for the allocator to reuse warm arenas instead of paying
    page faults on every fresh multi-MB array.
    """
    d = np.asarray(durations, np.float64)
    o = np.asarray(occupancy, np.float64)
    u = np.asarray(usable, np.float64)
    nb = np.asarray(nbanks, np.float64)
    th = np.asarray(threshold, np.float64)
    n_cand, n_seg = len(u), len(d)
    out = np.zeros((n_cand, STAT_COLS))
    if n_cand == 0 or n_seg == 0:
        return out

    cum = np.concatenate([[0.0], np.cumsum(d)])         # (S+1,)
    # occupancy levels repeat heavily (slot-quantized KV): divide once per
    # unique level, integrate leakage as a matvec over per-level durations,
    # and look at level-change positions only for bank toggles
    uniq, uinv = np.unique(o, return_inverse=True)
    d_by_level = np.bincount(uinv, weights=d, minlength=len(uniq))
    chg = np.flatnonzero(uinv[1:] != uinv[:-1])         # shared positions

    n_chg = len(chg)
    chunk = max(1, max_elems // max(n_chg + 2, 1))
    for c0 in range(0, n_cand, chunk):
        sl = slice(c0, min(c0 + chunk, n_cand))
        ui, nbi = u[sl][:, None], nb[sl][:, None]
        n = ui.shape[0]

        # occ >= 0 so only the upper clip is live; int16 keeps the event
        # passes small (B <= 2^15)
        act_u = np.minimum(np.ceil(uniq[None, :] / ui), nbi)    # (n, U) f64
        out[sl, 0] = act_u @ d_by_level
        act_ui = act_u.astype(np.int16)
        # activity plateaus: the value after change t holds until change
        # t+1, so one (n, P+1) gather yields both transition endpoints
        plateau = np.concatenate([[uinv[0]], uinv[chg + 1]])
        vals = act_ui[:, plateau]                               # (n, P+1)
        vflat = vals.ravel()

        # transition table [cand, pos, lo, hi): interior level changes plus
        # virtual all-ON states before/after the trace (pos 0 and n_seg);
        # flat single-pass extraction, no dense (n, P) index tuples
        neq = vals[:, 1:] != vals[:, :-1]                       # (n, P)
        flat = np.flatnonzero(neq)
        m = len(flat)
        t_cand = np.empty(m + 2 * n, np.int64)
        t_pos = np.empty(m + 2 * n, np.int64)
        t_lo = np.empty(m + 2 * n, np.int64)
        t_hi = np.empty(m + 2 * n, np.int64)
        if n_chg:
            t_cand[:m], cj = np.divmod(flat, n_chg)
            t_pos[:m] = chg[cj] + 1
            j = flat + t_cand[:m]          # index into vals.ravel()
            av = vflat[j].astype(np.int64)
            bv = vflat[j + 1].astype(np.int64)
            np.minimum(av, bv, out=t_lo[:m])
            np.maximum(av, bv, out=t_hi[:m])
        nb_col = nbi[:, 0].astype(np.int64)
        arng = np.arange(n)
        t_cand[m:m + n] = arng
        t_pos[m:m + n] = 0
        t_lo[m:m + n] = vals[:, 0]
        t_hi[m:m + n] = nb_col
        t_cand[m + n:] = arng
        t_pos[m + n:] = n_seg
        t_lo[m + n:] = vals[:, -1]
        t_hi[m + n:] = nb_col
        counts = t_hi - t_lo                   # >= 0; zeros vanish in repeat
        total = int(counts.sum())
        if total == 0:
            continue

        # expand each transition into its crossed levels [lo, hi)
        first = np.repeat(np.cumsum(counts) - counts, counts)
        level = np.repeat(t_lo, counts) + (np.arange(total) - first)
        ev_cand = np.repeat(t_cand, counts)
        ev_pos = np.repeat(t_pos, counts)

        # total order by (candidate, level, position): keys are unique, and
        # within each (candidate, level) group the sorted crossings
        # alternate down/up
        key = (ev_cand * (np.int64(nbi.max()) + 1) + level) \
            * np.int64(n_seg + 2) + ev_pos
        idx = np.argsort(key, kind="stable")
        down_pos = ev_pos[idx[0::2]]
        up_pos = ev_pos[idx[1::2]]
        run_cand = ev_cand[idx[0::2]]

        # groups alternate (down, up) and have even length, so downs and
        # ups interleave globally
        run_dur = cum[up_pos] - cum[down_pos]
        long = run_dur >= th[sl][run_cand]
        out[sl, 1] = np.bincount(run_cand[long], minlength=n)
        out[sl, 2] = np.bincount(run_cand[long],
                                 weights=run_dur[long], minlength=n)
        out[sl, 3] = np.bincount(run_cand[~long], minlength=n)
        out[sl, 4] = np.bincount(run_cand[~long],
                                 weights=run_dur[~long], minlength=n)
    return out


def exact_bank_stats_ref(durations: jax.Array, occupancy: jax.Array,
                         usable: jax.Array, nbanks: jax.Array,
                         threshold: jax.Array, *, bmax: int) -> jax.Array:
    """jnp twin of `exact_bank_stats_np` (float32 unless x64 is enabled);
    one fused expression over (N, bmax, S), jit-friendly."""
    d = durations[None, :]
    o = occupancy[None, :]
    u = usable[:, None]
    nb = nbanks[:, None]
    th = threshold[:, None, None]

    act = jnp.clip(jnp.ceil(o / u), 0.0, nb)                    # (N, S)
    cum = jnp.cumsum(d[0])
    cumend = cum
    cumstart = cum - d[0]
    total_t = cum[-1]
    bank = jnp.arange(bmax, dtype=act.dtype)
    exceed = act[:, None, :] > bank[None, :, None]              # (N, B, S)
    bankmask = bank[None, :] < nb                               # (N, B)

    last_exc = jax.lax.cummax(
        jnp.where(exceed, cumend[None, None, :], 0.0), axis=2)
    run_start = jnp.concatenate(
        [jnp.zeros_like(last_exc[:, :, :1]), last_exc[:, :, :-1]], axis=2)
    prev_exc = jnp.concatenate(
        [jnp.ones_like(exceed[:, :, :1]), exceed[:, :, :-1]], axis=2)
    is_rise = exceed & ~prev_exc
    run_dur = cumstart[None, None, :] - run_start
    long = run_dur >= th
    m3 = bankmask[:, :, None]
    rise_long = is_rise & long & m3
    rise_short = is_rise & ~long & m3

    tail_dur = total_t - last_exc[:, :, -1]
    tail_idle = ~exceed[:, :, -1] & bankmask
    tail_long = tail_idle & (tail_dur >= th[:, :, 0])
    tail_short = tail_idle & ~tail_long

    zero = jnp.zeros_like(run_dur)
    n_long = rise_long.sum((1, 2)) + tail_long.sum(1)
    long_s = (jnp.where(rise_long, run_dur, zero).sum((1, 2))
              + jnp.where(tail_long, tail_dur, 0.0).sum(1))
    n_short = rise_short.sum((1, 2)) + tail_short.sum(1)
    short_s = (jnp.where(rise_short, run_dur, zero).sum((1, 2))
               + jnp.where(tail_short, tail_dur, 0.0).sum(1))
    act_s = (act * d).sum(1)
    return jnp.stack([act_s, n_long.astype(act.dtype), long_s,
                      n_short.astype(act.dtype), short_s], axis=1)
