"""Pure-jnp oracle for the bank-energy analytics kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bank_energy_ref(durations: jax.Array, occupancy: jax.Array,
                    usable: jax.Array, nbanks: jax.Array) -> jax.Array:
    """Same contract as bank_energy_kernel: returns (C, 2)."""
    d = durations.astype(jnp.float32)[None, :]          # (1, S)
    o = occupancy.astype(jnp.float32)[None, :]
    u = usable.astype(jnp.float32)[:, None]             # (C, 1)
    b = nbanks.astype(jnp.float32)[:, None]
    act = jnp.clip(jnp.ceil(o / u), 0.0, b)             # (C, S)
    seconds = jnp.sum(act * d, axis=1)
    trans = jnp.sum(jnp.abs(act[:, 1:] - act[:, :-1]), axis=1)
    return jnp.stack([seconds, trans], axis=1)
