from repro.kernels.bank_energy.ops import (bank_activity_stats,  # noqa: F401
                                           candidate_grid, exact_bank_stats)
from repro.kernels.bank_energy.ref import (bank_energy_np,  # noqa: F401
                                           bank_energy_ref,
                                           exact_bank_stats_np,
                                           exact_bank_stats_ref)
from repro.kernels.bank_energy.kernel import (bank_energy_kernel,  # noqa: F401
                                              exact_bank_stats_kernel)
