from repro.kernels.bank_energy.ops import bank_activity_stats, candidate_grid  # noqa: F401
from repro.kernels.bank_energy.ref import bank_energy_ref  # noqa: F401
from repro.kernels.bank_energy.kernel import bank_energy_kernel  # noqa: F401
