"""Jit'd public wrapper for paged GQA speculative verification."""
from __future__ import annotations

import functools

import jax

from repro.kernels import quant
from repro.kernels.paged_gqa_verify.kernel import paged_gqa_verify_kernel
from repro.kernels.paged_gqa_verify.ref import paged_gqa_verify_ref


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_gqa_verify(q, k_pages, v_pages, page_table, base_lens, *,
                     backend: str = "auto"):
    """backend: auto | pallas | interpret | ref.

    q: (B, V, H, d) — V = spec_k + 1 query rows per slot, row v at absolute
    position base_lens + v; k_pages, v_pages: (N, K, page_size, d);
    page_table: (B, P) int32 page ids; base_lens: (B,) int32 context
    lengths before the speculative window. -> (B, V, H, d)."""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if backend == "ref":
        return paged_gqa_verify_ref(q, k_pages, v_pages, page_table,
                                    base_lens)
    if k_pages.dtype == quant.FP8_STORAGE_DTYPE:
        # fp8 pools travel as uint8 bit codes (see quant.FP8_STORAGE_DTYPE);
        # the kernel wants the float8 view
        k_pages = jax.lax.bitcast_convert_type(k_pages, quant.FP8_DTYPE)
        v_pages = jax.lax.bitcast_convert_type(v_pages, quant.FP8_DTYPE)
    return paged_gqa_verify_kernel(q, k_pages, v_pages, page_table,
                                   base_lens,
                                   interpret=(backend == "interpret"))
