"""Paged GQA speculative-verification Pallas TPU kernel.

Target-model verification of a speculative window: each slot carries
V = spec_k + 1 query rows (the pending token plus the k drafted candidates),
all attending against the same page-table-indirected pool the decode kernel
streams. Row v sits at absolute position base_lens[b] + v, so its causal
horizon is base_lens[b] + v + 1 — the causal mask widens by one row per
query row of the speculative window. All V rows of a (kv head, page) block
share one HBM->VMEM page copy, which is the point: scoring k + 1 candidates
costs one pass over the resident pages instead of k + 1 sequential decode
calls.

Grid (B, K, P) exactly like `paged_gqa_decode`: kv heads parallel, pages
innermost sequential so the fp32 split-K online-softmax scratch carries
across them. The only differences are the fatter query block (V * group
rows instead of group) and the per-row causal bound derived from the row's
spec index (row // group).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_verify_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_sc, l_sc, acc_sc, *, scale: float, page_size: int,
                         num_pages: int, group: int, num_q: int):
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    base = len_ref[b]
    t_start = it * page_size

    # the widest row (spec index num_q - 1) reaches base + num_q tokens
    @pl.when(t_start < base + num_q)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale    # (num_q * group, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (ps, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        tpos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # per-row causal horizon: query row r belongs to spec index
        # r // group and may attend tokens [0, base + r // group + 1)
        row_v = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        s = jnp.where(tpos < base + row_v + 1, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(it == num_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / denom[:, None]).astype(o_ref.dtype)


def paged_gqa_verify_kernel(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, page_table: jax.Array,
                            base_lens: jax.Array, *,
                            interpret: bool = False) -> jax.Array:
    """q: (B, V, H, d) speculative-window queries; k_pages, v_pages:
    (N, K, ps, d); page_table: (B, P) int32; base_lens: (B,) int32 context
    lengths *before* the window (row v attends base_lens + v + 1 tokens).
    Returns (B, V, H, d) in q.dtype."""
    B, V, H, d = q.shape
    N, K, ps, _ = k_pages.shape
    P = page_table.shape[1]
    assert H % K == 0
    group = H // K
    scale = 1.0 / math.sqrt(d)

    # rows of one kv head block are laid out spec-major: row v * group + g
    # is query head g of spec index v, so the kernel recovers the spec
    # index as row // group
    qg = (q.reshape(B, V, K, group, d).transpose(0, 2, 1, 3, 4)
          .reshape(B, K, V * group, d))
    kern = functools.partial(_paged_verify_kernel, scale=scale, page_size=ps,
                             num_pages=P, group=group, num_q=V)

    def q_map(b, kh, it, lens, pt):
        return (b, kh, 0, 0)

    def kv_map(b, kh, it, lens, pt):
        return (pt[b, it], kh, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, P),
        in_specs=[
            pl.BlockSpec((1, 1, V * group, d), q_map),
            pl.BlockSpec((1, 1, ps, d), kv_map),
            pl.BlockSpec((1, 1, ps, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, V * group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((V * group,), jnp.float32),
            pltpu.VMEM((V * group,), jnp.float32),
            pltpu.VMEM((V * group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, V * group, d), q.dtype),
        interpret=interpret,
    )(base_lens.astype(jnp.int32), page_table.astype(jnp.int32),
      qg, k_pages, v_pages)
    return (out.reshape(B, K, V, group, d).transpose(0, 2, 1, 3, 4)
            .reshape(B, V, H, d))
