from repro.kernels.paged_gqa_verify.ops import paged_gqa_verify  # noqa: F401
from repro.kernels.paged_gqa_verify.ref import (  # noqa: F401
    paged_gqa_verify_ref)
