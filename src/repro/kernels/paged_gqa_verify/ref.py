"""Pure-jnp oracle for paged GQA speculative verification.

Row v of the speculative window is scored by the *decode* oracle at length
`base_lens + v + 1`: the reference is literally a stack of
`paged_gqa_decode_ref` calls, one per window row. That makes the serving
`ref` backend's verify logits bit-identical per row to stepping the
non-speculative decode path token by token — the foundation of the
accepted-tokens bit-identity guarantee pinned in tests — while the Pallas
kernel is checked against this stack to ~1e-6 (split-K online softmax vs
single-shot softmax).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_gqa_decode.ref import paged_gqa_decode_ref


def paged_gqa_verify_ref(q: jax.Array, k_pages: jax.Array,
                         v_pages: jax.Array, page_table: jax.Array,
                         base_lens: jax.Array) -> jax.Array:
    """q: (B, V, H, d); k_pages, v_pages: (N, K, ps, d); page_table: (B, P)
    int32; base_lens: (B,) int32 context lengths before the speculative
    window. Returns (B, V, H, d)."""
    V = q.shape[1]
    rows = [paged_gqa_decode_ref(q[:, v], k_pages, v_pages, page_table,
                                 base_lens + (v + 1)) for v in range(V)]
    return jnp.stack(rows, axis=1)
