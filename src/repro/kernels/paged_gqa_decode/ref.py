"""Pure-jnp oracle for paged GQA decode attention.

The KV cache lives in a global page pool shared by every sequence; each
sequence owns an ordered list of page ids (its page table row) and a true
context length. The oracle gathers the pages back into a dense per-sequence
cache and runs the same fp32 masked softmax as the dense `gqa_decode_ref`,
so the Pallas kernel's page-table indirection is tested against plain
advanced indexing.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gather_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """pool: (N, K, ps, d); page_table: (B, P) int32 -> dense (B, K, P*ps, d)."""
    B, P = page_table.shape
    N, K, ps, d = pool.shape
    g = pool[page_table]                       # (B, P, K, ps, d)
    return g.transpose(0, 2, 1, 3, 4).reshape(B, K, P * ps, d)


def paged_gqa_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                         page_table: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B, H, d); k_pages, v_pages: (N, K, ps, d); page_table: (B, P);
    lengths: (B,) int32 true context sizes (<= P*ps). Returns (B, H, d).

    Tokens of sequence b live at pool[page_table[b, t // ps], :, t % ps]
    for t < lengths[b]; entries past `lengths` (including the tail of a
    partially-filled last page) are masked out.
    """
    B, H, d = q.shape
    K, ps = k_pages.shape[1], k_pages.shape[2]
    T = page_table.shape[1] * ps
    group = H // K
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    qg = (q.astype(jnp.float32) / math.sqrt(d)).reshape(B, K, group, d)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32))
    valid = jnp.arange(T)[None, :] < lengths[:, None]        # (B, T)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, d).astype(q.dtype)
