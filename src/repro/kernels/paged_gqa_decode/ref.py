"""Pure-jnp oracle for paged GQA decode attention.

The KV cache lives in a global page pool shared by every sequence; each
sequence owns an ordered list of page ids (its page table row) and a true
context length. The oracle gathers the pages back into a dense per-sequence
cache and runs the same fp32 masked softmax as the dense `gqa_decode_ref`,
so the Pallas kernel's page-table indirection is tested against plain
advanced indexing.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import quant


def _gather_pool_f32(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """`gather_pages` into float32. fp8 pools dequantize (table lookup,
    bit-identical to astype — see `quant.from_fp8`) BEFORE the gather: on
    CPU XLA, gathers on 1-byte float dtypes are an order of magnitude
    slower than on f32, and the widening convert is not vectorized
    either — dequant-then-gather is ~4x faster than gather-then-astype."""
    if quant.is_fp8_pool(pool.dtype):
        return gather_pages(quant.from_fp8(pool), page_table)
    return gather_pages(pool, page_table).astype(jnp.float32)


def gather_page_scales(scales: jax.Array, page_table: jax.Array) -> jax.Array:
    """scales: (N, K, ps); page_table: (B, P) int32 -> dense (B, K, P*ps)."""
    B, P = page_table.shape
    N, K, ps = scales.shape
    g = scales[page_table]                     # (B, P, K, ps)
    return g.transpose(0, 2, 1, 3).reshape(B, K, P * ps)


def paged_gqa_decode_quant_mirror_ref(q: jax.Array, k_pages: jax.Array,
                                      v_pages: jax.Array, k_scale: jax.Array,
                                      v_scale: jax.Array,
                                      page_table: jax.Array,
                                      lengths: jax.Array) -> jax.Array:
    """Quantized-page oracle: int8 pools + per-row float32 scales.

    q: (B, H, d); k_pages, v_pages: (N, K, ps, d) int8; k_scale, v_scale:
    (N, K, ps); page_table: (B, P); lengths: (B,). Returns (B, H, d).

    Deliberately mirrors the Pallas kernel's split-K online softmax page by
    page — same dequant (int8 * per-row scale in fp32), same masked-score /
    m-l-acc update order — so interpret-mode kernel output is bit-exact
    against this reference, not merely close. Page-table slots at or past
    `lengths` contribute an exact no-op update (corr == 1, p == 0), which is
    float-identical to the kernel skipping the block.
    """
    B, H, d = q.shape
    N, K, ps, _ = k_pages.shape
    P = page_table.shape[1]
    group = H // K
    scale = 1.0 / math.sqrt(d)
    qg = (q.astype(jnp.float32) * scale).reshape(B, K, group, d)

    m = jnp.full((B, K, group), -1.0e30, jnp.float32)
    l = jnp.zeros((B, K, group), jnp.float32)
    acc = jnp.zeros((B, K, group, d), jnp.float32)
    for it in range(P):
        pid = page_table[:, it]                               # (B,)
        k = k_pages[pid].astype(jnp.float32) * k_scale[pid][..., None]
        v = v_pages[pid].astype(jnp.float32) * v_scale[pid][..., None]
        s = jnp.einsum("bkgd,bkpd->bkgp", qg, k)
        tpos = it * ps + jnp.arange(ps, dtype=jnp.int32)
        s = jnp.where(tpos[None, None, None, :] <
                      lengths[:, None, None, None], s, -1.0e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(s <= -1.0e30 / 2, 0.0, p)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgp,bkpd->bkgd", p, v)
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, d).astype(q.dtype)


def paged_gqa_decode_quant_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, k_scale: jax.Array,
                               v_scale: jax.Array, page_table: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """Vectorized quantized-page oracle — the serving `ref` backend.

    Same signature as the kernel wrapper; gathers pages and scales densely
    and runs the single-shot masked softmax of `paged_gqa_decode_ref`. The
    per-row scales factor out of the dot products, so they are folded into
    the scores (K scale) and the softmax weights (V scale) instead of
    materializing dequantized (B, K, T, d) pools. Numerically equivalent to
    the kernel within ~1e-6 but not bit-exact (different reduction order);
    `paged_gqa_decode_quant_mirror_ref` is the bit-level oracle."""
    B, H, d = q.shape
    k = gather_pages(k_pages, page_table).astype(jnp.float32)
    v = gather_pages(v_pages, page_table).astype(jnp.float32)
    ks = gather_page_scales(k_scale, page_table)             # (B, K, T)
    vs = gather_page_scales(v_scale, page_table)
    K, T = k.shape[1], k.shape[2]
    group = H // K
    qg = (q.astype(jnp.float32) / math.sqrt(d)).reshape(B, K, group, d)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k) * ks[:, :, None, :]
    valid = jnp.arange(T)[None, :] < lengths[:, None]        # (B, T)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgt,bktd->bkgd", p * vs[:, :, None, :], v)
    return out.reshape(B, H, d).astype(q.dtype)


def gather_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """pool: (N, K, ps, d); page_table: (B, P) int32 -> dense (B, K, P*ps, d)."""
    B, P = page_table.shape
    N, K, ps, d = pool.shape
    g = pool[page_table]                       # (B, P, K, ps, d)
    return g.transpose(0, 2, 1, 3, 4).reshape(B, K, P * ps, d)


def paged_gqa_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                         page_table: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B, H, d); k_pages, v_pages: (N, K, ps, d); page_table: (B, P);
    lengths: (B,) int32 true context sizes (<= P*ps). Returns (B, H, d).

    Tokens of sequence b live at pool[page_table[b, t // ps], :, t % ps]
    for t < lengths[b]; entries past `lengths` (including the tail of a
    partially-filled last page) are masked out.
    """
    B, H, d = q.shape
    K, ps = k_pages.shape[1], k_pages.shape[2]
    T = page_table.shape[1] * ps
    group = H // K
    k = _gather_pool_f32(k_pages, page_table)
    v = _gather_pool_f32(v_pages, page_table)
    qg = (q.astype(jnp.float32) / math.sqrt(d)).reshape(B, K, group, d)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k)
    valid = jnp.arange(T)[None, :] < lengths[:, None]        # (B, T)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v)
    return out.reshape(B, H, d).astype(q.dtype)
