from repro.kernels.paged_gqa_decode.ops import (  # noqa: F401
    paged_gqa_decode, paged_gqa_decode_quant)
from repro.kernels.paged_gqa_decode.ref import (  # noqa: F401
    gather_page_scales, gather_pages, paged_gqa_decode_quant_mirror_ref,
    paged_gqa_decode_quant_ref, paged_gqa_decode_ref)
