from repro.kernels.paged_gqa_decode.ops import paged_gqa_decode  # noqa: F401
from repro.kernels.paged_gqa_decode.ref import (gather_pages,  # noqa: F401
                                                paged_gqa_decode_ref)
