"""Paged GQA decode-attention Pallas TPU kernel.

Decode attention against a *paged* KV cache: K/V rows live in a global page
pool (N pages x page_size tokens), and each sequence names its pages through
an int32 page-table row. The page table and the per-sequence lengths are
scalar-prefetched (`PrefetchScalarGridSpec`), so the BlockSpec index_map
itself performs the indirection — the kernel streams exactly the pages a
sequence owns, one HBM->VMEM copy per (kv head, page), and never touches the
rest of the pool. Split-K style fp32 online softmax accumulates partial
(m, l, acc) statistics across the page grid dimension, which natively
handles ragged per-sequence lengths including a partially-filled last page.

Grid (B, K, P): kv heads are the parallel dimension (all q heads of a GQA
group ride along in VMEM and reuse the same K/V page — the paper's GQA
bytes/“slot” observation expressed as a BlockSpec), pages are the innermost
sequential dimension so the accumulator scratch carries across them.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_decode_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_sc, l_sc, acc_sc, *, scale: float, page_size: int,
                         num_pages: int):
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    length = len_ref[b]
    t_start = it * page_size

    @pl.when(t_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (ps, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        tpos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < length, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(it == num_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / denom[:, None]).astype(o_ref.dtype)


def _paged_decode_quant_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref,
                               ks_ref, vs_ref, o_ref, m_sc, l_sc, acc_sc, *,
                               scale: float, page_size: int, num_pages: int):
    """int8 variant: K/V blocks arrive as int8 plus a per-row float32 scale
    block gathered through the same page-table indirection, and are
    dequantized in-register right before the split-K online-softmax update.
    Identical control flow and accumulator math to `_paged_decode_kernel`."""
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    length = len_ref[b]
    t_start = it * page_size

    @pl.when(t_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (group, d)
        # in-register dequant: int8 payload * per-row scale
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        tpos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < length, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(it == num_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / denom[:, None]).astype(o_ref.dtype)


def paged_gqa_decode_quant_kernel(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array, k_scale: jax.Array,
                                  v_scale: jax.Array, page_table: jax.Array,
                                  lengths: jax.Array, *,
                                  interpret: bool = False) -> jax.Array:
    """q: (B, H, d); k_pages, v_pages: (N, K, ps, d) int8; k_scale, v_scale:
    (N, K, ps) float32 per-row scales; page_table: (B, P) int32;
    lengths: (B,) int32. Returns (B, H, d) in q.dtype."""
    B, H, d = q.shape
    N, K, ps, _ = k_pages.shape
    P = page_table.shape[1]
    assert H % K == 0
    group = H // K
    scale = 1.0 / math.sqrt(d)

    qg = q.reshape(B, K, group, d)
    kern = functools.partial(_paged_decode_quant_kernel, scale=scale,
                             page_size=ps, num_pages=P)

    def q_map(b, kh, it, lens, pt):
        return (b, kh, 0, 0)

    def kv_map(b, kh, it, lens, pt):
        return (pt[b, it], kh, 0, 0)

    def sc_map(b, kh, it, lens, pt):
        # per-page scales ride the same prefetched page-table indirection
        return (pt[b, it], kh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, P),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), q_map),
            pl.BlockSpec((1, 1, ps, d), kv_map),
            pl.BlockSpec((1, 1, ps, d), kv_map),
            pl.BlockSpec((1, 1, ps), sc_map),
            pl.BlockSpec((1, 1, ps), sc_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, group, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32),
      qg, k_pages, v_pages, k_scale, v_scale)
    return out.reshape(B, H, d)


def paged_gqa_decode_kernel(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, page_table: jax.Array,
                            lengths: jax.Array, *,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, d); k_pages, v_pages: (N, K, ps, d); page_table: (B, P)
    int32; lengths: (B,) int32. Returns (B, H, d)."""
    B, H, d = q.shape
    N, K, ps, _ = k_pages.shape
    P = page_table.shape[1]
    assert H % K == 0
    group = H // K
    scale = 1.0 / math.sqrt(d)

    qg = q.reshape(B, K, group, d)
    kern = functools.partial(_paged_decode_kernel, scale=scale, page_size=ps,
                             num_pages=P)

    def q_map(b, kh, it, lens, pt):
        return (b, kh, 0, 0)

    def kv_map(b, kh, it, lens, pt):
        # the page-table indirection: block row = the page this sequence
        # maps at table slot `it` (unused slots hold the null page 0 and are
        # masked out by `lengths` inside the kernel body)
        return (pt[b, it], kh, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, P),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), q_map),
            pl.BlockSpec((1, 1, ps, d), kv_map),
            pl.BlockSpec((1, 1, ps, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, group, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, d)
