"""Jit'd public wrapper for paged GQA decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_gqa_decode.kernel import paged_gqa_decode_kernel
from repro.kernels.paged_gqa_decode.ref import paged_gqa_decode_ref


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_gqa_decode(q, k_pages, v_pages, page_table, lengths, *,
                     backend: str = "auto"):
    """backend: auto | pallas | interpret | ref.

    q: (B, H, d); k_pages, v_pages: (N, K, page_size, d);
    page_table: (B, P) int32 page ids; lengths: (B,) int32. -> (B, H, d)."""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if backend == "ref":
        return paged_gqa_decode_ref(q, k_pages, v_pages, page_table, lengths)
    return paged_gqa_decode_kernel(q, k_pages, v_pages, page_table, lengths,
                                   interpret=(backend == "interpret"))
