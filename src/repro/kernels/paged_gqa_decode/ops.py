"""Jit'd public wrapper for paged GQA decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels import quant
from repro.kernels.paged_gqa_decode.kernel import (
    paged_gqa_decode_kernel, paged_gqa_decode_quant_kernel)
from repro.kernels.paged_gqa_decode.ref import (paged_gqa_decode_quant_ref,
                                                paged_gqa_decode_ref)


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_gqa_decode(q, k_pages, v_pages, page_table, lengths, *,
                     backend: str = "auto"):
    """backend: auto | pallas | interpret | ref.

    q: (B, H, d); k_pages, v_pages: (N, K, page_size, d);
    page_table: (B, P) int32 page ids; lengths: (B,) int32. -> (B, H, d)."""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if backend == "ref":
        return paged_gqa_decode_ref(q, k_pages, v_pages, page_table, lengths)
    if k_pages.dtype == quant.FP8_STORAGE_DTYPE:
        # fp8 pools travel as uint8 bit codes (see quant.FP8_STORAGE_DTYPE);
        # the kernel wants the float8 view
        k_pages = jax.lax.bitcast_convert_type(k_pages, quant.FP8_DTYPE)
        v_pages = jax.lax.bitcast_convert_type(v_pages, quant.FP8_DTYPE)
    return paged_gqa_decode_kernel(q, k_pages, v_pages, page_table, lengths,
                                   interpret=(backend == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_gqa_decode_quant(q, k_pages, v_pages, k_scale, v_scale, page_table,
                           lengths, *, backend: str = "auto"):
    """int8-page variant with fused in-register dequant.

    backend: auto | pallas | interpret | ref. q: (B, H, d); k_pages,
    v_pages: (N, K, page_size, d) int8; k_scale, v_scale: (N, K, page_size)
    float32 per-row scales; page_table: (B, P) int32; lengths: (B,) int32.
    -> (B, H, d)."""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if backend == "ref":
        return paged_gqa_decode_quant_ref(q, k_pages, v_pages, k_scale,
                                          v_scale, page_table, lengths)
    return paged_gqa_decode_quant_kernel(q, k_pages, v_pages, k_scale,
                                         v_scale, page_table, lengths,
                                         interpret=(backend == "interpret"))
