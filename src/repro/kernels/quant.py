"""Shared symmetric quantization helpers for kernels and KV page pools.

One home for the scale/round/clip logic that `kernels/int8_matmul` and the
quantized paged KV cache both use, so the write paths (prefill scatter,
decode row append, shared-prefix rewrite) and the read paths (jnp reference,
fused in-register dequant kernel) quantize identically — bit-for-bit.

Two storage formats:

  * int8 — symmetric per-row scales: each (token row, kv head) keeps a
    float32 scale ``s = max(|x|, eps) / 127`` alongside the int8 payload.
    Row granularity matters for the paged cache: a decode step appends one
    token row into an existing page, and per-row scales make that append
    local (no requantization of rows already in the page). Quantization is
    idempotent per row (the max element always maps to +-127, so a
    dequantize -> requantize round trip reproduces the same int8 codes).
  * fp8 (E4M3) — scale-free: the per-element exponent bits play the role of
    the group scale, so pages store raw ``float8_e4m3fn`` values at exactly
    1 byte/element. E4M3 has no inf and overflows to NaN, so the cast clips
    to the finite range (+-448) first.

`kv_dtype_spec` maps a serving-level kv_dtype name to (pool dtype,
bytes/element, scale bytes/row) so `serve.paged.page_bytes` and the
Stage-I ledgers account the true physical footprint.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

INT8_QMAX = 127.0
SCALE_EPS = 1e-8
FP8_DTYPE = jnp.float8_e4m3fn
FP8_MAX = float(jnp.finfo(jnp.float8_e4m3fn).max)        # 448.0


def quantize_rows(x: jax.Array):
    """Symmetric per-row int8 quantization: x ~= q * s (s keeps dims)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(amax, SCALE_EPS) / INT8_QMAX
    q = jnp.clip(jnp.round(x / s), -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def quantize_cols(w: jax.Array):
    """Symmetric per-column int8 quantization: w ~= q * s."""
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    s = jnp.maximum(amax, SCALE_EPS) / INT8_QMAX
    q = jnp.clip(jnp.round(w / s), -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def quantize_page_rows(x: jax.Array):
    """Per-row int8 for page pools: (..., rows, d) -> q (..., rows, d) int8
    and s (..., rows) float32, one scale per row (the last axis is the
    quantization group)."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    s = jnp.maximum(amax, SCALE_EPS) / INT8_QMAX
    q = jnp.clip(jnp.round(x / s[..., None]),
                 -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def dequantize_page_rows(q: jax.Array, s: jax.Array) -> jax.Array:
    """Inverse of `quantize_page_rows`: q (..., rows, d), s (..., rows)."""
    return q.astype(jnp.float32) * s[..., None].astype(jnp.float32)


def to_fp8(x: jax.Array) -> jax.Array:
    """Saturating cast to E4M3 (values beyond +-448 clip, never NaN)."""
    return jnp.clip(x.astype(jnp.float32), -FP8_MAX, FP8_MAX).astype(FP8_DTYPE)


# fp8 pools are STORED as uint8 bit codes, not as float8 arrays: CPU XLA
# treats the ml_dtypes float8 types as exotic everywhere — gathers, scatters
# and especially the lax.scan slice/stack over stacked per-layer pools run
# 10-100x slower than the same ops on u8 (measured: a pass-through scan over
# (L, N, K, ps, d) pools is ~1.3 ms as float8_e4m3fn vs ~14 us as uint8).
# The bit pattern is identical either way; `to_fp8_codes` / `from_fp8`
# bitcast at the few sites that touch values.
FP8_STORAGE_DTYPE = jnp.dtype(jnp.uint8)


def is_fp8_pool(dtype) -> bool:
    """True for a KV pool holding E4M3 codes (stored u8 or native fp8)."""
    dt = jnp.dtype(dtype)
    return dt == FP8_STORAGE_DTYPE or dt == jnp.dtype(FP8_DTYPE)


def to_fp8_codes(x: jax.Array) -> jax.Array:
    """Saturating E4M3 cast, returned as uint8 storage codes."""
    return jax.lax.bitcast_convert_type(to_fp8(x), FP8_STORAGE_DTYPE)


_FP8_F32_TABLE = None


def from_fp8(x: jax.Array) -> jax.Array:
    """E4M3 (as float8 values or uint8 codes) -> float32 by 256-entry table
    lookup. Bit-identical to ``x.astype(float32)`` of the float8 view but
    measurably faster on CPU XLA, where the widening convert is not
    vectorized — and the jnp reference attention is the decode hot path
    whenever there is no TPU."""
    global _FP8_F32_TABLE
    if _FP8_F32_TABLE is None:
        import numpy as np
        # kept as numpy: a cached jax.Array created under a trace would
        # leak a tracer; as a numpy constant it folds into each jaxpr
        _FP8_F32_TABLE = np.arange(256, dtype=np.uint8).view(
            np.dtype(FP8_DTYPE)).astype(np.float32)
    idx = (x if x.dtype == FP8_STORAGE_DTYPE
           else jax.lax.bitcast_convert_type(x, jnp.uint8)).astype(jnp.int32)
    return jnp.take(jnp.asarray(_FP8_F32_TABLE), idx, axis=0)


@dataclasses.dataclass(frozen=True)
class KVDtypeSpec:
    """Resolved kv_dtype: pool storage dtype plus physical byte accounting."""
    name: str
    pool_dtype: Any
    itemsize: int                 # payload bytes per cached element
    scale_bytes_per_row: int      # extra bytes per (token row, kv head)
    quantized: bool

    @property
    def has_scales(self) -> bool:
        return self.scale_bytes_per_row > 0


_FLOAT_KV_DTYPES = {
    "fp32": jnp.float32,
    "bf16": jnp.bfloat16,
    "fp16": jnp.float16,
}


def kv_dtype_spec(name: str, native: Optional[Any] = None) -> KVDtypeSpec:
    """Resolve a serving-level kv_dtype name.

    "native" (the default knob) stores pages in `native` (the model compute
    dtype) — the pre-quantization behaviour. "fp32"/"bf16"/"fp16" force a
    float pool dtype; "int8" selects per-row-scale int8 pools; "fp8"
    selects scale-free E4M3 pools.
    """
    if name == "native":
        if native is None:
            raise ValueError("kv_dtype='native' needs the model dtype")
        dt = jnp.dtype(native)
        return KVDtypeSpec("native", dt, dt.itemsize, 0, False)
    if name in _FLOAT_KV_DTYPES:
        dt = jnp.dtype(_FLOAT_KV_DTYPES[name])
        return KVDtypeSpec(name, dt, dt.itemsize, 0, False)
    if name == "int8":
        return KVDtypeSpec("int8", jnp.dtype(jnp.int8), 1, 4, True)
    if name == "fp8":
        # storage dtype is uint8: the pools hold E4M3 bit codes (see the
        # FP8_STORAGE_DTYPE note above); `from_fp8` decodes at read sites
        return KVDtypeSpec("fp8", FP8_STORAGE_DTYPE, 1, 0, True)
    raise ValueError(f"unknown kv_dtype {name!r} (want native/fp32/bf16/"
                     f"fp16/int8/fp8)")


def kv_dtype_bytes(name: str, native: Optional[Any] = None) -> int:
    """Payload bytes/element for a kv_dtype name (model-free simulators)."""
    return kv_dtype_spec(name, native).itemsize
