from repro.kernels.int8_matmul.ops import int8_matmul, quantized_linear  # noqa: F401
from repro.kernels.int8_matmul.ref import (int8_matmul_ref, quantize_cols,  # noqa: F401
                                           quantize_rows)
from repro.kernels.int8_matmul.kernel import int8_matmul_kernel  # noqa: F401
