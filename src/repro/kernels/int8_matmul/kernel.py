"""int8 x int8 -> int32 quantized matmul Pallas TPU kernel.

The paper's accelerator computes uniformly in 8-bit operands; this is the TPU
serving-path analogue: int8 weights/activations with per-row (activation) and
per-column (weight) fp32 scales, int32 MXU accumulation, dequantized output.

Grid (nm, nn, nk) with nk innermost; (bm, bn) int32 accumulator in VMEM
scratch; 128-aligned blocks for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_mm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_sc, *,
                    num_k_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    x = x_ref[...].astype(jnp.int32)          # prepromotion for int matmul
    w = w_ref[...].astype(jnp.int32)
    acc_sc[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        sx = sx_ref[...].astype(jnp.float32)          # (bm, 1)
        sw = sw_ref[...].astype(jnp.float32)          # (1, bn)
        o_ref[...] = (acc_sc[...].astype(jnp.float32) * sx * sw).astype(
            o_ref.dtype)


def int8_matmul_kernel(x: jax.Array, w: jax.Array, sx: jax.Array,
                       sw: jax.Array, *, block_m: int = 128,
                       block_n: int = 128, block_k: int = 128,
                       out_dtype=jnp.float32,
                       interpret: bool = False) -> jax.Array:
    """x: (M, K) int8; w: (K, N) int8; sx: (M, 1) f32; sw: (1, N) f32."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    grid = (M // block_m, N // block_n, K // block_k)

    kern = functools.partial(_int8_mm_kernel, num_k_blocks=grid[2])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda im, in_, ik: (im, ik)),
            pl.BlockSpec((block_k, block_n), lambda im, in_, ik: (ik, in_)),
            pl.BlockSpec((block_m, 1), lambda im, in_, ik: (im, 0)),
            pl.BlockSpec((1, block_n), lambda im, in_, ik: (0, in_)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda im, in_, ik: (im, in_)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x, w, sx, sw)
