"""Pure-jnp oracle for the int8 matmul kernel + quantization helpers.

The scale/round/clip logic lives in `repro.kernels.quant` (shared with the
quantized paged KV pools); `quantize_rows`/`quantize_cols` stay importable
from here for compatibility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant import quantize_cols, quantize_rows  # noqa: F401


def int8_matmul_ref(x: jax.Array, w: jax.Array, sx: jax.Array,
                    sw: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    return (acc.astype(jnp.float32) * sx.astype(jnp.float32)
            * sw.astype(jnp.float32)).astype(out_dtype)
