"""Pure-jnp oracle for the int8 matmul kernel + quantization helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul_ref(x: jax.Array, w: jax.Array, sx: jax.Array,
                    sw: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    return (acc.astype(jnp.float32) * sx.astype(jnp.float32)
            * sw.astype(jnp.float32)).astype(out_dtype)


def quantize_rows(x: jax.Array):
    """Symmetric per-row int8 quantization: x ~= q * s."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def quantize_cols(w: jax.Array):
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)
