"""Jit'd public wrapper: quantized linear y = dequant(int8(x) @ int8(w))."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul.kernel import int8_matmul_kernel
from repro.kernels.int8_matmul.ref import (int8_matmul_ref, quantize_cols,
                                           quantize_rows)


@functools.partial(jax.jit, static_argnames=("backend", "block_m", "block_n",
                                             "block_k"))
def int8_matmul(x_q, w_q, sx, sw, *, backend: str = "auto",
                block_m: int = 128, block_n: int = 128, block_k: int = 128):
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if backend == "ref":
        return int8_matmul_ref(x_q, w_q, sx, sw)
    return int8_matmul_kernel(x_q, w_q, sx, sw, block_m=block_m,
                              block_n=block_n, block_k=block_k,
                              interpret=(backend == "interpret"))


def quantized_linear(x: jax.Array, w: jax.Array, *, backend: str = "auto"):
    """Full path: quantize fp activations/weights, int8 matmul, dequantize."""
    x_q, sx = quantize_rows(x)
    w_q, sw = quantize_cols(w)
    return int8_matmul(x_q, w_q, sx, sw, backend=backend)
