"""GQA decode-attention Pallas TPU kernel: one query token per sequence
against a (possibly partially filled) KV cache.

This is the TPU-native reading of the paper's insight: decode attention is
bandwidth-bound on KV-cache reads (HBM->VMEM), and GQA divides those bytes by
the sharing group size — all q heads of a group consume the same K/V block,
which the index_map expresses directly. The paper's SRAM banking question
("how much of the cache must be live?") becomes the cache-length mask here.

Grid (B, K, nt): kv heads (not q heads) are the parallel dimension so each
K/V block is streamed exactly once per sequence; the whole q-head group
(group x d) rides along in VMEM. fp32 online softmax across nt blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                   scale: float, block_t: int, num_t_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    length = len_ref[0]
    t_start = it * block_t

    @pl.when(t_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bt, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        tpos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < length, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(it == num_t_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / denom[:, None]).astype(o_ref.dtype)


def gqa_decode_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                      lengths: jax.Array, *, block_t: int = 256,
                      interpret: bool = False) -> jax.Array:
    """q: (B, H, d); k, v: (B, K, T, d); lengths: (B,) int32 valid-cache sizes.

    Returns (B, H, d)."""
    B, H, d = q.shape
    K, T = k.shape[1], k.shape[2]
    assert H % K == 0
    group = H // K
    block_t = min(block_t, T)
    assert T % block_t == 0
    nt = T // block_t
    scale = 1.0 / math.sqrt(d)

    qg = q.reshape(B, K, group, d)
    grid = (B, K, nt)
    kern = functools.partial(_decode_kernel, scale=scale, block_t=block_t,
                             num_t_blocks=nt)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, kh, it: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, d), lambda b, kh, it: (b, kh, 0, 0)),
            pl.BlockSpec((1, 1, block_t, d), lambda b, kh, it: (b, kh, it, 0)),
            pl.BlockSpec((1, 1, block_t, d), lambda b, kh, it: (b, kh, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda b, kh, it: (b, kh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, d)
