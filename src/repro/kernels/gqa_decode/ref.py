"""Pure-jnp oracle for the GQA decode kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gqa_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                   lengths: jax.Array) -> jax.Array:
    """q: (B, H, d); k, v: (B, K, T, d); lengths: (B,)."""
    B, H, d = q.shape
    K, T = k.shape[1], k.shape[2]
    group = H // K
    qg = (q.astype(jnp.float32) / math.sqrt(d)).reshape(B, K, group, d)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32))
    valid = jnp.arange(T)[None, :] < lengths[:, None]        # (B, T)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, d).astype(q.dtype)
