"""Jit'd public wrapper for GQA decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.gqa_decode.kernel import gqa_decode_kernel
from repro.kernels.gqa_decode.ref import gqa_decode_ref


@functools.partial(jax.jit, static_argnames=("backend", "block_t"))
def gqa_decode(q, k, v, lengths, *, backend: str = "auto",
               block_t: int = 256):
    """backend: auto | pallas | interpret | ref."""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if backend == "ref":
        return gqa_decode_ref(q, k, v, lengths)
    return gqa_decode_kernel(q, k, v, lengths, block_t=block_t,
                             interpret=(backend == "interpret"))
