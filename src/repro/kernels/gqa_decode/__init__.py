from repro.kernels.gqa_decode.ops import gqa_decode  # noqa: F401
from repro.kernels.gqa_decode.ref import gqa_decode_ref  # noqa: F401
from repro.kernels.gqa_decode.kernel import gqa_decode_kernel  # noqa: F401
