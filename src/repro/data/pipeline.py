"""Deterministic, resumable synthetic-token data pipeline.

Batches are a pure function of (seed, step, shard) via counter-based Philox
RNG — no pipeline state to checkpoint: restoring a run at step N reproduces
exactly the batches a never-preempted run would have seen (the property the
fault-tolerance test asserts). A background prefetch thread hides generation
latency.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so the LM loss actually decreases during examples
    structured: bool = True


class SyntheticTokens:
    """Shard-aware deterministic token stream."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        key = (np.uint64(c.seed) << np.uint64(32)) ^ np.uint64(0xD5)
        rng = np.random.Generator(np.random.Philox(
            key=[key, np.uint64(step) << np.uint64(16) | np.uint64(self.shard)]))
        B, S, V = self.local_batch, c.seq_len, c.vocab_size
        if not c.structured:
            toks = rng.integers(0, V, size=(B, S), dtype=np.int64)
        else:
            # piecewise-linear token ramps: learnable local structure
            start = rng.integers(0, V, size=(B, 1))
            stride = rng.integers(1, 17, size=(B, 1))
            noise = rng.integers(0, 2, size=(B, S))
            toks = (start + stride * np.arange(S)[None, :] + noise) % V
        batch = {"tokens": toks.astype(np.int32)}
        batch["labels"] = batch["tokens"]
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue; resumable via start_step."""

    def __init__(self, ds: SyntheticTokens, start_step: int = 0,
                 depth: int = 2):
        self.ds = ds
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.ds.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
