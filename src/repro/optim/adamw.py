"""AdamW with global-norm clipping — pure-pytree implementation (no optax
dependency). Optimizer state is two pytrees (m, v) mirroring the params, so
ZeRO-1 sharding is just a different NamedSharding on those trees.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]      # schedule: step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros))

    def update(self, grads: Any, state: AdamWState,
               params: Any) -> Tuple[Any, AdamWState, dict]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

        step = state.step + 1
        lr = self.lr(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        new_m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                             state.m, grads)
        new_v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                             state.v, grads)

        def upd(p, m, v):
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay > 0:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, new_m, new_v)
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step, new_m, new_v), metrics


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
