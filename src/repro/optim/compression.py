"""Gradient compression for the data-parallel all-reduce.

Two modes used at scale:
  * bf16   — cast gradients to bf16 before the DP all-reduce (2x bytes off the
             wire; XLA keeps the reduction in fp32 accumulation).
  * int8ef — symmetric per-leaf int8 with error feedback: the quantization
             residual is carried into the next step, keeping the compressed
             SGD direction unbiased over time.

The compression hooks into train/step.py before gradients cross the DP axes —
under GSPMD that is exactly the tensor that rides the all-reduce.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress_bf16(grads: Any) -> Any:
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_int8_ef(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads to feed the optimizer, new error state).

    q = round(clip((g+e)/s)) with per-leaf amax scaling; e' = (g+e) - q*s.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / s), -127, 127)
        deq = q * s
        return deq, x - deq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = tdef.unflatten([o[0] for o in outs])
    new_err = tdef.unflatten([o[1] for o in outs])
    return deq, new_err


def apply_compression(grads: Any, mode: str,
                      err: Optional[Any] = None) -> Tuple[Any, Optional[Any]]:
    if mode == "none":
        return grads, err
    if mode == "bf16":
        return compress_bf16(grads), err
    if mode == "int8ef":
        assert err is not None
        return compress_int8_ef(grads, err)
    raise ValueError(mode)
