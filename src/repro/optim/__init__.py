from repro.optim.adamw import AdamW, AdamWState, global_norm  # noqa: F401
from repro.optim.schedule import cosine_with_warmup, constant  # noqa: F401
from repro.optim.compression import (apply_compression, compress_bf16,  # noqa: F401
                                     compress_int8_ef, init_error_state)
