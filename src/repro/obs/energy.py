"""Streaming per-bank energy meter with per-request/per-tenant attribution.

Stage II (`core.gating.evaluate`) replays finished traces offline; the
:class:`BankEnergyMeter` turns the same Eq. (2)-(5) energy model into a
*live* observable. It subscribes to the very delta events the occupancy
traces are built from (page alloc/free/COW/truncate in the serving ledgers,
`trace.event` in the model-free traffic sims) and maintains, online on the
sim clock, a per-bank state machine — active / drowsy / gated, wake
transients, stall windows — for one ``(C, B, alpha, policy)`` candidate.

Exactness contract: every event is mirrored into an internal
`OccupancyTrace`, and :meth:`finalize` runs the *offline scalar reference*
over the mirrored stream through the identical assembly pipeline
(stable time sort -> integrate -> collapse duplicate timestamps ->
segment). The meter's cumulative energy is therefore **bit-identical
(f64)** to `gating.evaluate` on the same trace — pinned across all four
policies by ``tests/test_energy_attribution.py``. The online machine is
additionally pinned structurally: its per-segment activity equals
`gating.bank_timeline`'s exactly, its transition count equals the
reference's ``n_transitions`` exactly, and its sequentially-accumulated
energy agrees with the reference to float roundoff (the reference's
pairwise numpy reductions are the only difference).

Attribution: every accounted joule is charged either to the request (and
tenant) whose tagged page events caused or sustained it — switch energy to
the request whose event woke the bank, retention pro rata over the bytes
each live request holds — or to an explicit *floor* (idle-bank leakage,
unattributed/cache retention, short-idle retention, trailing transitions).
Conservation, monotone non-negative charges and arrival-permutation
invariance are property-tested.
"""
from __future__ import annotations

import copy
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cacti import (WAKEUP_LATENCY_NS, SramCharacterization,
                              characterize)
from repro.core.gating import GatingResult, Policy, evaluate
from repro.sim.trace import OccupancyTrace

WAKE_CAUSES = ("admission", "decode_growth", "cow", "spec_rollback",
               "prewake", "other")

# bank states reported on intervals / Perfetto tracks
STATE_ACTIVE = "active"
STATE_IDLE = "idle"          # short idle, bank kept fully powered
STATE_DROWSY = "drowsy"      # short idle at retention voltage
STATE_GATED = "gated"


class _OutOfOrder(Exception):
    pass


class _Machine:
    """Online per-bank state machine over closed occupancy segments.

    Mirrors `gating.evaluate`'s arithmetic wherever a sequential form
    exists bit-for-bit: segment durations are direct subtractions
    (== np.diff), the duration prefix sum is sequential (== np.cumsum), so
    per-run idle durations and every gate/no-gate threshold decision are
    exact. Only the grand totals differ from the reference's pairwise
    reductions, and only in the last ulps."""

    def __init__(self, capacity: int, banks: int, policy: Policy,
                 char: SramCharacterization, use: str, keep_series: bool):
        self.capacity, self.B = capacity, banks
        self.policy, self.ch, self.use = policy, char, use
        self.keep_series = keep_series
        self.usable = policy.alpha * (capacity / banks)
        self.threshold = policy.min_gate_multiple * char.break_even_s
        self.leak_w = char.leak_w_per_bank
        self.e_switch = char.e_switch_j
        self.wake_s = WAKEUP_LATENCY_NS * 1e-9
        self.drowsy = (policy.drowsy_fraction != 1.0
                       or policy.drowsy_switch_fraction != 0.0)
        # integrated occupancy
        self.needed = 0
        self.obsolete = 0
        # open segment / group state; the "group" is all events sharing the
        # open segment's start timestamp (the trace collapses them into one
        # step), and the wake attribution winner is chosen by an
        # order-independent key so delivery permutations cannot flip it
        self.t0: Optional[float] = None
        self.group_rid = None
        self.group_tenant = None
        self.group_cause: Optional[str] = None
        self.group_key: Optional[Tuple] = None
        self.group_ckey: Optional[Tuple] = None
        # sequential prefix sum of closed segment durations (== np.cumsum)
        self.cum_d = 0.0
        self.nseg = 0
        self.prev_act = banks                # "all on" before the timeline
        self.bank_on_since = [math.nan] * banks
        self.idle_start_cum: List[Optional[float]] = [None] * banks
        self.idle_start_t = [math.nan] * banks
        # accumulators (sequential f64)
        self.e_leak = 0.0
        self.e_sw = 0.0
        self.on_bank_s = 0.0                 # required (active) bank-seconds
        self.gated_s = 0.0
        self.drowsy_s = 0.0
        self.n_sw = 0
        self.n_drowsy = 0
        self.stall_s = 0.0
        self.wakes: Dict[str, int] = {}
        # attribution
        self.held: Dict[object, float] = {}          # rid -> live bytes
        self.req_j: Dict[object, float] = {}
        self.tenant_j: Dict[str, float] = {}
        self.rid_tenant: Dict[object, str] = {}
        self.floor_j = 0.0
        # series + intervals for dashboards / Perfetto export
        self.seg_t0: List[float] = []
        self.seg_dur: List[float] = []
        self.seg_act: List[int] = []
        self.seg_cum_j: List[float] = []
        self.intervals: List[Tuple[int, str, float, float]] = []

    # ----------------------------------------------------------- charging
    def _charge(self, j: float, rid, cause: Optional[str]) -> None:
        if j == 0.0:
            return
        if rid is None:
            self.floor_j += j
            return
        self.req_j[rid] = self.req_j.get(rid, 0.0) + j
        ten = self.rid_tenant.get(rid)
        if ten is not None:
            self.tenant_j[ten] = self.tenant_j.get(ten, 0.0) + j

    def _activity(self) -> int:
        occ = (self.needed if self.use == "needed"
               else self.needed + self.obsolete)
        v = np.ceil(np.float64(occ) / self.usable)
        return int(min(max(v, 0.0), float(self.B)))

    def _resolve_idle_run(self, b: int, run_d: float, t_end: float,
                          wake: bool) -> None:
        """An idle run of bank `b` closed (a wake at `t_end`, or the
        timeline flushed). Gate/drowsy decision + charging, matching the
        reference's per-run arithmetic."""
        start_t = self.idle_start_t[b]
        self.idle_start_cum[b] = None
        if run_d >= self.threshold:
            self.n_sw += 1
            self.gated_s += run_d
            self.e_sw += self.e_switch
            state = STATE_GATED
            if wake:
                cause = self.group_cause or "other"
                self.wakes[cause] = self.wakes.get(cause, 0) + 1
                self.stall_s += self.wake_s
                self._charge(self.e_switch, self.group_rid, cause)
            else:
                self.floor_j += self.e_switch
        elif self.drowsy:
            self.n_drowsy += 1
            self.drowsy_s += run_d
            retain = self.policy.drowsy_fraction * self.leak_w * run_d
            sw = self.e_switch * self.policy.drowsy_switch_fraction
            self.e_leak += retain
            self.e_sw += sw
            self.floor_j += retain            # retained data serves everyone
            state = STATE_DROWSY
            if wake:
                cause = self.group_cause or "other"
                self.wakes[cause] = self.wakes.get(cause, 0) + 1
                self._charge(sw, self.group_rid, cause)
            else:
                self.floor_j += sw
        else:
            # classic two-state: too short to gate, bank stayed fully on
            leak = self.leak_w * run_d
            self.e_leak += leak
            self.floor_j += leak
            state = STATE_IDLE
        if self.keep_series and not math.isnan(start_t):
            self.intervals.append((b, state, start_t, t_end))
        self.bank_on_since[b] = t_end

    def _close_segment(self, t: float) -> None:
        """Close the open segment [t0, t); occupancy state already holds
        every event at t0 and nothing later."""
        t0 = self.t0
        dur = t - t0
        if dur <= 0.0:
            return
        act = self._activity()
        cum0 = self.cum_d                    # == cum[i] before this segment
        if self.policy.gate:
            if act > self.prev_act:          # banks woke at t0
                for b in range(self.prev_act, act):
                    if self.idle_start_cum[b] is not None:
                        run_d = cum0 - self.idle_start_cum[b]
                        self._resolve_idle_run(b, run_d, t0, wake=True)
                    else:
                        self.bank_on_since[b] = t0
            elif act < self.prev_act:        # banks went idle at t0
                for b in range(act, self.prev_act):
                    self.idle_start_cum[b] = cum0
                    self.idle_start_t[b] = t0
                    if self.keep_series and not math.isnan(
                            self.bank_on_since[b]):
                        self.intervals.append(
                            (b, STATE_ACTIVE, self.bank_on_since[b], t0))
        # retention of the banks the occupancy requires, split pro rata
        # over the bytes each live request holds
        e_on = self.leak_w * act * dur
        self.e_leak += (e_on if self.policy.gate
                        else self.leak_w * self.B * dur)
        if not self.policy.gate:
            self.floor_j += self.leak_w * (self.B - act) * dur
        if e_on > 0.0:
            W = 0.0
            for h in self.held.values():
                if h > 0.0:
                    W += h
            if W > 0.0:
                for rid, h in self.held.items():
                    if h > 0.0:
                        self._charge(e_on * (h / W), rid, None)
            else:
                self.floor_j += e_on
        self.on_bank_s += act * dur
        self.cum_d += dur
        self.nseg += 1
        self.prev_act = act
        if self.keep_series:
            self.seg_t0.append(t0)
            self.seg_dur.append(dur)
            self.seg_act.append(act)
            self.seg_cum_j.append(self.e_leak + self.e_sw)

    # ------------------------------------------------------------ ingest
    def push(self, t: float, dn: int, do: int, rid, tenant,
             cause: Optional[str], wdelta: Optional[int]) -> None:
        w = dn if wdelta is None else wdelta
        if dn == 0 and do == 0:
            # pure holdings update — never a step-function boundary (the
            # trace drops it), so it must not split a segment; a stale one
            # still forces the replay path so holdings land in time order
            if self.t0 is not None and t < self.t0:
                raise _OutOfOrder
            if rid is not None:
                if tenant is not None:
                    self.rid_tenant[rid] = tenant
                if w:
                    self.held[rid] = self.held.get(rid, 0.0) + w
            return
        if self.t0 is None:
            self.t0 = t
        elif t > self.t0:
            self._close_segment(t)
            self.t0 = t
            self.group_rid = self.group_tenant = self.group_cause = None
            self.group_key = self.group_ckey = None
        elif t < self.t0:
            raise _OutOfOrder
        self.needed += dn
        self.obsolete += do
        if rid is not None:
            if tenant is not None:
                self.rid_tenant[rid] = tenant
            if w:
                self.held[rid] = self.held.get(rid, 0.0) + w
        if dn > 0:
            if rid is not None:
                key = (dn, str(rid))
                if self.group_key is None or key > self.group_key:
                    self.group_key = key
                    self.group_rid, self.group_tenant = rid, tenant
            if cause is not None:
                ckey = (dn, cause)
                if self.group_ckey is None or ckey > self.group_ckey:
                    self.group_ckey = ckey
                    self.group_cause = cause

    def flush(self, end_time: float) -> None:
        """Close the final segment against `end_time` and resolve trailing
        idle runs — mirrors `segments()`'s trailing edge and the
        reference's runs that end at the last segment."""
        if self.t0 is not None:
            self._close_segment(max(end_time, self.t0))
            self.t0 = None
        if self.policy.gate:
            for b in range(self.B):
                if self.idle_start_cum[b] is not None:
                    run_d = self.cum_d - self.idle_start_cum[b]
                    self._resolve_idle_run(b, run_d, float("nan"), wake=False)
                elif self.keep_series and not math.isnan(
                        self.bank_on_since[b]):
                    self.intervals.append(
                        (b, STATE_ACTIVE, self.bank_on_since[b],
                         max(end_time, self.bank_on_since[b])))
        elif self.keep_series and self.seg_t0:
            for b in range(self.B):
                self.intervals.append(
                    (b, STATE_ACTIVE, self.seg_t0[0],
                     max(end_time, self.seg_t0[0])))
        # intervals whose run closed at flush have a NaN end: pin them
        if self.keep_series:
            self.intervals = [
                (b, st, a, (max(end_time, a) if math.isnan(e) else e))
                for (b, st, a, e) in self.intervals]


@dataclass
class MeterReport:
    """Headline view of one metered run — campaign rows and the obs CLI."""
    result: GatingResult                     # exact (bit-identical) Stage II
    live_e_j: float                          # online accumulation (no e_dyn)
    request_j: Dict[object, float]
    tenant_j: Dict[str, float]
    floor_j: float
    wakes: Dict[str, int]
    stall_s: float
    j_per_request: Tuple[float, float, float] = (0.0, 0.0, 0.0)  # p50/90/99
    j_per_token: Tuple[float, float, float] = (0.0, 0.0, 0.0)

    def format(self) -> str:
        r = self.result
        lines = [
            f"bank energy meter  C={r.capacity / 2**20:g} MiB B={r.banks} "
            f"alpha={r.alpha:g} policy={r.policy}",
            f"  E_total={r.e_total * 1e3:.4g} mJ  (dyn {r.e_dyn * 1e3:.4g}, "
            f"leak {r.e_leak * 1e3:.4g}, sw {r.e_sw * 1e3:.4g})  "
            f"transitions={r.n_transitions}  stall={self.stall_s * 1e3:.3g} ms",
        ]
        if self.wakes:
            ws = ", ".join(f"{c}={n}" for c, n in sorted(self.wakes.items()))
            lines.append(f"  wakes: {ws}")
        if self.request_j:
            p50, p90, p99 = self.j_per_request
            lines.append(f"  J/request p50={p50:.3e} p90={p90:.3e} "
                         f"p99={p99:.3e}  attributed "
                         f"{sum(self.request_j.values()) * 1e3:.4g} mJ, "
                         f"floor {self.floor_j * 1e3:.4g} mJ")
        if any(self.j_per_token):
            p50, p90, p99 = self.j_per_token
            lines.append(f"  J/token   p50={p50:.3e} p90={p90:.3e} "
                         f"p99={p99:.3e}")
        for ten, j in sorted(self.tenant_j.items(),
                             key=lambda kv: -kv[1])[:8]:
            lines.append(f"    tenant {ten}: {j * 1e3:.4g} mJ")
        return "\n".join(lines)


class BankEnergyMeter:
    """Online Stage-II energy for one ``(C, B, alpha, policy)`` candidate.

    Feed it the same (t, d_needed, d_obsolete) delta events the occupancy
    trace receives — tagged with the causing request/tenant — via
    :meth:`record`; query live state any time; :meth:`finalize` returns the
    bit-identical offline `GatingResult`."""

    def __init__(self, capacity: int, banks: int, *,
                 policy: Union[Policy, str] = "conservative",
                 alpha: Optional[float] = None,
                 char: Optional[SramCharacterization] = None,
                 use: str = "needed", telemetry=None,
                 keep_series: bool = True):
        if use not in ("needed", "total"):
            raise ValueError(f"use must be needed|total, got {use!r}")
        if isinstance(policy, str):
            policy = Policy.by_name(policy, alpha)
        elif alpha is not None and alpha != policy.alpha:
            from dataclasses import replace
            policy = replace(policy, alpha=alpha)
        self.capacity = int(capacity)
        self.banks = int(banks)
        self.policy = policy
        self.char = char or characterize(self.capacity, self.banks)
        self.use = use
        self.tel = telemetry
        self.keep_series = keep_series
        # the exactness substrate: a verbatim mirror of the event stream
        self.trace = OccupancyTrace("meter", self.capacity)
        # parallel log (incl. zero-delta holdings updates) for replay
        self._t: List[float] = []
        self._dn: List[int] = []
        self._do: List[int] = []
        self._tags: List[Tuple] = []
        self._m = self._fresh_machine()
        self._dirty = False
        self._last_t = 0.0
        self._prewakes = 0
        self._published: Dict[str, int] = {}
        self.n_events = 0

    @classmethod
    def from_spec(cls, spec: str, *, telemetry=None,
                  keep_series: bool = True) -> "BankEnergyMeter":
        """Parse a CLI meter spec ``C_mib,B[,alpha[,policy]]`` — e.g.
        ``64,8,0.9,conservative`` — into a configured meter."""
        parts = [p.strip() for p in str(spec).split(",")]
        if len(parts) < 2 or len(parts) > 4:
            raise ValueError(
                f"meter spec must be 'C_mib,B[,alpha[,policy]]', got {spec!r}")
        cap = int(round(float(parts[0]) * 2**20))
        banks = int(parts[1])
        alpha = float(parts[2]) if len(parts) >= 3 else None
        policy = parts[3] if len(parts) == 4 else "conservative"
        return cls(cap, banks, policy=policy, alpha=alpha,
                   telemetry=telemetry, keep_series=keep_series)

    # ------------------------------------------------------------- ingest
    def _fresh_machine(self) -> _Machine:
        return _Machine(self.capacity, self.banks, self.policy, self.char,
                        self.use, self.keep_series)

    def record(self, t: float, d_needed: int, d_obsolete: int = 0, *,
               rid=None, tenant: Optional[str] = None,
               cause: Optional[str] = None,
               weight_delta: Optional[int] = None) -> None:
        """One ledger delta event: `d_needed`/`d_obsolete` mirror the trace
        deltas; `weight_delta` overrides the attribution-holdings change
        when it differs from `d_needed` (shared/COW pages)."""
        t = float(t)
        dn, do = int(d_needed), int(d_obsolete)
        self._t.append(t)
        self._dn.append(dn)
        self._do.append(do)
        self._tags.append((rid, tenant, cause, weight_delta))
        self.trace.event(t, dn, do)
        self.n_events += 1
        if t > self._last_t:
            self._last_t = t
        if not self._dirty:
            try:
                self._m.push(t, dn, do, rid, tenant, cause, weight_delta)
            except _OutOfOrder:
                self._dirty = True

    def record_bulk(self, times, d_needed, d_obsolete, *,
                    rids: Optional[Sequence] = None,
                    tenants: Optional[Sequence] = None,
                    cause: Optional[str] = None) -> None:
        """Vectorized-source mirror (the traffic sims' `trace.extend`
        path); event order is preserved element-wise."""
        times = np.asarray(times, np.float64)
        dns = np.asarray(d_needed, np.int64)
        dos = np.asarray(d_obsolete, np.int64)
        for i in range(len(times)):
            self.record(float(times[i]), int(dns[i]), int(dos[i]),
                        rid=None if rids is None else rids[i],
                        tenant=None if tenants is None else tenants[i],
                        cause=cause)

    def note_prewake(self, n: int = 1) -> None:
        """A controller pre-wake happened (forecast leg): counted in the
        wake-cause family without perturbing the exact energy integral."""
        self._prewakes += int(n)

    # ----------------------------------------------------------- queries
    def _machine(self) -> _Machine:
        if self._dirty:
            m = self._fresh_machine()
            order = np.argsort(np.asarray(self._t, np.float64),
                               kind="stable")
            for i in order:
                m.push(self._t[i], self._dn[i], self._do[i], *self._tags[i])
            self._m = m
            self._dirty = False
        return self._m

    def _flushed(self, end_time: Optional[float]) -> _Machine:
        end = self._last_t if end_time is None else float(end_time)
        m = copy.deepcopy(self._machine())
        m.flush(end)
        return m

    def finalize(self, end_time: Optional[float] = None, *,
                 n_reads: int = 0, n_writes: int = 0) -> GatingResult:
        """The exact Stage-II result of the streamed trace: assembled by
        the identical `OccupancyTrace` pipeline and evaluated by the
        offline scalar reference — bit-identical f64 to `gating.evaluate`
        on the source trace."""
        end = self._last_t if end_time is None else float(end_time)
        dur, occ = self.trace.occupancy_series(end, use=self.use)
        res = evaluate(dur, occ, capacity=self.capacity, banks=self.banks,
                       policy=self.policy, n_reads=n_reads,
                       n_writes=n_writes, char=self.char)
        self._publish_counters()
        return res

    def energy_j(self, end_time: Optional[float] = None) -> float:
        """Live (sequentially accumulated) leakage + switching energy."""
        m = self._flushed(end_time)
        return m.e_leak + m.e_sw

    def request_energy_j(self, end_time: Optional[float] = None
                         ) -> Dict[object, float]:
        return dict(self._flushed(end_time).req_j)

    def request_energy(self, rid, end_time: Optional[float] = None) -> float:
        return self._flushed(end_time).req_j.get(rid, 0.0)

    def request_energy_live(self, rid) -> float:
        """O(1) unflushed charge — no copy, no trailing-run resolution.
        Exact-final for a request whose pages are all freed (its last
        retention charge landed when its free event closed the prior
        segment, and freed requests cause no further wakes)."""
        return self._machine().req_j.get(rid, 0.0)

    def tenant_energy_j(self, end_time: Optional[float] = None
                        ) -> Dict[str, float]:
        return dict(self._flushed(end_time).tenant_j)

    def floor_j(self, end_time: Optional[float] = None) -> float:
        return self._flushed(end_time).floor_j

    def wake_counts(self, end_time: Optional[float] = None) -> Dict[str, int]:
        w = dict(self._flushed(end_time).wakes)
        if self._prewakes:
            w["prewake"] = w.get("prewake", 0) + self._prewakes
        return w

    def stall_s(self, end_time: Optional[float] = None) -> float:
        return self._flushed(end_time).stall_s

    def activity_series(self, end_time: Optional[float] = None):
        """(t0, durations, active_banks) per segment — `active_banks`
        equals `gating.bank_timeline`'s integer activity exactly."""
        m = self._flushed(end_time)
        return (np.asarray(m.seg_t0), np.asarray(m.seg_dur),
                np.asarray(m.seg_act, np.int64))

    def energy_series(self, end_time: Optional[float] = None):
        """(boundary times, cumulative live J) — segment right edges. The
        last point carries the flushed grand total, so trailing idle-run
        charges (resolved only at flush) are never lost by an export."""
        m = self._flushed(end_time)
        edges = np.asarray(m.seg_t0) + np.asarray(m.seg_dur)
        cum = np.asarray(m.seg_cum_j)
        total = m.e_leak + m.e_sw
        if len(edges) and total != cum[-1]:
            edges = np.append(edges, edges[-1])
            cum = np.append(cum, total)
        return edges, cum

    def bank_intervals(self, end_time: Optional[float] = None
                       ) -> List[Tuple[int, str, float, float]]:
        """(bank, state, t_start, t_end) rows, states active|idle|drowsy|
        gated — the Perfetto bank-state timeline."""
        return list(self._flushed(end_time).intervals)

    def report(self, end_time: Optional[float] = None, *,
               n_reads: int = 0, n_writes: int = 0,
               tokens_by_rid: Optional[Dict] = None) -> MeterReport:
        m = self._flushed(end_time)
        res = self.finalize(end_time, n_reads=n_reads, n_writes=n_writes)
        req = dict(m.req_j)
        rep = MeterReport(result=res, live_e_j=m.e_leak + m.e_sw,
                          request_j=req, tenant_j=dict(m.tenant_j),
                          floor_j=m.floor_j,
                          wakes=self.wake_counts(end_time),
                          stall_s=m.stall_s)
        if req:
            js = np.asarray(sorted(req.values()))
            rep.j_per_request = tuple(
                float(np.percentile(js, q)) for q in (50, 90, 99))
            if tokens_by_rid:
                per_tok = [j / max(tokens_by_rid.get(rid, 1), 1)
                           for rid, j in req.items()]
                rep.j_per_token = tuple(
                    float(np.percentile(per_tok, q)) for q in (50, 90, 99))
        return rep

    def format_dashboard(self, end_time: Optional[float] = None) -> str:
        """Live one-glance view: occupancy bar, bank states, energy."""
        m = self._flushed(end_time)
        occ = m.needed if self.use == "needed" else m.needed + m.obsolete
        act = m.prev_act if m.nseg else 0
        bar = "#" * act + "-" * (self.banks - act)
        end = self._last_t if end_time is None else end_time
        lines = [
            f"[energy] t={end:.4f}s  occ={occ / 2**20:.2f} MiB  "
            f"banks [{bar}] {act}/{self.banks}  "
            f"policy={self.policy.name} alpha={self.policy.alpha:g}",
            f"  E(live)={(m.e_leak + m.e_sw) * 1e3:.4g} mJ  "
            f"(leak {m.e_leak * 1e3:.4g}, sw {m.e_sw * 1e3:.4g})  "
            f"transitions={m.n_sw}  gated={m.gated_s:.3g} bank-s  "
            f"stall={m.stall_s * 1e3:.3g} ms",
        ]
        w = self.wake_counts(end_time)
        if w:
            lines.append("  wakes: " + ", ".join(
                f"{c}={n}" for c, n in sorted(w.items())))
        if m.tenant_j:
            tot = sum(m.tenant_j.values())
            tops = sorted(m.tenant_j.items(), key=lambda kv: -kv[1])[:4]
            lines.append("  tenants: " + ", ".join(
                f"{t}={j * 1e3:.3g}mJ({j / tot:.0%})" for t, j in tops))
        return "\n".join(lines)

    # --------------------------------------------------------- telemetry
    def _publish_counters(self) -> None:
        if self.tel is None or not getattr(self.tel, "enabled", False):
            return
        for cause, n in self.wake_counts().items():
            prev = self._published.get(cause, 0)
            if n > prev:
                self.tel.counter(f"energy.wakes.{cause}").inc(n - prev)
                self._published[cause] = n
