"""Unified telemetry: metrics registry, spans, SLOs, Perfetto export."""
from repro.obs.perfetto import (chrome_trace_events, counter_integral,
                                export_chrome_trace)
from repro.obs.slo import (RequestTimeline, SLOSummary, SLOTracker,
                           percentile_summary, summarize_histograms)
from repro.obs.telemetry import (DEFAULT_BUCKETS, LATENCY_BUCKETS, Counter,
                                 Gauge, Histogram, Span, Telemetry,
                                 default_registry, log_bucket_edges,
                                 noop_registry)

__all__ = [
    "Counter", "Gauge", "Histogram", "Span", "Telemetry",
    "DEFAULT_BUCKETS", "LATENCY_BUCKETS", "log_bucket_edges",
    "default_registry", "noop_registry",
    "RequestTimeline", "SLOSummary", "SLOTracker",
    "percentile_summary", "summarize_histograms",
    "chrome_trace_events", "counter_integral", "export_chrome_trace",
]
