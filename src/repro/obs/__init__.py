"""Unified telemetry: metrics registry, spans, SLOs, energy meter,
Perfetto export."""
from repro.obs.energy import BankEnergyMeter, MeterReport
from repro.obs.perfetto import (bank_state_events, chrome_trace_events,
                                counter_integral, energy_counter_total,
                                export_chrome_trace)
from repro.obs.slo import (RequestTimeline, SLOSummary, SLOTracker,
                           attach_energy_percentiles, percentile_summary,
                           summarize_histograms)
from repro.obs.telemetry import (DEFAULT_BUCKETS, LATENCY_BUCKETS, Counter,
                                 Gauge, Histogram, Span, Telemetry,
                                 default_registry, log_bucket_edges,
                                 noop_registry)

__all__ = [
    "Counter", "Gauge", "Histogram", "Span", "Telemetry",
    "DEFAULT_BUCKETS", "LATENCY_BUCKETS", "log_bucket_edges",
    "default_registry", "noop_registry",
    "RequestTimeline", "SLOSummary", "SLOTracker",
    "percentile_summary", "summarize_histograms",
    "chrome_trace_events", "counter_integral", "export_chrome_trace",
    "BankEnergyMeter", "MeterReport", "attach_energy_percentiles",
    "bank_state_events", "energy_counter_total",
]
