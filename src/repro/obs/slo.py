"""Per-request serving SLOs computed from request timelines.

The serving engines stamp a :class:`RequestTimeline` per request (on their
logical sim clock — the same time base as the Stage-I occupancy trace) and
feed it to an :class:`SLOTracker` at retirement. The tracker folds three
latency distributions into registry histograms:

  * **TTFT**  — submit to first token (queue wait + prefill);
  * **TBT**   — gap between consecutive emitted tokens (the streaming
    cadence users actually feel);
  * **e2e**   — submit to retirement.

Percentiles come from the mergeable fixed-bucket histograms, so per-shard
trackers reduce into fleet SLOs exactly like any other registry metric.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.obs.telemetry import LATENCY_BUCKETS, Histogram, Telemetry


@dataclass
class RequestTimeline:
    """Lifecycle timestamps of one request on the engine's clock."""
    rid: int
    submit_t: float
    admit_t: float = math.nan          # left the queue (prefill starts)
    first_token_t: float = math.nan    # prefill's argmax emitted token #1
    finish_t: float = math.nan
    token_ts: List[float] = field(default_factory=list)
    # times this request was preempted and requeued; the admit/token stamps
    # above always describe the final (completed) admission
    preemptions: int = 0
    # joules attributed to this request by a BankEnergyMeter (NaN = no
    # meter attached to the engine)
    energy_j: float = math.nan

    def reset_admission(self) -> None:
        """Roll the timeline back to the queued state after a preemption:
        submit_t survives (TTFT/e2e keep charging the requeue wait), the
        admission-scoped stamps are cleared for the re-prefill."""
        self.preemptions += 1
        self.admit_t = math.nan
        self.first_token_t = math.nan
        self.token_ts.clear()

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.submit_t

    @property
    def e2e_s(self) -> float:
        return self.finish_t - self.submit_t

    def gaps(self) -> np.ndarray:
        """Inter-token gaps (empty for single-token requests)."""
        if len(self.token_ts) < 2:
            return np.zeros(0)
        return np.diff(np.asarray(self.token_ts))


@dataclass
class SLOSummary:
    """Headline percentiles — what `PagedStats`, campaign rows and the
    `obs` CLI surface next to energy."""
    n_requests: int = 0
    ttft_p50_s: float = 0.0
    ttft_p90_s: float = 0.0
    ttft_p99_s: float = 0.0
    tbt_p50_s: float = 0.0
    tbt_p90_s: float = 0.0
    tbt_p99_s: float = 0.0
    e2e_p50_s: float = 0.0
    e2e_p90_s: float = 0.0
    e2e_p99_s: float = 0.0
    # bytes-based KV occupancy (physical, i.e. after quantization /
    # sharing): filled by engines that own a Stage-I ledger, zero otherwise
    kv_peak_bytes: float = 0.0
    kv_mean_bytes: float = 0.0
    # per-request energy attribution (BankEnergyMeter), zero without a meter
    energy_p50_j: float = 0.0
    energy_p90_j: float = 0.0
    energy_p99_j: float = 0.0
    energy_per_tok_p50_j: float = 0.0
    energy_per_tok_p90_j: float = 0.0
    energy_per_tok_p99_j: float = 0.0

    def format(self) -> str:
        head = f"{'metric':<22} {'p50':>10} {'p90':>10} {'p99':>10}"
        rows = [
            ("ttft [s]", self.ttft_p50_s, self.ttft_p90_s, self.ttft_p99_s),
            ("time-between-tok [s]", self.tbt_p50_s, self.tbt_p90_s,
             self.tbt_p99_s),
            ("e2e latency [s]", self.e2e_p50_s, self.e2e_p90_s,
             self.e2e_p99_s),
        ]
        lines = [f"serving SLOs over {self.n_requests} requests", head]
        lines += [f"{n:<22} {a:>10.4g} {b:>10.4g} {c:>10.4g}"
                  for n, a, b, c in rows]
        if self.kv_peak_bytes:
            lines.append(
                f"{'kv occupancy [MiB]':<22} peak "
                f"{self.kv_peak_bytes / 2**20:.3f}  mean "
                f"{self.kv_mean_bytes / 2**20:.3f}")
        if self.energy_p99_j:
            lines.append(f"{'energy [mJ/request]':<22} "
                         f"{self.energy_p50_j * 1e3:>10.4g} "
                         f"{self.energy_p90_j * 1e3:>10.4g} "
                         f"{self.energy_p99_j * 1e3:>10.4g}")
        if self.energy_per_tok_p99_j:
            lines.append(f"{'energy [mJ/token]':<22} "
                         f"{self.energy_per_tok_p50_j * 1e3:>10.4g} "
                         f"{self.energy_per_tok_p90_j * 1e3:>10.4g} "
                         f"{self.energy_per_tok_p99_j * 1e3:>10.4g}")
        return "\n".join(lines)


def _q(h: Histogram, q: float) -> float:
    v = h.quantile(q)
    return 0.0 if math.isnan(v) else v


def summarize_histograms(ttft: Histogram, tbt: Histogram,
                         e2e: Histogram) -> SLOSummary:
    return SLOSummary(
        n_requests=ttft.count,
        ttft_p50_s=_q(ttft, 0.5), ttft_p90_s=_q(ttft, 0.9),
        ttft_p99_s=_q(ttft, 0.99),
        tbt_p50_s=_q(tbt, 0.5), tbt_p90_s=_q(tbt, 0.9),
        tbt_p99_s=_q(tbt, 0.99),
        e2e_p50_s=_q(e2e, 0.5), e2e_p90_s=_q(e2e, 0.9),
        e2e_p99_s=_q(e2e, 0.99))


class SLOTracker:
    """Folds retired request timelines into TTFT/TBT/e2e histograms
    registered on `tel` under ``{prefix}.ttft_s`` etc."""

    def __init__(self, tel: Telemetry, prefix: str = "serve"):
        self.ttft = tel.histogram(f"{prefix}.ttft_s", LATENCY_BUCKETS)
        self.tbt = tel.histogram(f"{prefix}.tbt_s", LATENCY_BUCKETS)
        self.e2e = tel.histogram(f"{prefix}.e2e_s", LATENCY_BUCKETS)

    def observe(self, tl: RequestTimeline) -> None:
        self.ttft.observe(tl.ttft_s)
        self.e2e.observe(tl.e2e_s)
        g = tl.gaps()
        if len(g):
            self.tbt.observe_array(g)

    def summary(self) -> SLOSummary:
        return summarize_histograms(self.ttft, self.tbt, self.e2e)


def percentile_summary(ttft_s: Optional[List[float]] = None,
                       tbt_hist: Optional[Histogram] = None,
                       e2e_s: Optional[List[float]] = None) -> SLOSummary:
    """SLO summary from raw samples where lists already exist (the
    model-free traffic sims keep latency lists for other consumers)."""
    def pct(xs, q):
        return float(np.percentile(xs, q)) if xs else 0.0

    out = SLOSummary(n_requests=len(ttft_s or []))
    if ttft_s:
        out.ttft_p50_s = pct(ttft_s, 50)
        out.ttft_p90_s = pct(ttft_s, 90)
        out.ttft_p99_s = pct(ttft_s, 99)
    if tbt_hist is not None and tbt_hist.count:
        out.tbt_p50_s = _q(tbt_hist, 0.5)
        out.tbt_p90_s = _q(tbt_hist, 0.9)
        out.tbt_p99_s = _q(tbt_hist, 0.99)
    if e2e_s:
        out.e2e_p50_s = pct(e2e_s, 50)
        out.e2e_p90_s = pct(e2e_s, 90)
        out.e2e_p99_s = pct(e2e_s, 99)
    return out


def attach_energy_percentiles(summary: SLOSummary, request_j,
                              tokens_by_rid=None) -> SLOSummary:
    """Fold a BankEnergyMeter's per-request charges into an SLO summary:
    J/request percentiles, and J/token when token counts are known."""
    js = [j for j in request_j.values()]
    if not js:
        return summary
    summary.energy_p50_j = float(np.percentile(js, 50))
    summary.energy_p90_j = float(np.percentile(js, 90))
    summary.energy_p99_j = float(np.percentile(js, 99))
    if tokens_by_rid:
        per_tok = [j / max(int(tokens_by_rid.get(rid, 1)), 1)
                   for rid, j in request_j.items()]
        summary.energy_per_tok_p50_j = float(np.percentile(per_tok, 50))
        summary.energy_per_tok_p90_j = float(np.percentile(per_tok, 90))
        summary.energy_per_tok_p99_j = float(np.percentile(per_tok, 99))
    return summary
