"""Zero-dependency telemetry: counters, gauges, histograms, spans.

The serving/sim stack is instrumented through one :class:`Telemetry`
registry per engine (or the process-wide :func:`default_registry` for
aggregate counters like compile counts). Design constraints, in order:

  * **disabled is free** — every instrument holds a reference to its
    registry and checks one boolean before recording; ``tel.span(...)`` on
    a disabled registry returns a shared no-op context manager without
    allocating. Engines run with a disabled registry by default, so the
    hot decode path pays one branch per event.
  * **mergeable** — counters add, gauges sum (max-of-max rides along),
    histograms share fixed bucket boundaries so ``merge`` is exact on
    counts. Per-shard registries from a future mesh-sharded engine reduce
    into one fleet view with :meth:`Telemetry.merge`.
  * **clock-agnostic** — spans read ``Telemetry.clock``. Wall-clock users
    keep the ``time.perf_counter`` default; the serving batchers re-point
    the clock at their logical sim clock so spans land on the same
    timeline as the Stage-I `OccupancyTrace` (what makes the Perfetto
    export a *single* coherent view).

Histogram quantiles are estimated from fixed log-spaced buckets: the
estimate for any order statistic lies inside the bucket that truly
contains it (bucket counts are exact), clamped to the observed min/max —
the property the hypothesis suite pins down.
"""
from __future__ import annotations

import bisect
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


def log_bucket_edges(lo: float = 1e-6, hi: float = 1e4,
                     per_decade: int = 5) -> Tuple[float, ...]:
    """Log-spaced bucket boundaries shared by every histogram of a kind —
    identical edges are what make cross-registry merges exact."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


DEFAULT_BUCKETS = log_bucket_edges()
# serving latencies: 10 µs .. 1000 s, 8 buckets per decade
LATENCY_BUCKETS = log_bucket_edges(1e-5, 1e3, per_decade=8)


class Counter:
    """Monotonic add-only metric. `value` may be int or float."""

    __slots__ = ("name", "_tel", "value")

    def __init__(self, name: str, tel: "Telemetry"):
        self.name = name
        self._tel = tel
        self.value = 0

    def inc(self, n=1) -> None:
        if self._tel.enabled:
            self.value += n


class Gauge:
    """Last-value metric; tracks the max ever set. Merges by summing the
    last values (per-shard residency gauges add up) and max-of-max."""

    __slots__ = ("name", "_tel", "value", "max_value")

    def __init__(self, name: str, tel: "Telemetry"):
        self.name = name
        self._tel = tel
        self.value = 0
        self.max_value = 0

    def set(self, v) -> None:
        if self._tel.enabled:
            self.value = v
            if v > self.max_value:
                self.max_value = v


class Histogram:
    """Fixed-bucket histogram with mergeable quantile estimates.

    Buckets are the half-open intervals between `edges` plus an underflow
    and an overflow bucket; `counts` has ``len(edges) + 1`` entries.
    Quantiles follow numpy's default convention (rank ``q * (n - 1)``,
    linear between the two bounding order statistics), with each order
    statistic located in its exact bucket and placed by within-bucket rank
    interpolation, clamped to the observed ``[min_value, max_value]``.

    Equality compares edges, counts and extrema — **not** `total`, whose
    float value depends on summation order (bulk vs scalar observes), so
    two registries that saw the same samples compare equal either way.
    """

    __slots__ = ("name", "_tel", "edges", "counts", "count", "total",
                 "min_value", "max_value")

    def __init__(self, name: str, tel: Optional["Telemetry"] = None,
                 edges: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self._tel = tel
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.total = 0.0
        self.min_value = math.inf
        self.max_value = -math.inf

    # --------------------------------------------------------------- record
    @property
    def _enabled(self) -> bool:
        return self._tel is None or self._tel.enabled

    def observe(self, x: float, n: int = 1) -> None:
        if not self._enabled:
            return
        x = float(x)
        self.counts[bisect.bisect_right(self.edges, x)] += n
        self.count += n
        self.total += x * n
        if x < self.min_value:
            self.min_value = x
        if x > self.max_value:
            self.max_value = x

    def observe_array(self, xs: np.ndarray) -> None:
        """Vectorized bulk observe — the traffic fast-forward path records
        thousands of identical token gaps per window through this."""
        if not self._enabled or len(xs) == 0:
            return
        xs = np.asarray(xs, np.float64)
        idx = np.searchsorted(self.edges, xs, side="right")
        for b, c in zip(*np.unique(idx, return_counts=True)):
            self.counts[int(b)] += int(c)
        self.count += len(xs)
        self.total += float(xs.sum())
        lo, hi = float(xs.min()), float(xs.max())
        if lo < self.min_value:
            self.min_value = lo
        if hi > self.max_value:
            self.max_value = hi

    # -------------------------------------------------------------- queries
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def bucket_bounds(self, x: float) -> Tuple[float, float]:
        """(lo, hi) edges of the bucket that holds value `x` (±inf at the
        ends) — the resolution limit of any estimate involving `x`."""
        b = bisect.bisect_right(self.edges, float(x))
        lo = self.edges[b - 1] if b > 0 else -math.inf
        hi = self.edges[b] if b < len(self.edges) else math.inf
        return lo, hi

    def _order_stat(self, i: int) -> float:
        """Estimate of the i-th (0-based) order statistic: exact bucket,
        within-bucket rank interpolation, clamped to observed extrema."""
        target = i + 1
        cum = 0
        for b, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo = self.edges[b - 1] if b > 0 else self.min_value
                hi = self.edges[b] if b < len(self.edges) else self.max_value
                lo = max(lo, self.min_value)
                hi = min(hi, self.max_value)
                return lo + (hi - lo) * ((target - cum) / c)
            cum += c
        return self.max_value

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return math.nan
        k = min(max(q, 0.0), 1.0) * (self.count - 1)
        lo = self._order_stat(int(math.floor(k)))
        f = k - math.floor(k)
        if f == 0.0:
            return lo
        return lo * (1.0 - f) + self._order_stat(int(math.ceil(k))) * f

    # ---------------------------------------------------------------- merge
    def merge(self, other: "Histogram") -> None:
        if self.edges != other.edges:
            raise ValueError(
                f"histogram {self.name}: bucket edges differ, not mergeable")
        for b, c in enumerate(other.counts):
            self.counts[b] += c
        self.count += other.count
        self.total += other.total
        self.min_value = min(self.min_value, other.min_value)
        self.max_value = max(self.max_value, other.max_value)

    def __eq__(self, other) -> bool:
        if not isinstance(other, Histogram):
            return NotImplemented
        return (self.edges == other.edges and self.counts == other.counts
                and self.count == other.count
                and self.min_value == other.min_value
                and self.max_value == other.max_value)

    def __repr__(self) -> str:
        return (f"Histogram({self.name!r}, n={self.count}, "
                f"p50={self.quantile(0.5):.3g}, p99={self.quantile(0.99):.3g})")


@dataclass
class Span:
    """One timed interval on the registry's clock. Zero-duration spans are
    rendered as instant events by the Perfetto exporter."""
    name: str
    t0: float
    t1: float
    attrs: Dict[str, object] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _NullSpan:
    """Shared no-op context manager — the whole cost of a disabled span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    __slots__ = ("_tel", "_name", "_attrs", "_t0")

    def __init__(self, tel: "Telemetry", name: str, attrs: Dict):
        self._tel = tel
        self._name = name
        self._attrs = attrs

    def __enter__(self):
        self._t0 = self._tel.clock()
        return self

    def __exit__(self, *exc):
        tel = self._tel
        tel.spans.append(Span(self._name, self._t0, tel.clock(), self._attrs))
        return False


class Telemetry:
    """Registry of named instruments plus a span log.

    `clock` is any ``() -> float``; engines with a logical sim clock bind
    it so spans share the occupancy trace's time base. `record_spans`
    gates the span log separately from metrics — the process-wide default
    registry keeps counters on but spans off (unbounded growth across a
    long campaign is the failure mode that guards against).
    """

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 record_spans: bool = True):
        self.enabled = enabled
        self.clock = clock
        self.record_spans = record_spans
        self.spans: List[Span] = []
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._clock_owner: Optional[object] = None

    # ----------------------------------------------------------------- clock
    def bind_clock(self, clock: Callable[[], float], owner: object) -> None:
        """Point `self.clock` at an engine's logical time base, recording
        `owner` as the binding engine. A second engine binding the same
        registry raises instead of silently re-pointing the clock — the old
        failure mode corrupted the first engine's spans and SLO timelines
        mid-flight. To reuse a registry sequentially, call
        :meth:`release_clock` after the first engine drains."""
        if self._clock_owner is not None and self._clock_owner is not owner:
            raise RuntimeError(
                "telemetry clock is already bound by another engine; one "
                "registry records one timeline — use a separate Telemetry "
                "per engine (merge() them afterwards) or release_clock() "
                "when the first engine is done")
        self._clock_owner = owner
        self.clock = clock

    def release_clock(self) -> None:
        """Detach the bound engine and restore the wall clock, allowing a
        new engine to bind this registry."""
        self._clock_owner = None
        self.clock = time.perf_counter

    # ---------------------------------------------------------- instruments
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, self)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, self)
        return g

    def histogram(self, name: str,
                  edges: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, self, edges)
        return h

    # ----------------------------------------------------------------- spans
    def span(self, name: str, **attrs):
        """``with tel.span("prefill", slot=i): ...`` — times the body on
        `self.clock`. Disabled path: one branch, shared no-op return."""
        if not (self.enabled and self.record_spans):
            return _NULL_SPAN
        return _SpanCtx(self, name, attrs)

    def add_span(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Record a span with explicit timestamps (engines that advance a
        sim clock mid-body emit their spans this way)."""
        if self.enabled and self.record_spans:
            self.spans.append(Span(name, t0, t1, attrs))

    # ----------------------------------------------------------------- merge
    def merge(self, other: "Telemetry") -> "Telemetry":
        """Fold `other`'s instruments and spans into this registry (exact
        for counters/histograms; gauges sum last values). Returns self."""
        for name, c in other._counters.items():
            self.counter(name).value += c.value
        for name, g in other._gauges.items():
            mine = self.gauge(name)
            mine.value += g.value
            mine.max_value = max(mine.max_value, g.max_value)
        for name, h in other._histograms.items():
            self.histogram(name, h.edges).merge(h)
        self.spans.extend(other.spans)
        return self

    # ------------------------------------------------------------- reporting
    def snapshot(self) -> Dict[str, Dict]:
        """Plain-data view (JSON-serializable) of every instrument."""
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: {"value": g.value, "max": g.max_value}
                       for n, g in sorted(self._gauges.items())},
            "histograms": {
                n: {"count": h.count, "mean": h.mean,
                    "p50": h.quantile(0.5), "p90": h.quantile(0.9),
                    "p99": h.quantile(0.99)}
                for n, h in sorted(self._histograms.items())},
            "spans": len(self.spans),
        }

    def format(self) -> str:
        """Text metrics dump (the `obs report` CLI view)."""
        lines = ["-- counters " + "-" * 46]
        for n, c in sorted(self._counters.items()):
            lines.append(f"  {n:<44} {c.value}")
        if self._gauges:
            lines.append("-- gauges " + "-" * 48)
            for n, g in sorted(self._gauges.items()):
                lines.append(f"  {n:<44} {g.value} (max {g.max_value})")
        if self._histograms:
            lines.append("-- histograms " + "-" * 44)
            for n, h in sorted(self._histograms.items()):
                if h.count:
                    lines.append(
                        f"  {n:<34} n={h.count:<7} mean={h.mean:9.3g} "
                        f"p50={h.quantile(0.5):9.3g} "
                        f"p99={h.quantile(0.99):9.3g}")
                else:
                    lines.append(f"  {n:<34} n=0")
        by_name: Dict[str, Tuple[int, float]] = {}
        for s in self.spans:
            k, tot = by_name.get(s.name, (0, 0.0))
            by_name[s.name] = (k + 1, tot + s.dur)
        if by_name:
            lines.append("-- spans " + "-" * 49)
            for n, (k, tot) in sorted(by_name.items()):
                lines.append(f"  {n:<34} n={k:<7} total={tot:9.3g}s")
        return "\n".join(lines)


_DEFAULT: Optional[Telemetry] = None
_NOOP = Telemetry(enabled=False, record_spans=False)


def default_registry() -> Telemetry:
    """Process-wide registry backing aggregate counters (compile counts,
    DES/PSS totals). Metrics on, spans off — safe to grow for a process
    lifetime. Per-engine registries stay separate and mergeable."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Telemetry(enabled=True, record_spans=False)
    return _DEFAULT


def noop_registry() -> Telemetry:
    """The shared disabled registry engines default to — records nothing."""
    return _NOOP
