"""Chrome-trace-event exporter: spans + occupancy traces on one timeline.

Emits the JSON object format of the Trace Event spec (the dialect
ui.perfetto.dev and chrome://tracing load directly): request spans and
per-slot lanes as ``"X"`` complete events, zero-duration spans as ``"i"``
instants, and every Stage-I `OccupancyTrace` as a ``"C"`` counter track —
all in microseconds on the registry's clock. Because the serving batchers
record spans on the same logical sim clock their ledgers emit trace events
on, the KV-occupancy counter rises and falls in lockstep with the very
admissions/retirements drawn above it.

Lane (pid/tid) layout:

  * pid 1 "serving" — tid 1 "engine" (unclassified spans), tid 2
    "decode chunks", tid 10+i "slot i" (spans carrying a ``slot`` attr);
  * pid 2 "requests" — one lane per request id for ``request`` lifecycle
    spans (queue wait + streaming window end to end);
  * counter tracks attach to pid 1, one per occupancy trace, with
    ``needed``/``obsolete`` series stacked.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

import numpy as np

SERVING_PID = 1
REQUEST_PID = 2
BANKS_PID = 3

ENERGY_COUNTER = "bank energy [J]"
ACTIVE_COUNTER = "active banks"
_TID_ENGINE = 1
_TID_CHUNKS = 2
_TID_SLOT0 = 10


def _meta(pid: int, name: str, tid: Optional[int] = None) -> Dict:
    ev = {"ph": "M", "pid": pid,
          "name": "process_name" if tid is None else "thread_name",
          "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def chrome_trace_events(telemetry=None, traces: Iterable = (),
                        *, end_time: Optional[float] = None,
                        meter=None) -> List[Dict]:
    """Build the trace-event list from a `Telemetry` registry's spans and
    any number of `OccupancyTrace`s (anything with ``mem_name`` and
    ``as_arrays()``). Times are seconds in, microseconds out. With a
    `BankEnergyMeter`, its bank-state timeline and energy counters ride
    along as pid-3 tracks (see `bank_state_events`)."""
    events: List[Dict] = [_meta(SERVING_PID, "serving")]
    used_tids: Dict[int, str] = {}
    req_tids: Dict[object, int] = {}

    spans = telemetry.spans if telemetry is not None else []
    for s in spans:
        attrs = s.attrs
        if s.name == "request" and "rid" in attrs:
            pid = REQUEST_PID
            rid = attrs["rid"]
            tid = req_tids.setdefault(rid, len(req_tids) + 1)
        else:
            pid = SERVING_PID
            if "slot" in attrs:
                tid = _TID_SLOT0 + int(attrs["slot"])
                used_tids.setdefault(tid, f"slot {attrs['slot']}")
            elif s.name == "decode_chunk":
                tid = _TID_CHUNKS
                used_tids.setdefault(tid, "decode chunks")
            else:
                tid = _TID_ENGINE
                used_tids.setdefault(tid, "engine")
        ev = {"name": s.name, "cat": "span", "pid": pid, "tid": tid,
              "ts": s.t0 * 1e6,
              "args": {k: v for k, v in attrs.items()}}
        if s.t1 > s.t0:
            ev["ph"] = "X"
            ev["dur"] = (s.t1 - s.t0) * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        events.append(ev)

    for tid, name in sorted(used_tids.items()):
        events.append(_meta(SERVING_PID, name, tid))
    if req_tids:
        events.append(_meta(REQUEST_PID, "requests"))
        for rid, tid in req_tids.items():
            events.append(_meta(REQUEST_PID, f"request {rid}", tid))

    for tr in traces:
        t, n, o = tr.as_arrays()
        name = f"{tr.mem_name} occupancy [B]"
        for ti, ni, oi in zip(t, n, o):
            events.append({"ph": "C", "name": name, "pid": SERVING_PID,
                           "ts": float(ti) * 1e6,
                           "args": {"needed": int(ni), "obsolete": int(oi)}})
        if end_time is not None and len(t) and end_time > t[-1]:
            # hold the final level to the end of the timeline
            events.append({"ph": "C", "name": name, "pid": SERVING_PID,
                           "ts": float(end_time) * 1e6,
                           "args": {"needed": int(n[-1]),
                                    "obsolete": int(o[-1])}})

    if meter is not None:
        events.extend(bank_state_events(meter, end_time=end_time))

    # stable render order: metadata first, then strictly by timestamp
    events.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0.0)))
    return events


def bank_state_events(meter, *, end_time: Optional[float] = None
                      ) -> List[Dict]:
    """Pid-3 tracks for a `BankEnergyMeter`: one ``\"X\"`` span lane per
    bank (state names active|idle|drowsy|gated), an active-bank-count
    counter (left segment edges, so `counter_integral` over it equals the
    timeline's bank-seconds) and a cumulative energy counter whose final
    sample is the meter's exact live total (f64 round-trips through JSON
    losslessly — `energy_counter_total` recovers it bit-identically)."""
    evs: List[Dict] = [_meta(BANKS_PID, "sram banks")]
    for b in range(meter.banks):
        evs.append(_meta(BANKS_PID, f"bank {b}", b + 1))
    for b, state, t0, t1 in meter.bank_intervals(end_time):
        evs.append({"ph": "X", "name": state, "cat": "bank",
                    "pid": BANKS_PID, "tid": int(b) + 1,
                    "ts": float(t0) * 1e6,
                    "dur": max(float(t1) - float(t0), 0.0) * 1e6,
                    "args": {"bank": int(b), "state": state}})
    t0s, durs, act = meter.activity_series(end_time)
    for t, a in zip(t0s, act):
        evs.append({"ph": "C", "name": ACTIVE_COUNTER, "pid": BANKS_PID,
                    "ts": float(t) * 1e6, "args": {"active": int(a)}})
    te, cum = meter.energy_series(end_time)
    for t, j in zip(te, cum):
        evs.append({"ph": "C", "name": ENERGY_COUNTER, "pid": BANKS_PID,
                    "ts": float(t) * 1e6, "args": {"cum_j": float(j)}})
    return evs


def energy_counter_total(events: List[Dict],
                         name: str = ENERGY_COUNTER,
                         series: str = "cum_j") -> float:
    """Final value of a cumulative counter track — the energy analogue of
    `counter_integral`: proves the exported track carries the meter's
    exact (bit-identical f64) live energy total."""
    pts = [(e["ts"], i, e["args"][series]) for i, e in enumerate(events)
           if e.get("ph") == "C" and e.get("name") == name]
    if not pts:
        return 0.0
    return float(max(pts)[2])


def counter_integral(events: List[Dict], name: str, end_time_us: float,
                     series: str = "needed") -> float:
    """∫ value·dt (byte·µs) of one counter track reconstructed from the
    exported events — the golden-format test checks this against
    `OccupancyTrace.time_integral` to prove the export lost nothing."""
    pts = [(e["ts"], e["args"][series]) for e in events
           if e.get("ph") == "C" and e.get("name") == name]
    if not pts:
        return 0.0
    ts = np.array([p[0] for p in pts])
    vs = np.array([p[1] for p in pts], np.float64)
    edges = np.append(ts, max(end_time_us, ts[-1]))
    return float((vs * np.diff(edges)).sum())


def export_chrome_trace(path: str, telemetry=None, traces: Iterable = (),
                        *, end_time: Optional[float] = None, meter=None,
                        other_data: Optional[Dict] = None) -> Dict:
    """Write a Perfetto-loadable trace file; returns the written object.

    `other_data` rides along under the spec's ``otherData`` key (ignored
    by the viewer) — the obs CLI stores the SLO summary there so smoke
    checks can assert on it without re-running the serve."""
    obj = {"traceEvents": chrome_trace_events(telemetry, traces,
                                              end_time=end_time,
                                              meter=meter),
           "displayTimeUnit": "ms"}
    if other_data:
        obj["otherData"] = other_data
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj
