"""Paged KV-cache serving: free-list page allocator + device-resident batcher.

This is the production face of the paper's occupancy analysis: KV memory is
allocated in fixed-size pages rather than dense ``max_len`` slabs, so a
slot's resident bytes track its *true* context length (quantized to one
page), GQA shrinks the page itself, and fragmentation / page residency
become first-class time-resolved signals. Three pieces:

  * :class:`PageAllocator` — host-side free list over the global page pool
    (page 0 is reserved as the null page inactive slots point at);
  * :class:`PagedKVLedger` — page accounting + page-granular
    `OccupancyTrace` emission (alloc/free events integrate to zero at
    drain; occupancy is always ``pages x page_bytes``);
  * :class:`PagedContinuousBatcher` — priority continuous batching (FIFO
    within a class; strictly-higher-priority arrivals may preempt) where
    the decode hot path is device-resident: one jitted ``lax.scan`` advances
    every slot ``chunk_steps`` tokens per host round-trip (donated cache
    buffers, no per-token sync), admission *maps the prompt's pages into
    the slot's table* instead of re-prefilling, and per-slot positions are
    exact — no max-length mask.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant import kv_dtype_spec
from repro.models.transformer import (init_paged_cache, prefix_tail_rows,
                                      self_spec_draft, write_prefill_to_pages)
from repro.obs.slo import (RequestTimeline, SLOSummary, SLOTracker,
                           attach_energy_percentiles)
from repro.obs.telemetry import default_registry, noop_registry
from repro.serve.scheduler import AdmissionQueue, Request, SchedulerStats
from repro.sim.trace import AccessStats, OccupancyTrace, TraceBundle


class OutOfPages(RuntimeError):
    """The page pool cannot cover a request's worst-case page demand."""


def page_bytes(cfg, page_size: int, kv_dtype_bytes: int = 2,
               scale_bytes_per_row: int = 0) -> int:
    """Bytes one KV page pins across all full-attention layers (K + V).

    `scale_bytes_per_row` adds the per-(token row, kv head) quantization
    scale storage (4 for int8's float32 per-row scales, 0 for float and
    scale-free fp8 pools) so quantized ledgers account the true physical
    footprint, scales included."""
    n_full = sum(1 for k in cfg.layer_kinds() if k == "full")
    b = n_full * 2 * page_size * cfg.kv_dim * kv_dtype_bytes
    if scale_bytes_per_row:
        b += n_full * 2 * page_size * cfg.num_kv_heads * scale_bytes_per_row
    return b


def pages_for(tokens: int, page_size: int) -> int:
    return max(0, -(-tokens // page_size))


# ---------------------------------------------------------------------------
# Allocator + ledger (host side, model-free — hypothesis-testable)
# ---------------------------------------------------------------------------

class PageAllocator:
    """LIFO free-list allocator over `num_pages` pages; page 0 is the
    reserved null page and is never handed out."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._allocated: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(f"requested {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._allocated:
                raise ValueError(f"double free / foreign page {p}")
            self._allocated.remove(p)
            self._free.append(p)


class PagedKVLedger:
    """Per-slot page ownership + page-granular occupancy trace.

    Every `admit`/`grow` emits a positive delta of ``n_pages x page_bytes``
    on the trace at the given logical time, every `retire` the matching
    negative delta — so the integrated trace equals the allocator's
    outstanding pages at all times, and drains to zero."""

    def __init__(self, num_pages: int, page_bytes_: int,
                 page_size: Optional[int] = None):
        self.allocator = PageAllocator(num_pages)
        self.page_bytes = page_bytes_
        self.page_size = page_size
        self.trace = OccupancyTrace("kv", (num_pages - 1) * page_bytes_)
        self.slot_pages: Dict[int, List[int]] = {}
        # Speculative-decoding draft lane: per-slot private pages drawn from
        # the same allocator/page-id space as the target lane, accounted at
        # the draft model's (smaller) per-page byte width.
        self.draft_pages: Dict[int, List[int]] = {}
        self.draft_page_bytes: Optional[int] = None
        # optional streaming energy meter (obs.energy.BankEnergyMeter):
        # every trace delta is mirrored to it, tagged with the slot's
        # request/tenant and the ledger verb that caused it
        self.meter = None
        self.slot_meta: Dict[int, tuple] = {}

    def set_slot_meta(self, slot: int, rid, tenant=None) -> None:
        """Tag a slot so mirrored meter events attribute to its request."""
        self.slot_meta[slot] = (rid, tenant)

    def _mark(self, t: float, delta: int, slot: int,
              cause: Optional[str]) -> None:
        self.trace.event(t, delta, 0)
        if self.meter is not None:
            rid, tenant = self.slot_meta.get(slot, (None, None))
            self.meter.record(t, delta, 0, rid=rid, tenant=tenant,
                              cause=cause)

    def occupancy_bytes(self) -> int:
        nd = sum(len(p) for p in self.draft_pages.values())
        db = (self.draft_page_bytes if self.draft_page_bytes is not None
              else self.page_bytes)
        return (self.allocator.n_allocated - nd) * self.page_bytes + nd * db

    def logical_bytes(self) -> int:
        """Without sharing, logical (per-slot demand) == physical bytes."""
        return self.occupancy_bytes()

    def admit(self, slot: int, n_pages: int, t: float) -> List[int]:
        assert slot not in self.slot_pages, f"slot {slot} already admitted"
        pages = self.allocator.alloc(n_pages)
        self.slot_pages[slot] = list(pages)
        if n_pages:
            self._mark(t, n_pages * self.page_bytes, slot, "admission")
        return pages

    def grow(self, slot: int, total_pages: int, t: float,
             cause: str = "decode_growth") -> List[int]:
        have = self.slot_pages[slot]
        extra = total_pages - len(have)
        if extra <= 0:
            return []
        pages = self.allocator.alloc(extra)
        have.extend(pages)
        self._mark(t, extra * self.page_bytes, slot, cause)
        return pages

    def retire(self, slot: int, t: float) -> int:
        pages = self.slot_pages.pop(slot)
        self.allocator.free(pages)
        if pages:
            self._mark(t, -len(pages) * self.page_bytes, slot, None)
        dpages = self.draft_pages.pop(slot, [])
        if dpages:
            self.allocator.free(dpages)
            db = (self.draft_page_bytes if self.draft_page_bytes is not None
                  else self.page_bytes)
            self._mark(t, -len(dpages) * db, slot, None)
        self.slot_meta.pop(slot, None)
        return len(pages) + len(dpages)

    # ------------------------------------------------- speculative draft lane
    def enable_draft_lane(self, draft_page_bytes: int) -> None:
        """Declare the byte width of draft-lane pages (the draft model's
        per-page KV footprint)."""
        self.draft_page_bytes = int(draft_page_bytes)

    def admit_draft(self, slot: int, n_pages: int, t: float) -> List[int]:
        assert slot not in self.draft_pages, \
            f"slot {slot} already has a draft lane"
        pages = self.allocator.alloc(n_pages)
        self.draft_pages[slot] = list(pages)
        db = (self.draft_page_bytes if self.draft_page_bytes is not None
              else self.page_bytes)
        if n_pages:
            self._mark(t, n_pages * db, slot, "admission")
        return pages

    def grow_draft(self, slot: int, total_pages: int, t: float) -> List[int]:
        have = self.draft_pages[slot]
        extra = total_pages - len(have)
        if extra <= 0:
            return []
        pages = self.allocator.alloc(extra)
        have.extend(pages)
        db = (self.draft_page_bytes if self.draft_page_bytes is not None
              else self.page_bytes)
        self._mark(t, extra * db, slot, "decode_growth")
        return pages

    def truncate_rows(self, slot: int, n_rows: int, t: float
                      ) -> "Tuple[List[int], List[int]]":
        """Rollback-by-page-truncation: free every page past
        `pages_for(n_rows)` in both lanes (target + draft). The negative
        mid-stream trace deltas this emits are the speculative-rollback
        occupancy signature. Returns the (target, draft) pages freed."""
        if self.page_size is None:
            raise ValueError("truncate_rows needs a ledger page_size")
        keep = pages_for(n_rows, self.page_size)
        freed_t: List[int] = []
        have = self.slot_pages[slot]
        if keep < len(have):
            freed_t = have[keep:]
            del have[keep:]
            self.allocator.free(freed_t)
            self._mark(t, -len(freed_t) * self.page_bytes, slot,
                       "spec_rollback")
        freed_d: List[int] = []
        dhave = self.draft_pages.get(slot)
        if dhave is not None and keep < len(dhave):
            freed_d = dhave[keep:]
            del dhave[keep:]
            self.allocator.free(freed_d)
            db = (self.draft_page_bytes if self.draft_page_bytes is not None
                  else self.page_bytes)
            self._mark(t, -len(freed_d) * db, slot, "spec_rollback")
        return freed_t, freed_d


# ---------------------------------------------------------------------------
# Device decode loop
# ---------------------------------------------------------------------------

# traced once per XLA compilation of the chunk loop — tests assert the
# continuous batcher never recompiles it across chunks/admissions; counted
# on the process-wide registry (loop_compile_count() is the shim view)
_COMPILES = default_registry().counter("serve.paged.loop_compiles")


def _decode_loop(model, steps: int, attn_backend: str, collect_logits: bool,
                 params, cache, tok, eos, remaining):
    """Greedy multi-token decode: `steps` tokens for every slot in one
    on-device `lax.scan`. Slots retire in-scan (EOS or token budget) via the
    cache's `active` mask; inactive lanes emit -1 and stop advancing. With
    `collect_logits` the scan additionally emits every step's last-position
    logits (exactness debugging / the bit-identity regression)."""
    _COMPILES.inc()

    def step(carry, _):
        cache, tok, remaining = carry
        logits, cache = model.decode_step_paged(params, cache, tok,
                                                attn_backend=attn_backend)
        active = cache["active"]
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        emit = jnp.where(active, nxt, -1)
        remaining = remaining - active.astype(jnp.int32)
        done = active & ((remaining <= 0) | ((eos >= 0) & (nxt == eos)))
        cache = dict(cache)
        cache["active"] = active & ~done
        tok = jnp.where(active[:, None], nxt[:, None], tok)
        out = (emit, logits[:, -1, :]) if collect_logits else emit
        return (cache, tok, remaining), out

    (cache, tok, remaining), emitted = jax.lax.scan(
        step, (cache, tok, remaining), None, length=steps)
    return emitted, cache, tok, remaining


def _spec_decode_loop(model, draft_model, rounds, spec_k, attn_backend,
                      params, draft_params, cache, draft_cache, tok, eos,
                      remaining):
    """Speculative greedy decode: `rounds` draft-then-verify rounds in one
    on-device `lax.scan`. Each round the draft proposes `spec_k` tokens
    (sequential small-model decode over its own page lane), the target
    scores the pending token plus all candidates in ONE batched
    `verify_step_paged` call (V = spec_k + 1 rows), and the longest
    accepted prefix advances both lanes' positions. Every emitted token is
    the TARGET's argmax — draft quality moves the acceptance rate, never
    the output, so the accepted stream is bit-identical to `_decode_loop`.
    A rejected suffix "rolls back" by pos arithmetic alone: its rows sit
    past `pos` as garbage the next round overwrites before reading.

    Emits a (rounds, num_slots, V) block of accepted tokens, -1 padded:
    within a round the accepted prefix is contiguous from column 0, and
    rounds after a slot retires are all -1, so ravel-and-filter recovers
    the stream in order."""
    _COMPILES.inc()
    V = spec_k + 1

    def round_step(carry, _):
        cache, dcache, tok, remaining = carry
        active = cache["active"]
        pos0 = dcache["pos"]

        def draft_step(dc, _):
            dcache, dtok = dc
            dlogits, dcache = draft_model.decode_step_paged(
                draft_params, dcache, dtok, attn_backend=attn_backend)
            nxt = jnp.argmax(dlogits[:, -1, :], axis=-1).astype(jnp.int32)
            dtok = jnp.where(active[:, None], nxt[:, None], dtok)
            return (dcache, dtok), nxt

        (dcache, dtok), drafted = jax.lax.scan(
            draft_step, (dcache, tok), None, length=spec_k)
        # catch-up: write the last candidate's draft KV row so a fully
        # accepted round leaves no hole in the draft lane (logits discarded)
        _, dcache = draft_model.decode_step_paged(
            draft_params, dcache, dtok, attn_backend=attn_backend)
        drafted = drafted.reshape(spec_k, -1).T            # (B, k)
        cand = jnp.concatenate([tok, drafted], axis=1)     # (B, V)
        vlogits, cache = model.verify_step_paged(
            params, cache, cand, attn_backend=attn_backend)
        g = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)  # (B, V) target
        # candidate v+1 survives iff it equals the target's continuation g_v
        match = (drafted == g[:, :spec_k]).astype(jnp.int32)
        m_full = 1 + jnp.cumprod(match, axis=1).sum(axis=1)      # in [1, V]
        eos_hit = (eos[:, None] >= 0) & (g == eos[:, None])
        first_eos = jnp.where(eos_hit.any(axis=1),
                              jnp.argmax(eos_hit, axis=1).astype(jnp.int32),
                              jnp.int32(V))
        m = jnp.minimum(jnp.minimum(m_full, first_eos + 1), remaining)
        m = jnp.where(active, m, 0)
        col = jnp.arange(V, dtype=jnp.int32)[None, :]
        emit = jnp.where(col < m[:, None], g, -1)
        new_tok = jnp.take_along_axis(g, jnp.maximum(m - 1, 0)[:, None],
                                      axis=1)
        tok = jnp.where(active[:, None], new_tok, tok)
        remaining = remaining - m
        eos_fired = eos_hit.any(axis=1) & (first_eos < m)
        done = active & ((remaining <= 0) | eos_fired)
        cache = dict(cache)
        dcache = dict(dcache)
        cache["pos"] = cache["pos"] + m
        dcache["pos"] = pos0 + m          # rollback: rejected rows orphaned
        cache["active"] = active & ~done
        dcache["active"] = cache["active"]
        return (cache, dcache, tok, remaining), emit

    (cache, draft_cache, tok, remaining), emitted = jax.lax.scan(
        round_step, (cache, draft_cache, tok, remaining), None, length=rounds)
    return emitted, cache, draft_cache, tok, remaining


# ---------------------------------------------------------------------------
# Continuous batcher
# ---------------------------------------------------------------------------

@dataclass
class PagedStats(SchedulerStats):
    pages_allocated: int = 0
    pages_freed: int = 0
    peak_pages: int = 0
    chunks: int = 0
    # prefix-sharing counters (stay zero without prefix_cache)
    cow_splits: int = 0
    evicted_pages: int = 0
    # chunked-prefill slices executed (zero without prefill_chunk_tokens)
    prefill_slices: int = 0
    # speculative-decoding counters (stay zero without speculate_k)
    spec_rounds: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    rolled_back_pages: int = 0


class PagedContinuousBatcher:
    """Priority continuous batching over a paged KV cache.

    Admission pops the highest-priority queued request (FIFO within a
    class). When the head would otherwise wait — no free slot, or the pool
    cannot cover its worst-case pages — it may *preempt* strictly-lower-
    priority active slots: the victim's pages free through the retire path,
    its partial output is discarded, and the request requeues behind its
    own class for a from-scratch re-prefill (greedy restart keeps its
    tokens bit-identical to an uncontended run). Equal priorities never
    preempt each other, so the default ``priority=0`` workload behaves
    exactly like the old FCFS batcher.

    Chunked prefill (``prefill_chunk_tokens``, pure full-attention stacks):
    prompts longer than the chunk admit in page-aligned slices with one
    decode chunk for the other slots interleaved between slices, so a long
    prompt stops stalling every active stream's time-between-tokens. Slices
    chain through the shared-prefix machinery (gather resident pages →
    suffix-only prefill at fixed attention width), which keeps the emitted
    tokens bit-identical to one monolithic prefill. Composes with
    ``prefix_cache``: on a prefix hit only the *suffix* past the match is
    chunked — the first slice is sized to re-align the (possibly mid-page)
    match boundary to a page multiple, every later slice gathers the
    slot's own resident pages, and the fixed-attention-width property
    keeps the result bit-identical to the monolithic suffix prefill.

    Speculative decoding (``speculate_k``, pure full-attention stacks,
    greedy only): a draft model proposes `speculate_k` tokens per round
    through its own *draft page lane* (same allocator/page-id space,
    smaller per-page bytes), the target scores the pending token plus all
    candidates in one batched ``paged_gqa_verify`` kernel call, and the
    longest target-agreeing prefix is accepted. Every emitted token is the
    target's argmax, so the accepted stream is bit-identical to the
    non-speculative loop — the draft only moves the acceptance rate, i.e.
    accepted-tokens/s. Rejected suffixes roll back by page truncation at
    chunk boundaries (`ledger.truncate_rows`): both lanes' tail pages past
    the accepted context free mid-stream, which is the negative-delta
    occupancy signature Stage I sees. With ``draft_model=None`` the draft
    is `self_spec_draft(model, params, skip=2)` — the target's own weights
    at every 2nd layer.

    Admission prefills the prompt once (batch=1), then scatters its KV rows
    into freshly allocated pages of the global pool — older slots are never
    touched. Decode runs in device-resident chunks of `chunk_steps` tokens
    (one jitted, donated `lax.scan` per chunk; the host syncs once per chunk
    to collect tokens, retire finished slots, free their pages, and admit
    queued requests). A request is admitted only when the pool can cover its
    worst-case page demand (prompt + max_new_tokens), so growth allocations
    between chunks never fail mid-stream.

    Emits the same Stage-I artifact as `ContinuousBatcher`, but at page
    granularity: `occupancy_bundle()` is a `TraceBundle` whose "kv" trace
    steps in units of `page_bytes` — feed it to `core.explorer.sweep` /
    `core.candidates.evaluate_candidates` unchanged.

    Compile discipline: the chunk decode loop compiles exactly once (shapes
    are fixed by the pool geometry). Admission prefill, like the dense
    batcher's, still traces per distinct (prompt length, page count) — pad
    or bucket prompts client-side if admission latency matters. With
    `prefix_cache` the hit path traces per (matched length, suffix length)
    pair instead.

    Prefix sharing (`prefix_cache=True`, pure full-attention stacks only):
    admission probes a `RadixPrefixIndex` with the prompt, maps matched
    pages read-only into the slot's table, runs a *suffix-only* prefill
    against the gathered prefix KV (bit-exact vs the full prefill), and
    caches every admitted run for later requests. The last page of a shared
    run is copy-on-write split on the first divergent write; unreferenced
    cached prefixes are LRU-evicted under page pressure. The ledger then
    emits dual Stage-I traces — "kv" (physical: unique referenced pages,
    cache-resident pages as obsolete) and "kv_logical" (per-slot demand sum)
    — so Stage II can price the gating headroom sharing unlocks.
    """

    def __init__(self, model, params, *, num_slots: int = 4,
                 page_size: int = 16, num_pages: int = 64,
                 max_pages_per_slot: Optional[int] = None,
                 chunk_steps: int = 16, attn_backend: str = "auto",
                 step_time_s: float = 1e-3, prefill_tok_s: float = 5e-5,
                 prefix_cache: bool = False, collect_logits: bool = False,
                 kv_dtype: str = "native",
                 prefill_chunk_tokens: Optional[int] = None,
                 on_long_prompt: str = "reject",
                 speculate_k: Optional[int] = None, draft_model=None,
                 draft_params=None, telemetry=None, meter=None):
        if not hasattr(model, "decode_step_paged"):
            raise TypeError("model lacks a paged decode path")
        if on_long_prompt not in ("reject", "truncate"):
            raise ValueError("on_long_prompt must be 'reject' or 'truncate'")
        if prefill_chunk_tokens is not None:
            if prefill_chunk_tokens < page_size or \
                    prefill_chunk_tokens % page_size:
                raise ValueError(
                    "prefill_chunk_tokens must be a positive multiple of "
                    f"page_size={page_size} so every slice boundary is "
                    "page-aligned (the chained slice prefill gathers whole "
                    f"pages); got {prefill_chunk_tokens}")
        if speculate_k is not None:
            if speculate_k < 1:
                raise ValueError(f"speculate_k must be >= 1, got "
                                 f"{speculate_k}")
            if collect_logits:
                raise NotImplementedError(
                    "collect_logits emits one logits row per decode step; "
                    "the speculative loop emits V verify rows per round "
                    "(rejected rows included) — use the non-speculative "
                    "loop for logits-level debugging")
            if kv_dtype == "int8":
                raise NotImplementedError(
                    "speculative verify scatters V rows per slot; the int8 "
                    "page pool's per-row requantization under that scatter "
                    "is not wired up (fp8/native pools are)")
            if (draft_model is None) != (draft_params is None):
                raise ValueError("pass draft_model and draft_params "
                                 "together (or neither for self-spec)")
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages_per_slot = max_pages_per_slot or \
            max(1, (num_pages - 1) // max(1, num_slots))
        self.chunk_steps = chunk_steps
        self.step_time_s = step_time_s
        self.prefill_tok_s = prefill_tok_s
        self.prefix_cache = prefix_cache
        self.collect_logits = collect_logits
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.on_long_prompt = on_long_prompt
        self.speculate_k = speculate_k

        # spans and SLOs record on the batcher's logical sim clock — the
        # time base the ledger's occupancy trace uses — so a passed-in
        # registry has its clock bound here: the Perfetto export then shows
        # request spans and the KV counter track on one timeline. bind_clock
        # raises if another engine already owns the registry's clock (a
        # second batcher would silently corrupt the first one's timelines).
        self.tel = telemetry if telemetry is not None else noop_registry()
        if telemetry is not None:
            telemetry.bind_clock(lambda: self._sim_t, owner=self)
        tel = self.tel
        self._slo = (SLOTracker(tel, "serve.paged") if tel.enabled else None)
        self._c_admitted = tel.counter("serve.paged.admitted")
        self._c_retired = tel.counter("serve.paged.retired")
        self._c_prefills = tel.counter("serve.paged.prefills")
        self._c_chunks = tel.counter("serve.paged.chunks")
        self._c_steps = tel.counter("serve.paged.decode_steps")
        self._c_alloc = tel.counter("serve.paged.pages_allocated")
        self._c_freed = tel.counter("serve.paged.pages_freed")
        self._c_evicted = tel.counter("serve.paged.pages_evicted")
        self._c_cow = tel.counter("serve.paged.cow_splits")
        self._c_hits = tel.counter("serve.paged.prefix_hits")
        self._c_miss = tel.counter("serve.paged.prefix_misses")
        self._c_reused = tel.counter("serve.paged.prefix_tokens_reused")
        self._c_wait = tel.counter("serve.paged.backpressure_waits")
        self._c_preempt = tel.counter("serve.paged.preemptions")
        self._c_slices = tel.counter("serve.paged.prefill_slices")
        self._c_spec_rounds = tel.counter("serve.paged.spec_rounds")
        self._c_drafted = tel.counter("serve.paged.spec_drafted")
        self._c_accepted = tel.counter("serve.paged.spec_accepted")
        self._c_rollback = tel.counter("serve.paged.spec_rolled_back_pages")
        self._c_dequant = tel.counter("quant.dequant_pages")
        self._g_pages = tel.gauge("serve.paged.pages_in_use")
        self._g_kv_phys = tel.gauge("serve.paged.kv_bytes_physical")
        self._g_kv_logical = tel.gauge("serve.paged.kv_bytes_logical")

        kv_spec = kv_dtype_spec(kv_dtype, native=model.compute_dtype)
        self.kv_dtype = kv_spec.name
        self.kv_quantized = kv_spec.quantized
        self.page_bytes = page_bytes(self.cfg, page_size, kv_spec.itemsize,
                                     kv_spec.scale_bytes_per_row)
        self.row_bytes = self.page_bytes // page_size
        if prefix_cache:
            from repro.serve.prefix import SharedKVLedger
            self.ledger = SharedKVLedger(
                num_pages, self.page_bytes, page_size,
                num_slots=num_slots,
                max_pages_per_slot=self.max_pages_per_slot,
                telemetry=tel)
        else:
            self.ledger = PagedKVLedger(num_pages, self.page_bytes,
                                        page_size)
        # optional streaming BankEnergyMeter: rides the ledger so every page
        # event is mirrored on the same sim clock, tagged with the causing
        # request — the batcher only supplies slot->request metadata
        self.meter = meter
        self.ledger.meter = meter
        self.access = AccessStats()
        self.stats = PagedStats()

        self.queue = AdmissionQueue()
        self.slots: List[Optional[Request]] = [None] * num_slots
        self._tokens_by_rid: Dict[int, int] = {}   # retired, for J/token
        self._reserved = [0] * num_slots        # worst-case pages not yet held
        self._ctx = np.zeros(num_slots, np.int64)
        self._next_tok = np.zeros(num_slots, np.int32)
        self._table = np.zeros((num_slots, self.max_pages_per_slot), np.int32)
        self._sim_t = 0.0

        self._cache = init_paged_cache(
            self.cfg, num_slots, num_pages, page_size,
            self.max_pages_per_slot, dtype=model.compute_dtype,
            kv_dtype=self.kv_dtype)
        self._prefill = jax.jit(
            lambda p, b, L: model.prefill(p, b, cache_len=L),
            static_argnums=(2,))
        self._write = jax.jit(functools.partial(write_prefill_to_pages,
                                                self.cfg),
                              donate_argnums=(0,))
        self._loop = jax.jit(
            functools.partial(_decode_loop, model, chunk_steps, attn_backend,
                              collect_logits),
            donate_argnums=(1,))
        if prefix_cache or prefill_chunk_tokens is not None:
            from repro.models.transformer import (_require_pure_full,
                                                  gather_prefix_pages,
                                                  write_shared_prefill_to_pages)
            _require_pure_full(model.cfg, "prefix_cache" if prefix_cache
                               else "prefill_chunk_tokens")
            self._gather = jax.jit(
                functools.partial(gather_prefix_pages, self.cfg),
                static_argnums=(2,))
            # fixed attention width = slot capacity: makes the suffix
            # prefill's reduction tree independent of who computed the
            # prefix (donor-exact KV, see _apply_block_shared_prefill) —
            # the same property makes chained chunked-prefill slices
            # bit-exact vs one monolithic prefill
            pad_to = self.max_pages_per_slot * page_size
            self._prefill_shared = jax.jit(
                lambda p, t, pfx: model.prefill_shared(
                    p, {"tokens": t}, pfx, pad_to=pad_to))
            self._write_shared = jax.jit(
                functools.partial(write_shared_prefill_to_pages, self.cfg),
                donate_argnums=(0,))
        if prefix_cache:
            from repro.models.transformer import copy_pages
            self._copy = jax.jit(functools.partial(copy_pages, self.cfg),
                                 donate_argnums=(0,))
        if speculate_k is not None:
            from repro.models.transformer import _require_pure_full
            _require_pure_full(model.cfg, "speculate_k")
            if draft_model is None:
                draft_model, draft_params = self_spec_draft(model, params,
                                                            skip=2)
            self.draft_model = draft_model
            self.draft_params = draft_params
            dcfg = draft_model.cfg
            self.draft_page_bytes = page_bytes(dcfg, page_size,
                                               kv_spec.itemsize,
                                               kv_spec.scale_bytes_per_row)
            self.draft_row_bytes = self.draft_page_bytes // page_size
            self.ledger.enable_draft_lane(self.draft_page_bytes)
            self._draft_table = np.zeros(
                (num_slots, self.max_pages_per_slot), np.int32)
            # the draft lane's pool arrays are indexed by the SAME page ids
            # as the target's (one allocator, one id space), so the draft
            # cache spans the full pool too — at the draft's smaller dims
            self._draft_cache = init_paged_cache(
                dcfg, num_slots, num_pages, page_size,
                self.max_pages_per_slot, dtype=draft_model.compute_dtype,
                kv_dtype=self.kv_dtype)
            self.spec_rounds_per_chunk = max(
                1, chunk_steps // (speculate_k + 1))
            # sim-clock cost of one draft-then-verify round vs one plain
            # decode step: the batched verify streams the target weights
            # once (~= one step), plus k+1 sequential draft steps at the
            # draft's layer fraction
            self.draft_cost_frac = (dcfg.num_layers
                                    / max(1, self.cfg.num_layers))
            self.spec_round_time_s = step_time_s * (
                1.0 + (speculate_k + 1) * self.draft_cost_frac)
            self._draft_prefill = jax.jit(
                lambda p, b, L: draft_model.prefill(p, b, cache_len=L),
                static_argnums=(2,))
            self._draft_write = jax.jit(
                functools.partial(write_prefill_to_pages, dcfg),
                donate_argnums=(0,))
            self._spec_loop = jax.jit(
                functools.partial(_spec_decode_loop, model, draft_model,
                                  self.spec_rounds_per_chunk, speculate_k,
                                  attn_backend),
                donate_argnums=(2, 3))

    # ------------------------------------------------------------ client API
    def submit(self, req: Request) -> None:
        S = int(len(req.tokens))
        cap = self.max_pages_per_slot * self.page_size
        # speculation writes up to V - 1 = speculate_k rows past the final
        # accepted context before the last rollback truncates them
        spec_extra = (self.speculate_k if self.speculate_k is not None
                      and req.max_new_tokens > 1 else 0)
        if S + max(req.max_new_tokens - 1, 0) + spec_extra > cap \
                and self.on_long_prompt == "truncate":
            # keep the decode budget, give the prompt whatever table
            # capacity remains (mirrors the dense batcher's max_len cut)
            keep = cap - max(req.max_new_tokens - 1, 0) - spec_extra
            if keep >= 1:
                req.tokens = np.asarray(req.tokens)[:keep]
                S = keep
        worst = pages_for(S + max(req.max_new_tokens - 1, 0) + spec_extra,
                          self.page_size)
        # speculation doubles the lane count: the draft mirrors the target's
        # page demand row-for-row (same page_size, smaller page_bytes)
        lanes = 2 if self.speculate_k is not None else 1
        # prefix mode reserves one extra pool page for the deferred COW
        # split of a mid-page prompt boundary; it never occupies a table
        # slot (COW swaps an entry in place), but it must fit the pool or
        # admission could wait forever on a demand no drain can satisfy
        pool_worst = worst * lanes + (
            1 if self.prefix_cache and S % self.page_size
            and req.max_new_tokens > 1 else 0)
        if worst > self.max_pages_per_slot or pool_worst > self.num_pages - 1:
            raise OutOfPages(
                f"request {req.rid} needs {worst} table / {pool_worst} pool "
                f"pages; slot tables hold {self.max_pages_per_slot}, pool "
                f"holds {self.num_pages - 1}")
        req.submitted_wall_s = time.perf_counter()
        req.submitted_s = self._sim_t
        if self.tel.enabled:
            req.timeline = RequestTimeline(rid=req.rid, submit_t=self._sim_t)
        self.queue.push(req)

    def run(self, max_chunks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_chunks):
            if not self.queue and all(s is None for s in self.slots):
                break
            self._admit(done)
            self._decode_chunk(done)
        if self._slo is not None:
            self.slo_summary()           # refresh stats percentiles once
        return done

    def slo_summary(self) -> SLOSummary:
        """Percentile view of per-request TTFT / TBT / e2e on the sim clock
        (zeros when the batcher runs without an enabled registry). Quantiles
        are computed here, at read time — never inside the decode loop, so
        enabled telemetry stays off the serving hot path."""
        if self._slo is None:
            return SLOSummary()
        s = self._slo.summary()
        # bytes-based physical occupancy (page count x quantized page_bytes)
        # next to the latency percentiles — page counts alone hide the
        # footprint reduction a quantized kv_dtype buys
        s.kv_peak_bytes = float(self.ledger.trace.peak_needed())
        s.kv_mean_bytes = float(self.ledger.trace.time_weighted_mean(
            max(self._sim_t, self.step_time_s)))
        st = self.stats
        st.ttft_p50_s, st.ttft_p99_s = s.ttft_p50_s, s.ttft_p99_s
        st.tbt_p50_s, st.tbt_p99_s = s.tbt_p50_s, s.tbt_p99_s
        st.e2e_p50_s, st.e2e_p99_s = s.e2e_p50_s, s.e2e_p99_s
        if self.meter is not None:
            attach_energy_percentiles(s, self.meter.request_energy_j(),
                                      self._tokens_by_rid)
        return s

    def occupancy_bundle(self) -> TraceBundle:
        """Page-granular Stage-II view: feed to explorer.sweep() unchanged.

        With `prefix_cache` the bundle carries the dual traces: "kv" is the
        *physical* occupancy (unique referenced pages as needed, cached
        pages as obsolete — what Stage II should gate against) and
        "kv_logical" the per-slot demand sum a non-sharing allocator would
        pin; their gap is the headroom sharing unlocked."""
        traces = {"kv": self.ledger.trace}
        name = f"{self.cfg.name}-paged-serve"
        if self.prefix_cache:
            traces["kv_logical"] = self.ledger.logical
            name = f"{self.cfg.name}-prefix-serve"
        return TraceBundle(graph_name=name,
                           total_time=max(self._sim_t, self.step_time_s),
                           traces=traces, access=self.access)

    # ------------------------------------------------------------- internals
    def _available_pages(self) -> int:
        return self.ledger.allocator.n_free - sum(self._reserved)

    def _worst_pages(self, S: int, max_new: int) -> int:
        """Worst-case page demand of one lane for a prompt of `S` tokens:
        prompt rows + decode rows + the up-to-`speculate_k` overshoot rows a
        verify window can write past the final accepted context."""
        extra = (self.speculate_k if self.speculate_k is not None
                 and max_new > 1 else 0)
        return pages_for(S + max(max_new - 1, 0) + extra, self.page_size)

    @property
    def _lanes(self) -> int:
        return 2 if self.speculate_k is not None else 1

    def _set_page_gauges(self) -> None:
        """Page-count plus bytes-based occupancy gauges: physical = pool
        pages held x page_bytes (quantization shrinks page_bytes), logical =
        the per-slot demand a non-sharing allocator would pin."""
        n = self.ledger.allocator.n_allocated
        self._g_pages.set(n)
        self._g_kv_phys.set(n * self.page_bytes)
        self._g_kv_logical.set(self.ledger.logical_bytes())

    def _retire(self, i: int, req: Request, done: List[Request],
                t: float) -> None:
        req.finished_wall_s = time.perf_counter()
        req.finished_s = t
        done.append(req)
        self.slots[i] = None
        n = self.ledger.retire(i, t)
        self.stats.pages_freed += n
        self.stats.retired_kv_bytes += n * self.page_bytes
        self.stats.finished += 1
        self._reserved[i] = 0
        self._ctx[i] = 0
        self._table[i, :] = 0
        if self.speculate_k is not None:
            self._draft_table[i, :] = 0
        self._c_retired.inc()
        self._c_freed.inc(n)
        self._set_page_gauges()
        if self.meter is not None:
            self._tokens_by_rid[req.rid] = len(req.output)
        tl = req.timeline
        if tl is not None and self.meter is not None:
            # final at retire: the request holds no pages past this event,
            # so no later charge can land on it
            tl.energy_j = self.meter.request_energy_live(req.rid)
        if tl is not None and self._slo is not None:
            tl.finish_t = t
            self._slo.observe(tl)
            self.tel.add_span("request", tl.submit_t, t, rid=req.rid,
                              tokens=len(req.output))
            if np.isfinite(tl.first_token_t) and t > tl.first_token_t:
                self.tel.add_span("decode", tl.first_token_t, t, slot=i,
                                  rid=req.rid)

    def _preempt_victim(self, priority: int) -> Optional[int]:
        """Pick the slot to evict for a `priority`-class admission: the
        lowest-priority active slot strictly below the admitting class
        (equal classes never preempt each other — no livelock), least
        decode progress first within a class (least work discarded)."""
        best = None
        best_key = None
        for i, r in enumerate(self.slots):
            if r is None or r.priority >= priority:
                continue
            key = (r.priority, len(r.output))
            if best is None or key < best_key:
                best, best_key = i, key
        return best

    def _preempt(self, i: int, t: float) -> None:
        """Evict slot `i` and requeue its request. Pages return through the
        ordinary retire path (the occupancy trace stays conservative); the
        partial output is discarded and the prompt re-prefills from scratch
        on re-admission — resuming mid-decode would not be bit-exact (the
        prefill reduction tree differs from the decode kernel's), while a
        greedy restart reproduces the uncontended tokens exactly."""
        req = self.slots[i]
        req.output.clear()
        req.logits.clear()
        req.preemptions += 1
        self.slots[i] = None
        n = self.ledger.retire(i, t)
        self.stats.pages_freed += n
        self.stats.retired_kv_bytes += n * self.page_bytes
        self.stats.preemptions += 1
        self._reserved[i] = 0
        self._ctx[i] = 0
        self._table[i, :] = 0
        if self.speculate_k is not None:
            self._draft_table[i, :] = 0
        self._c_preempt.inc()
        self._c_freed.inc(n)
        self._set_page_gauges()
        if req.timeline is not None:
            req.timeline.reset_admission()
        if self.tel.enabled:
            self.tel.add_span("preempt", t, t, slot=i, rid=req.rid)
        self.queue.push(req)     # fresh seq: re-enters behind its own class

    def _preempt_for(self, priority: int, worst: int) -> bool:
        """Free pages for a `priority`-class admission by preempting
        strictly-lower-priority slots, lowest class / least progress first.
        Returns False when eligible victims run out before `worst` pages
        are coverable (the head then backpressure-waits as before)."""
        while worst > self._available_pages():
            v = self._preempt_victim(priority)
            if v is None:
                return False
            self._preempt(v, self._sim_t)
        return True

    def _admit(self, done: List[Request]) -> None:
        while self.queue:
            i = next((k for k, s in enumerate(self.slots) if s is None), None)
            if i is None:
                # every slot is busy: a strictly-higher-priority head may
                # evict the lowest-priority slot instead of queueing
                v = self._preempt_victim(self.queue.peek().priority)
                if v is None:
                    break
                self._preempt(v, self._sim_t)
                continue
            if self.prefix_cache:
                if not self._admit_prefix(i, done):
                    break                  # wait for pages to free up
                continue
            req = self.queue.peek()
            prompt_len = int(len(req.tokens))
            worst = self._worst_pages(prompt_len, req.max_new_tokens) \
                * self._lanes
            if worst > self._available_pages() \
                    and not self._preempt_for(req.priority, worst):
                self._c_wait.inc()
                break                      # wait for pages to free up
            self.queue.pop()
            if (self.prefill_chunk_tokens is not None
                    and prompt_len > self.prefill_chunk_tokens):
                self._admit_chunked(i, req, done, worst)
                continue
            npg = pages_for(prompt_len, self.page_size)
            t_pre = self._sim_t

            batch = {"tokens": jnp.asarray(np.asarray(req.tokens)[None, :],
                                           jnp.int32)}
            logits, dense = self._prefill(self.params, batch,
                                          npg * self.page_size)
            tok = int(jnp.argmax(logits[0, -1]))
            self._sim_t += prompt_len * self.prefill_tok_s
            if self.meter is not None:
                self.ledger.set_slot_meta(i, req.rid, req.tenant)
            pages = self.ledger.admit(i, npg, self._sim_t)
            self._reserved[i] = worst - npg
            self.stats.pages_allocated += npg
            self.stats.peak_pages = max(self.stats.peak_pages,
                                        self.ledger.allocator.n_allocated)
            self.stats.admitted_kv_bytes += npg * self.page_bytes
            self.access.add_write("kv", prompt_len * self.row_bytes)
            self._c_alloc.inc(npg)

            self._cache = self._write(self._cache, dense, i,
                                      jnp.asarray(pages, jnp.int32))
            self._commit_admission(i, req, done, tok, logits, prompt_len,
                                   pages, t_pre)

    def _admit_chunked(self, i: int, req: Request, done: List[Request],
                       worst: int) -> None:
        """Chunked prefill: admit `req` into slot `i` in page-aligned
        slices of `prefill_chunk_tokens`, running one decode chunk for the
        other active slots between consecutive slices so a long prompt no
        longer stalls their token cadence. Slice 0 is a plain prefill;
        every later slice gathers the slot's own pages as a prefix and runs
        the suffix-only shared prefill at fixed attention width — the
        donor-exact property from prefix sharing, so the emitted tokens are
        bit-identical to one monolithic prefill. The slot stays invisible
        to the decode loop (host `active` mask) until the last slice
        commits; the page reservation made up-front keeps interleaved
        chunks from stealing this slot's worst-case pages.

        Tracing: each distinct (resident rows, slice length) pair traces
        once — every slice but the last is exactly `prefill_chunk_tokens`
        long, so long prompts bucket naturally."""
        prompt = np.asarray(req.tokens)
        S = int(len(prompt))
        ps = self.page_size
        C = self.prefill_chunk_tokens
        t_pre = self._sim_t
        pos = 0
        logits = None
        while pos < S:
            take = min(C, S - pos)
            sl = jnp.asarray(prompt[None, pos:pos + take], jnp.int32)
            t0 = self._sim_t
            if pos == 0:
                new_n = pages_for(take, ps)
                logits, dense = self._prefill(self.params, {"tokens": sl},
                                              new_n * ps)
                self._sim_t += take * self.prefill_tok_s
                if self.meter is not None:
                    self.ledger.set_slot_meta(i, req.rid, req.tenant)
                pages = self.ledger.admit(i, new_n, self._sim_t)
                self._reserved[i] = worst - new_n
                self._cache = self._write(self._cache, dense, i,
                                          jnp.asarray(pages, jnp.int32))
            else:
                held = list(self.ledger.slot_pages[i])
                prefix = self._gather(self._cache,
                                      jnp.asarray(held, jnp.int32), pos)
                if self.kv_quantized:
                    self._c_dequant.inc(len(held))
                head = prefix_tail_rows(prefix, 0)   # pos is page-aligned
                logits, suffix = self._prefill_shared(self.params, sl, prefix)
                self._sim_t += take * self.prefill_tok_s
                fresh = self.ledger.grow(i, pages_for(pos + take, ps),
                                         self._sim_t, cause="admission")
                self._reserved[i] -= len(fresh)
                new_n = len(fresh)
                self._cache = self._write_shared(
                    self._cache, suffix, head, jnp.int32(i),
                    jnp.asarray(held, jnp.int32),
                    jnp.asarray(fresh, jnp.int32))
            self.stats.pages_allocated += new_n
            self.stats.admitted_kv_bytes += new_n * self.page_bytes
            self.stats.peak_pages = max(self.stats.peak_pages,
                                        self.ledger.allocator.n_allocated)
            self.stats.prefill_slices += 1
            self.access.add_write("kv", take * self.row_bytes)
            self._c_alloc.inc(new_n)
            self._c_slices.inc()
            if self.tel.enabled:
                self.tel.add_span("prefill_slice", t0, self._sim_t, slot=i,
                                  rid=req.rid, tokens=take)
            pos += take
            if pos < S:
                # let the active slots stream tokens before the next slice
                self._decode_chunk(done)
        tok = int(jnp.argmax(logits[0, -1]))
        self._commit_admission(i, req, done, tok, logits, S,
                               self.ledger.slot_pages[i], t_pre)

    def _commit_admission(self, i: int, req: Request, done: List[Request],
                          tok: int, logits, ctx: int,
                          table_pages: List[int], t_pre: float) -> None:
        """Shared admission tail for the plain and prefix paths: host
        mirrors, stats, the prefill-produced first token, and the immediate
        retire when that token already satisfies the request. `t_pre` is
        the sim time before the prefill advance (the span start)."""
        self.slots[i] = req
        self._c_admitted.inc()
        self._c_prefills.inc()
        self._set_page_gauges()
        if self.tel.enabled:
            self.tel.add_span("prefill", t_pre, self._sim_t, slot=i,
                              rid=req.rid, tokens=ctx)
            tl = req.timeline
            if tl is not None:
                tl.admit_t = t_pre
                tl.first_token_t = self._sim_t
                tl.token_ts.append(self._sim_t)
        self._ctx[i] = ctx
        self._next_tok[i] = tok
        self._table[i, :] = 0
        self._table[i, :len(table_pages)] = table_pages
        req.output.append(tok)
        if self.collect_logits:
            req.logits.append(np.asarray(logits[0, -1]))
        self.stats.admitted += 1
        self.stats.prefills += 1
        self.stats.peak_active_slots = max(
            self.stats.peak_active_slots,
            sum(s is not None for s in self.slots))
        if (req.max_new_tokens <= 1
                or (req.eos_id is not None and tok == req.eos_id)):
            self._retire(i, req, done, self._sim_t)
        elif self.speculate_k is not None:
            self._admit_draft_lane(i, req)

    def _admit_draft_lane(self, i: int, req: Request) -> None:
        """Prefill the draft model over the full prompt into the slot's
        draft page lane. The draft never shares pages (no radix entry, no
        COW) — a prefix-cache hit only accelerates the target lane; the
        draft re-prefills its own (much smaller) KV from scratch."""
        prompt = np.asarray(req.tokens)
        S = int(len(prompt))
        dn = pages_for(S, self.page_size)
        self._sim_t += S * self.prefill_tok_s * self.draft_cost_frac
        dpages = self.ledger.admit_draft(i, dn, self._sim_t)
        self._reserved[i] -= dn
        self._draft_table[i, :] = 0
        self._draft_table[i, :dn] = dpages
        batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
        _, ddense = self._draft_prefill(self.draft_params, batch,
                                        dn * self.page_size)
        self._draft_cache = self._draft_write(
            self._draft_cache, ddense, i, jnp.asarray(dpages, jnp.int32))
        self.stats.pages_allocated += dn
        self.stats.peak_pages = max(self.stats.peak_pages,
                                    self.ledger.allocator.n_allocated)
        self.stats.admitted_kv_bytes += dn * self.draft_page_bytes
        self.access.add_write("kv", S * self.draft_row_bytes)
        self._c_alloc.inc(dn)
        self._set_page_gauges()

    def _admit_prefix(self, i: int, done: List[Request]) -> bool:
        """Prefix-cache admission of the queue head into slot `i`.

        Returns False when the pool (after LRU-evicting cached prefixes and
        preempting strictly-lower-priority slots) still cannot cover the
        request's worst-case *fresh* page demand — the head then waits. The
        worst case reserves the pages the match did not cover, plus one
        page for the deferred COW split of a mid-page prompt boundary."""
        req = self.queue.peek()
        prompt = np.asarray(req.tokens)
        S = int(len(prompt))
        ps = self.page_size
        worst_total = self._worst_pages(S, req.max_new_tokens)
        cow_extra = 1 if (S % ps and req.max_new_tokens > 1) else 0
        # the draft lane never shares: a hit only accelerates the target
        # lane, the draft's full worst case is fresh demand
        draft_extra = worst_total if self.speculate_k is not None else 0

        def demand(match):
            return (worst_total - len(match.pages) + cow_extra
                    + draft_extra)

        match = self.ledger.index.probe(prompt, limit=S - 1)
        short = demand(match) - self._available_pages()
        while short > 0:
            freed = self.ledger.evict_for(short, self._sim_t)
            if freed:
                self.stats.evicted_pages += freed
                self._c_evicted.inc(freed)
            else:
                # nothing cached left to drop: preempt a lower-priority
                # slot before giving up (pages free via the retire path)
                v = self._preempt_victim(req.priority)
                if v is None:
                    self._c_wait.inc()
                    return False
                self._preempt(v, self._sim_t)
            # eviction/preemption may have changed the matched path: re-probe
            match = self.ledger.index.probe(prompt, limit=S - 1)
            short = demand(match) - self._available_pages()
        self.queue.pop()

        n_full, j = len(match.pages), match.tail_tokens
        m = n_full * ps + j
        t_pre = self._sim_t
        C = self.prefill_chunk_tokens
        suffix_len = S - m
        if C is not None and suffix_len > C:
            # chunk the suffix-only prefill: slice 0 is sized to re-align
            # the (possibly mid-page) match boundary to a page multiple so
            # every later slice boundary is page-aligned — the chained
            # gather → fixed-width shared prefill then keeps the result
            # bit-identical to one monolithic suffix prefill
            slices = [min(C - (m % ps), suffix_len)]
            while sum(slices) < suffix_len:
                slices.append(min(C, suffix_len - sum(slices)))
        else:
            slices = [suffix_len]

        pos = m
        logits = None
        for si, take in enumerate(slices):
            sl = jnp.asarray(prompt[None, pos:pos + take], jnp.int32)
            t0 = self._sim_t
            if si == 0:
                gather_ids = list(match.pages) + \
                    ([match.tail_page] if j else [])
                prefix = self._gather(self._cache,
                                      jnp.asarray(gather_ids, jnp.int32), m)
                if self.kv_quantized and gather_ids:
                    self._c_dequant.inc(len(gather_ids))
                head = prefix_tail_rows(prefix, j)
                logits, suffix = self._prefill_shared(self.params, sl,
                                                      prefix)
                self._sim_t += take * self.prefill_tok_s  # suffix only
                new_n = pages_for(m + take, ps) - n_full
                if self.meter is not None:
                    self.ledger.set_slot_meta(i, req.rid, req.tenant)
                fresh = self.ledger.admit(i, new_n, self._sim_t,
                                          shared=match.pages)
                self._reserved[i] = demand(match) - new_n
                self._cache = self._write_shared(
                    self._cache, suffix, head, jnp.int32(i),
                    jnp.asarray(match.pages, jnp.int32),
                    jnp.asarray(fresh, jnp.int32))
            else:
                held = list(self.ledger.slot_pages[i])
                prefix = self._gather(self._cache,
                                      jnp.asarray(held, jnp.int32), pos)
                if self.kv_quantized:
                    self._c_dequant.inc(len(held))
                head = prefix_tail_rows(prefix, 0)   # pos is page-aligned
                logits, suffix = self._prefill_shared(self.params, sl,
                                                      prefix)
                self._sim_t += take * self.prefill_tok_s
                fresh = self.ledger.grow(i, pages_for(pos + take, ps),
                                         self._sim_t, cause="admission")
                self._reserved[i] -= len(fresh)
                new_n = len(fresh)
                self._cache = self._write_shared(
                    self._cache, suffix, head, jnp.int32(i),
                    jnp.asarray(held, jnp.int32),
                    jnp.asarray(fresh, jnp.int32))
            self.stats.pages_allocated += new_n
            self.stats.peak_pages = max(self.stats.peak_pages,
                                        self.ledger.allocator.n_allocated)
            self.stats.admitted_kv_bytes += new_n * self.page_bytes
            self.access.add_write("kv", take * self.row_bytes)
            self._c_alloc.inc(new_n)
            if len(slices) > 1:
                self.stats.prefill_slices += 1
                self._c_slices.inc()
                if self.tel.enabled:
                    self.tel.add_span("prefill_slice", t0, self._sim_t,
                                      slot=i, rid=req.rid, tokens=take)
            pos += take
            if pos < S:
                # let the active slots stream tokens before the next slice
                self._decode_chunk(done)
        tok = int(jnp.argmax(logits[0, -1]))
        if m:
            self.stats.prefix_hits += 1
            self.stats.prefix_tokens_reused += m
            self._c_hits.inc()
            self._c_reused.inc(m)
        else:
            self._c_miss.inc()
        # cache this run for later requests (index refs its pages)
        self.ledger.insert_run(prompt, self.ledger.slot_pages[i], self._sim_t)
        self._commit_admission(i, req, done, tok, logits, S,
                               self.ledger.slot_pages[i], t_pre)
        return True

    def _cow_for_chunk(self, i: int, steps_i: int, t: float) -> None:
        """Copy-on-write split every shared page this chunk will write.

        Decode appends rows [ctx, ctx + steps_i); only the page holding the
        prompt's mid-page boundary can be shared (with the prefix index, or
        with slots that mapped the same run), so at most one split fires per
        slot — but the scan is range-exact regardless. The reservation made
        at admission covers the extra page, so `alloc` cannot fail."""
        ps = self.page_size
        ctx = int(self._ctx[i])
        pages = self.ledger.slot_pages[i]
        first, last = ctx // ps, (ctx + steps_i - 1) // ps
        for idx in range(first, min(last + 1, len(pages))):
            page = pages[idx]
            if self.ledger.allocator.refcount(page) <= 1:
                continue
            new = self.ledger.cow(i, idx, t)
            self._cache = self._copy(self._cache, jnp.int32(page),
                                     jnp.int32(new))
            self._table[i, idx] = new
            self._reserved[i] -= 1
            self.stats.cow_splits += 1
            self.stats.pages_allocated += 1
            self._c_cow.inc()
            self._c_alloc.inc()
            self.tel.add_span("cow", t, t, slot=i, page=new)

    def _decode_chunk(self, done: List[Request]) -> None:
        if self.speculate_k is not None:
            return self._spec_chunk(done)
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return
        t0 = self._sim_t
        # grow page tables to cover this chunk's worst case (reservation at
        # admission guarantees these allocations succeed)
        remaining = np.zeros(self.num_slots, np.int32)
        for i in live:
            req = self.slots[i]
            remaining[i] = req.max_new_tokens - len(req.output)
            steps_i = min(self.chunk_steps, int(remaining[i]))
            new_pages = self.ledger.grow(
                i, pages_for(int(self._ctx[i]) + steps_i, self.page_size), t0)
            if new_pages:
                npg_have = len(self.ledger.slot_pages[i])
                self._table[i, npg_have - len(new_pages):npg_have] = new_pages
                self._reserved[i] -= len(new_pages)
                self.stats.pages_allocated += len(new_pages)
                self.stats.admitted_kv_bytes += len(new_pages) * self.page_bytes
                self._c_alloc.inc(len(new_pages))
            if self.prefix_cache:
                self._cow_for_chunk(i, steps_i, t0)
        self.stats.peak_pages = max(self.stats.peak_pages,
                                    self.ledger.allocator.n_allocated)

        cache = self._cache
        # host is the source of truth between chunks: push the page-table
        # mirror and the liveness mask (covers slots retired host-side at
        # admission, whose device `active` flag was never flipped in-scan)
        cache["page_table"] = jnp.asarray(self._table)
        cache["active"] = jnp.asarray(
            [s is not None for s in self.slots])
        emitted, cache, tok, _ = self._loop(
            self.params, cache, jnp.asarray(self._next_tok[:, None]),
            jnp.asarray([(self.slots[i].eos_id if self.slots[i] is not None
                          and self.slots[i].eos_id is not None else -1)
                         for i in range(self.num_slots)], jnp.int32),
            jnp.asarray(remaining))
        self._cache = cache
        self.stats.chunks += 1
        step_logits = None
        if self.collect_logits:
            emitted, step_logits = emitted
            step_logits = np.asarray(step_logits)  # (steps, num_slots, V)
        emitted = np.asarray(emitted)                    # (steps, num_slots)
        self._next_tok = np.array(tok[:, 0])
        still_active = np.array(cache["active"])
        self._sim_t = t0 + self.chunk_steps * self.step_time_s
        self._c_chunks.inc()
        self.tel.add_span("decode_chunk", t0, self._sim_t, slots=len(live))

        for i in live:
            req = self.slots[i]
            col = emitted[:, i]
            neg = np.nonzero(col < 0)[0]
            g = int(neg[0]) if len(neg) else len(col)
            req.output.extend(int(t) for t in col[:g])
            if step_logits is not None:
                req.logits.extend(step_logits[:g, i])
            self.stats.decode_steps += g
            # page-granular access accounting: each step streams the resident
            # pages and appends one row
            ctxs = int(self._ctx[i]) + 1 + np.arange(g)
            pages_read = int((np.ceil(ctxs / self.page_size)).sum())
            self.access.add_read("kv", pages_read * self.page_bytes)
            self.access.add_write("kv", g * self.row_bytes)
            if self.kv_quantized and pages_read:
                # every page the fused kernel streams is dequantized
                # in-register
                self._c_dequant.inc(pages_read)
            self._c_steps.inc(g)
            if req.timeline is not None and g:
                req.timeline.token_ts.extend(
                    (t0 + self.step_time_s * np.arange(1, g + 1)).tolist())
            self._ctx[i] += g
            if not still_active[i]:
                self._retire(i, req, done, t0 + g * self.step_time_s)

    def _spec_chunk(self, done: List[Request]) -> None:
        """One speculative decode chunk: `spec_rounds_per_chunk` draft-then-
        verify rounds for every live slot in one donated `lax.scan`, then a
        host sync that harvests the accepted tokens and *rolls back* both
        lanes by page truncation — every page past the accepted context
        frees mid-stream (the negative occupancy deltas Stage I sees as the
        speculative burst/rollback signature) and returns to the slot's
        reservation for later re-growth."""
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return
        t0 = self._sim_t
        V = self.speculate_k + 1
        R = self.spec_rounds_per_chunk
        ps = self.page_size
        remaining = np.zeros(self.num_slots, np.int32)
        for i in live:
            req = self.slots[i]
            remaining[i] = req.max_new_tokens - len(req.output)
            # worst rows this chunk can touch: every round writes V rows at
            # pos..pos+V-1 and advances >= 1, and the last active round
            # starts with >= 1 token remaining
            rows = int(self._ctx[i]) + min(R * V, int(remaining[i]) + V - 1)
            npg = pages_for(rows, ps)
            new_pages = self.ledger.grow(i, npg, t0)
            if new_pages:
                have = len(self.ledger.slot_pages[i])
                self._table[i, have - len(new_pages):have] = new_pages
                self._reserved[i] -= len(new_pages)
                self.stats.pages_allocated += len(new_pages)
                self.stats.admitted_kv_bytes += \
                    len(new_pages) * self.page_bytes
                self._c_alloc.inc(len(new_pages))
            dnew = self.ledger.grow_draft(i, npg, t0)
            if dnew:
                dhave = len(self.ledger.draft_pages[i])
                self._draft_table[i, dhave - len(dnew):dhave] = dnew
                self._reserved[i] -= len(dnew)
                self.stats.pages_allocated += len(dnew)
                self.stats.admitted_kv_bytes += \
                    len(dnew) * self.draft_page_bytes
                self._c_alloc.inc(len(dnew))
            if self.prefix_cache:
                # the verify window writes past ctx: COW every shared page
                # in the full speculative write range, not just chunk_steps
                self._cow_for_chunk(i, rows - int(self._ctx[i]), t0)
        self.stats.peak_pages = max(self.stats.peak_pages,
                                    self.ledger.allocator.n_allocated)

        cache = self._cache
        dcache = self._draft_cache
        cache["page_table"] = jnp.asarray(self._table)
        dcache["page_table"] = jnp.asarray(self._draft_table)
        # two separate transfers: both caches are donated, so the liveness
        # masks must be distinct buffers even though their contents match
        act_np = np.array([s is not None for s in self.slots])
        cache["active"] = jnp.asarray(act_np)
        dcache["active"] = jnp.asarray(act_np.copy())
        emitted, cache, dcache, tok, _ = self._spec_loop(
            self.params, self.draft_params, cache, dcache,
            jnp.asarray(self._next_tok[:, None]),
            jnp.asarray([(self.slots[i].eos_id if self.slots[i] is not None
                          and self.slots[i].eos_id is not None else -1)
                         for i in range(self.num_slots)], jnp.int32),
            jnp.asarray(remaining))
        self._cache = cache
        self._draft_cache = dcache
        self.stats.chunks += 1
        emitted = np.asarray(emitted)            # (rounds, num_slots, V)
        self._next_tok = np.array(tok[:, 0])
        still_active = np.array(cache["active"])
        self._sim_t = t0 + R * self.spec_round_time_s
        self._c_chunks.inc()
        self.tel.add_span("decode_chunk", t0, self._sim_t, slots=len(live))

        for i in live:
            req = self.slots[i]
            block = emitted[:, i, :]             # (rounds, V), -1 padded
            m_r = (block >= 0).sum(axis=1)       # per-round accepted count
            rounds_used = int((m_r > 0).sum())
            toks = block.ravel()
            toks = toks[toks >= 0]
            g = int(len(toks))
            req.output.extend(int(t) for t in toks)
            self.stats.decode_steps += g
            self.stats.spec_rounds += rounds_used
            self.stats.drafted_tokens += rounds_used * self.speculate_k
            self.stats.accepted_tokens += g
            self._c_spec_rounds.inc(rounds_used)
            self._c_drafted.inc(rounds_used * self.speculate_k)
            self._c_accepted.inc(g)
            # page-granular access accounting, per round: the verify kernel
            # streams the target's resident pages once; the draft streams
            # its own lane for each of its k+1 sequential steps
            ctx = int(self._ctx[i])
            pos = ctx
            pages_t = 0
            pages_d = 0
            for r in range(rounds_used):
                per_round = -(-(pos + V) // ps)
                pages_t += per_round
                pages_d += (self.speculate_k + 1) * per_round
                pos += int(m_r[r])
            self.access.add_read("kv", pages_t * self.page_bytes
                                 + pages_d * self.draft_page_bytes)
            self.access.add_write(
                "kv", rounds_used * (V * self.row_bytes
                                     + (self.speculate_k + 1)
                                     * self.draft_row_bytes))
            if self.kv_quantized and pages_t:
                self._c_dequant.inc(pages_t + pages_d)
            self._c_steps.inc(g)
            if req.timeline is not None and g:
                ts: List[float] = []
                for r in range(rounds_used):
                    ts.extend([t0 + (r + 1) * self.spec_round_time_s]
                              * int(m_r[r]))
                req.timeline.token_ts.extend(ts)
            self._ctx[i] = ctx + g
            t_end = t0 + rounds_used * self.spec_round_time_s
            # rollback-by-page-truncation: both lanes drop every page past
            # the accepted context; freed pages rejoin the reservation
            ft, fd = self.ledger.truncate_rows(i, int(self._ctx[i]), t_end)
            nf = len(ft) + len(fd)
            if nf:
                keep = pages_for(int(self._ctx[i]), ps)
                self._table[i, keep:] = 0
                self._draft_table[i, keep:] = 0
                self._reserved[i] += nf
                self.stats.pages_freed += nf
                self.stats.rolled_back_pages += nf
                self._c_freed.inc(nf)
                self._c_rollback.inc(nf)
            if not still_active[i]:
                self._retire(i, req, done, t_end)


def loop_compile_count() -> int:
    """How many times the chunk decode loop has been traced/compiled
    process-wide (tests assert it does not grow across chunks) —
    compatibility shim over the `serve.paged.loop_compiles` registry
    counter (the old module-global it replaced)."""
    return int(_COMPILES.value)
