"""Paged KV-cache serving: free-list page allocator + device-resident batcher.

This is the production face of the paper's occupancy analysis: KV memory is
allocated in fixed-size pages rather than dense ``max_len`` slabs, so a
slot's resident bytes track its *true* context length (quantized to one
page), GQA shrinks the page itself, and fragmentation / page residency
become first-class time-resolved signals. Three pieces:

  * :class:`PageAllocator` — host-side free list over the global page pool
    (page 0 is reserved as the null page inactive slots point at);
  * :class:`PagedKVLedger` — page accounting + page-granular
    `OccupancyTrace` emission (alloc/free events integrate to zero at
    drain; occupancy is always ``pages x page_bytes``);
  * :class:`PagedContinuousBatcher` — priority continuous batching (FIFO
    within a class; strictly-higher-priority arrivals may preempt) where
    the decode hot path is device-resident: one jitted ``lax.scan`` advances
    every slot ``chunk_steps`` tokens per host round-trip (donated cache
    buffers, no per-token sync), admission *maps the prompt's pages into
    the slot's table* instead of re-prefilling, and per-slot positions are
    exact — no max-length mask.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant import kv_dtype_spec
from repro.models.transformer import (init_paged_cache, prefix_tail_rows,
                                      write_prefill_to_pages)
from repro.obs.slo import RequestTimeline, SLOSummary, SLOTracker
from repro.obs.telemetry import default_registry, noop_registry
from repro.serve.scheduler import AdmissionQueue, Request, SchedulerStats
from repro.sim.trace import AccessStats, OccupancyTrace, TraceBundle


class OutOfPages(RuntimeError):
    """The page pool cannot cover a request's worst-case page demand."""


def page_bytes(cfg, page_size: int, kv_dtype_bytes: int = 2,
               scale_bytes_per_row: int = 0) -> int:
    """Bytes one KV page pins across all full-attention layers (K + V).

    `scale_bytes_per_row` adds the per-(token row, kv head) quantization
    scale storage (4 for int8's float32 per-row scales, 0 for float and
    scale-free fp8 pools) so quantized ledgers account the true physical
    footprint, scales included."""
    n_full = sum(1 for k in cfg.layer_kinds() if k == "full")
    b = n_full * 2 * page_size * cfg.kv_dim * kv_dtype_bytes
    if scale_bytes_per_row:
        b += n_full * 2 * page_size * cfg.num_kv_heads * scale_bytes_per_row
    return b


def pages_for(tokens: int, page_size: int) -> int:
    return max(0, -(-tokens // page_size))


# ---------------------------------------------------------------------------
# Allocator + ledger (host side, model-free — hypothesis-testable)
# ---------------------------------------------------------------------------

class PageAllocator:
    """LIFO free-list allocator over `num_pages` pages; page 0 is the
    reserved null page and is never handed out."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._allocated: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(f"requested {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._allocated:
                raise ValueError(f"double free / foreign page {p}")
            self._allocated.remove(p)
            self._free.append(p)


class PagedKVLedger:
    """Per-slot page ownership + page-granular occupancy trace.

    Every `admit`/`grow` emits a positive delta of ``n_pages x page_bytes``
    on the trace at the given logical time, every `retire` the matching
    negative delta — so the integrated trace equals the allocator's
    outstanding pages at all times, and drains to zero."""

    def __init__(self, num_pages: int, page_bytes_: int):
        self.allocator = PageAllocator(num_pages)
        self.page_bytes = page_bytes_
        self.trace = OccupancyTrace("kv", (num_pages - 1) * page_bytes_)
        self.slot_pages: Dict[int, List[int]] = {}

    def occupancy_bytes(self) -> int:
        return self.allocator.n_allocated * self.page_bytes

    def logical_bytes(self) -> int:
        """Without sharing, logical (per-slot demand) == physical bytes."""
        return self.occupancy_bytes()

    def admit(self, slot: int, n_pages: int, t: float) -> List[int]:
        assert slot not in self.slot_pages, f"slot {slot} already admitted"
        pages = self.allocator.alloc(n_pages)
        self.slot_pages[slot] = list(pages)
        if n_pages:
            self.trace.event(t, n_pages * self.page_bytes, 0)
        return pages

    def grow(self, slot: int, total_pages: int, t: float) -> List[int]:
        have = self.slot_pages[slot]
        extra = total_pages - len(have)
        if extra <= 0:
            return []
        pages = self.allocator.alloc(extra)
        have.extend(pages)
        self.trace.event(t, extra * self.page_bytes, 0)
        return pages

    def retire(self, slot: int, t: float) -> int:
        pages = self.slot_pages.pop(slot)
        self.allocator.free(pages)
        if pages:
            self.trace.event(t, -len(pages) * self.page_bytes, 0)
        return len(pages)


# ---------------------------------------------------------------------------
# Device decode loop
# ---------------------------------------------------------------------------

# traced once per XLA compilation of the chunk loop — tests assert the
# continuous batcher never recompiles it across chunks/admissions; counted
# on the process-wide registry (loop_compile_count() is the shim view)
_COMPILES = default_registry().counter("serve.paged.loop_compiles")


def _decode_loop(model, steps: int, attn_backend: str, collect_logits: bool,
                 params, cache, tok, eos, remaining):
    """Greedy multi-token decode: `steps` tokens for every slot in one
    on-device `lax.scan`. Slots retire in-scan (EOS or token budget) via the
    cache's `active` mask; inactive lanes emit -1 and stop advancing. With
    `collect_logits` the scan additionally emits every step's last-position
    logits (exactness debugging / the bit-identity regression)."""
    _COMPILES.inc()

    def step(carry, _):
        cache, tok, remaining = carry
        logits, cache = model.decode_step_paged(params, cache, tok,
                                                attn_backend=attn_backend)
        active = cache["active"]
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        emit = jnp.where(active, nxt, -1)
        remaining = remaining - active.astype(jnp.int32)
        done = active & ((remaining <= 0) | ((eos >= 0) & (nxt == eos)))
        cache = dict(cache)
        cache["active"] = active & ~done
        tok = jnp.where(active[:, None], nxt[:, None], tok)
        out = (emit, logits[:, -1, :]) if collect_logits else emit
        return (cache, tok, remaining), out

    (cache, tok, remaining), emitted = jax.lax.scan(
        step, (cache, tok, remaining), None, length=steps)
    return emitted, cache, tok, remaining


# ---------------------------------------------------------------------------
# Continuous batcher
# ---------------------------------------------------------------------------

@dataclass
class PagedStats(SchedulerStats):
    pages_allocated: int = 0
    pages_freed: int = 0
    peak_pages: int = 0
    chunks: int = 0
    # prefix-sharing counters (stay zero without prefix_cache)
    cow_splits: int = 0
    evicted_pages: int = 0
    # chunked-prefill slices executed (zero without prefill_chunk_tokens)
    prefill_slices: int = 0


class PagedContinuousBatcher:
    """Priority continuous batching over a paged KV cache.

    Admission pops the highest-priority queued request (FIFO within a
    class). When the head would otherwise wait — no free slot, or the pool
    cannot cover its worst-case pages — it may *preempt* strictly-lower-
    priority active slots: the victim's pages free through the retire path,
    its partial output is discarded, and the request requeues behind its
    own class for a from-scratch re-prefill (greedy restart keeps its
    tokens bit-identical to an uncontended run). Equal priorities never
    preempt each other, so the default ``priority=0`` workload behaves
    exactly like the old FCFS batcher.

    Chunked prefill (``prefill_chunk_tokens``, pure full-attention stacks,
    exclusive with ``prefix_cache``): prompts longer than the chunk admit
    in page-aligned slices with one decode chunk for the other slots
    interleaved between slices, so a long prompt stops stalling every
    active stream's time-between-tokens. Slices chain through the shared-
    prefix machinery (gather resident pages → suffix-only prefill at fixed
    attention width), which keeps the emitted tokens bit-identical to one
    monolithic prefill.

    Admission prefills the prompt once (batch=1), then scatters its KV rows
    into freshly allocated pages of the global pool — older slots are never
    touched. Decode runs in device-resident chunks of `chunk_steps` tokens
    (one jitted, donated `lax.scan` per chunk; the host syncs once per chunk
    to collect tokens, retire finished slots, free their pages, and admit
    queued requests). A request is admitted only when the pool can cover its
    worst-case page demand (prompt + max_new_tokens), so growth allocations
    between chunks never fail mid-stream.

    Emits the same Stage-I artifact as `ContinuousBatcher`, but at page
    granularity: `occupancy_bundle()` is a `TraceBundle` whose "kv" trace
    steps in units of `page_bytes` — feed it to `core.explorer.sweep` /
    `core.candidates.evaluate_candidates` unchanged.

    Compile discipline: the chunk decode loop compiles exactly once (shapes
    are fixed by the pool geometry). Admission prefill, like the dense
    batcher's, still traces per distinct (prompt length, page count) — pad
    or bucket prompts client-side if admission latency matters. With
    `prefix_cache` the hit path traces per (matched length, suffix length)
    pair instead.

    Prefix sharing (`prefix_cache=True`, pure full-attention stacks only):
    admission probes a `RadixPrefixIndex` with the prompt, maps matched
    pages read-only into the slot's table, runs a *suffix-only* prefill
    against the gathered prefix KV (bit-exact vs the full prefill), and
    caches every admitted run for later requests. The last page of a shared
    run is copy-on-write split on the first divergent write; unreferenced
    cached prefixes are LRU-evicted under page pressure. The ledger then
    emits dual Stage-I traces — "kv" (physical: unique referenced pages,
    cache-resident pages as obsolete) and "kv_logical" (per-slot demand sum)
    — so Stage II can price the gating headroom sharing unlocks.
    """

    def __init__(self, model, params, *, num_slots: int = 4,
                 page_size: int = 16, num_pages: int = 64,
                 max_pages_per_slot: Optional[int] = None,
                 chunk_steps: int = 16, attn_backend: str = "auto",
                 step_time_s: float = 1e-3, prefill_tok_s: float = 5e-5,
                 prefix_cache: bool = False, collect_logits: bool = False,
                 kv_dtype: str = "native",
                 prefill_chunk_tokens: Optional[int] = None,
                 on_long_prompt: str = "reject", telemetry=None):
        if not hasattr(model, "decode_step_paged"):
            raise TypeError("model lacks a paged decode path")
        if on_long_prompt not in ("reject", "truncate"):
            raise ValueError("on_long_prompt must be 'reject' or 'truncate'")
        if prefill_chunk_tokens is not None:
            if prefix_cache:
                raise ValueError(
                    "prefill_chunk_tokens is incompatible with prefix_cache "
                    "(both paths own the shared-prefill machinery; chunk "
                    "the suffix-only prefill is future work)")
            if prefill_chunk_tokens < page_size or \
                    prefill_chunk_tokens % page_size:
                raise ValueError(
                    "prefill_chunk_tokens must be a positive multiple of "
                    f"page_size={page_size} so every slice boundary is "
                    "page-aligned (the chained slice prefill gathers whole "
                    f"pages); got {prefill_chunk_tokens}")
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages_per_slot = max_pages_per_slot or \
            max(1, (num_pages - 1) // max(1, num_slots))
        self.chunk_steps = chunk_steps
        self.step_time_s = step_time_s
        self.prefill_tok_s = prefill_tok_s
        self.prefix_cache = prefix_cache
        self.collect_logits = collect_logits
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.on_long_prompt = on_long_prompt

        # spans and SLOs record on the batcher's logical sim clock — the
        # time base the ledger's occupancy trace uses — so a passed-in
        # registry has its clock bound here: the Perfetto export then shows
        # request spans and the KV counter track on one timeline. bind_clock
        # raises if another engine already owns the registry's clock (a
        # second batcher would silently corrupt the first one's timelines).
        self.tel = telemetry if telemetry is not None else noop_registry()
        if telemetry is not None:
            telemetry.bind_clock(lambda: self._sim_t, owner=self)
        tel = self.tel
        self._slo = (SLOTracker(tel, "serve.paged") if tel.enabled else None)
        self._c_admitted = tel.counter("serve.paged.admitted")
        self._c_retired = tel.counter("serve.paged.retired")
        self._c_prefills = tel.counter("serve.paged.prefills")
        self._c_chunks = tel.counter("serve.paged.chunks")
        self._c_steps = tel.counter("serve.paged.decode_steps")
        self._c_alloc = tel.counter("serve.paged.pages_allocated")
        self._c_freed = tel.counter("serve.paged.pages_freed")
        self._c_evicted = tel.counter("serve.paged.pages_evicted")
        self._c_cow = tel.counter("serve.paged.cow_splits")
        self._c_hits = tel.counter("serve.paged.prefix_hits")
        self._c_miss = tel.counter("serve.paged.prefix_misses")
        self._c_reused = tel.counter("serve.paged.prefix_tokens_reused")
        self._c_wait = tel.counter("serve.paged.backpressure_waits")
        self._c_preempt = tel.counter("serve.paged.preemptions")
        self._c_slices = tel.counter("serve.paged.prefill_slices")
        self._c_dequant = tel.counter("quant.dequant_pages")
        self._g_pages = tel.gauge("serve.paged.pages_in_use")
        self._g_kv_phys = tel.gauge("serve.paged.kv_bytes_physical")
        self._g_kv_logical = tel.gauge("serve.paged.kv_bytes_logical")

        kv_spec = kv_dtype_spec(kv_dtype, native=model.compute_dtype)
        self.kv_dtype = kv_spec.name
        self.kv_quantized = kv_spec.quantized
        self.page_bytes = page_bytes(self.cfg, page_size, kv_spec.itemsize,
                                     kv_spec.scale_bytes_per_row)
        self.row_bytes = self.page_bytes // page_size
        if prefix_cache:
            from repro.serve.prefix import SharedKVLedger
            self.ledger = SharedKVLedger(
                num_pages, self.page_bytes, page_size,
                num_slots=num_slots,
                max_pages_per_slot=self.max_pages_per_slot,
                telemetry=tel)
        else:
            self.ledger = PagedKVLedger(num_pages, self.page_bytes)
        self.access = AccessStats()
        self.stats = PagedStats()

        self.queue = AdmissionQueue()
        self.slots: List[Optional[Request]] = [None] * num_slots
        self._reserved = [0] * num_slots        # worst-case pages not yet held
        self._ctx = np.zeros(num_slots, np.int64)
        self._next_tok = np.zeros(num_slots, np.int32)
        self._table = np.zeros((num_slots, self.max_pages_per_slot), np.int32)
        self._sim_t = 0.0

        self._cache = init_paged_cache(
            self.cfg, num_slots, num_pages, page_size,
            self.max_pages_per_slot, dtype=model.compute_dtype,
            kv_dtype=self.kv_dtype)
        self._prefill = jax.jit(
            lambda p, b, L: model.prefill(p, b, cache_len=L),
            static_argnums=(2,))
        self._write = jax.jit(functools.partial(write_prefill_to_pages,
                                                self.cfg),
                              donate_argnums=(0,))
        self._loop = jax.jit(
            functools.partial(_decode_loop, model, chunk_steps, attn_backend,
                              collect_logits),
            donate_argnums=(1,))
        if prefix_cache or prefill_chunk_tokens is not None:
            from repro.models.transformer import (_require_pure_full,
                                                  gather_prefix_pages,
                                                  write_shared_prefill_to_pages)
            _require_pure_full(model.cfg, "prefix_cache" if prefix_cache
                               else "prefill_chunk_tokens")
            self._gather = jax.jit(
                functools.partial(gather_prefix_pages, self.cfg),
                static_argnums=(2,))
            # fixed attention width = slot capacity: makes the suffix
            # prefill's reduction tree independent of who computed the
            # prefix (donor-exact KV, see _apply_block_shared_prefill) —
            # the same property makes chained chunked-prefill slices
            # bit-exact vs one monolithic prefill
            pad_to = self.max_pages_per_slot * page_size
            self._prefill_shared = jax.jit(
                lambda p, t, pfx: model.prefill_shared(
                    p, {"tokens": t}, pfx, pad_to=pad_to))
            self._write_shared = jax.jit(
                functools.partial(write_shared_prefill_to_pages, self.cfg),
                donate_argnums=(0,))
        if prefix_cache:
            from repro.models.transformer import copy_pages
            self._copy = jax.jit(functools.partial(copy_pages, self.cfg),
                                 donate_argnums=(0,))

    # ------------------------------------------------------------ client API
    def submit(self, req: Request) -> None:
        S = int(len(req.tokens))
        cap = self.max_pages_per_slot * self.page_size
        if S + max(req.max_new_tokens - 1, 0) > cap \
                and self.on_long_prompt == "truncate":
            # keep the decode budget, give the prompt whatever table
            # capacity remains (mirrors the dense batcher's max_len cut)
            keep = cap - max(req.max_new_tokens - 1, 0)
            if keep >= 1:
                req.tokens = np.asarray(req.tokens)[:keep]
                S = keep
        worst = pages_for(S + max(req.max_new_tokens - 1, 0), self.page_size)
        # prefix mode reserves one extra pool page for the deferred COW
        # split of a mid-page prompt boundary; it never occupies a table
        # slot (COW swaps an entry in place), but it must fit the pool or
        # admission could wait forever on a demand no drain can satisfy
        pool_worst = worst + (1 if self.prefix_cache and S % self.page_size
                              and req.max_new_tokens > 1 else 0)
        if worst > self.max_pages_per_slot or pool_worst > self.num_pages - 1:
            raise OutOfPages(
                f"request {req.rid} needs {worst} table / {pool_worst} pool "
                f"pages; slot tables hold {self.max_pages_per_slot}, pool "
                f"holds {self.num_pages - 1}")
        req.submitted_wall_s = time.perf_counter()
        req.submitted_s = self._sim_t
        if self.tel.enabled:
            req.timeline = RequestTimeline(rid=req.rid, submit_t=self._sim_t)
        self.queue.push(req)

    def run(self, max_chunks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_chunks):
            if not self.queue and all(s is None for s in self.slots):
                break
            self._admit(done)
            self._decode_chunk(done)
        if self._slo is not None:
            self.slo_summary()           # refresh stats percentiles once
        return done

    def slo_summary(self) -> SLOSummary:
        """Percentile view of per-request TTFT / TBT / e2e on the sim clock
        (zeros when the batcher runs without an enabled registry). Quantiles
        are computed here, at read time — never inside the decode loop, so
        enabled telemetry stays off the serving hot path."""
        if self._slo is None:
            return SLOSummary()
        s = self._slo.summary()
        # bytes-based physical occupancy (page count x quantized page_bytes)
        # next to the latency percentiles — page counts alone hide the
        # footprint reduction a quantized kv_dtype buys
        s.kv_peak_bytes = float(self.ledger.trace.peak_needed())
        s.kv_mean_bytes = float(self.ledger.trace.time_weighted_mean(
            max(self._sim_t, self.step_time_s)))
        st = self.stats
        st.ttft_p50_s, st.ttft_p99_s = s.ttft_p50_s, s.ttft_p99_s
        st.tbt_p50_s, st.tbt_p99_s = s.tbt_p50_s, s.tbt_p99_s
        st.e2e_p50_s, st.e2e_p99_s = s.e2e_p50_s, s.e2e_p99_s
        return s

    def occupancy_bundle(self) -> TraceBundle:
        """Page-granular Stage-II view: feed to explorer.sweep() unchanged.

        With `prefix_cache` the bundle carries the dual traces: "kv" is the
        *physical* occupancy (unique referenced pages as needed, cached
        pages as obsolete — what Stage II should gate against) and
        "kv_logical" the per-slot demand sum a non-sharing allocator would
        pin; their gap is the headroom sharing unlocked."""
        traces = {"kv": self.ledger.trace}
        name = f"{self.cfg.name}-paged-serve"
        if self.prefix_cache:
            traces["kv_logical"] = self.ledger.logical
            name = f"{self.cfg.name}-prefix-serve"
        return TraceBundle(graph_name=name,
                           total_time=max(self._sim_t, self.step_time_s),
                           traces=traces, access=self.access)

    # ------------------------------------------------------------- internals
    def _available_pages(self) -> int:
        return self.ledger.allocator.n_free - sum(self._reserved)

    def _set_page_gauges(self) -> None:
        """Page-count plus bytes-based occupancy gauges: physical = pool
        pages held x page_bytes (quantization shrinks page_bytes), logical =
        the per-slot demand a non-sharing allocator would pin."""
        n = self.ledger.allocator.n_allocated
        self._g_pages.set(n)
        self._g_kv_phys.set(n * self.page_bytes)
        self._g_kv_logical.set(self.ledger.logical_bytes())

    def _retire(self, i: int, req: Request, done: List[Request],
                t: float) -> None:
        req.finished_wall_s = time.perf_counter()
        req.finished_s = t
        done.append(req)
        self.slots[i] = None
        n = self.ledger.retire(i, t)
        self.stats.pages_freed += n
        self.stats.retired_kv_bytes += n * self.page_bytes
        self.stats.finished += 1
        self._reserved[i] = 0
        self._ctx[i] = 0
        self._table[i, :] = 0
        self._c_retired.inc()
        self._c_freed.inc(n)
        self._set_page_gauges()
        tl = req.timeline
        if tl is not None and self._slo is not None:
            tl.finish_t = t
            self._slo.observe(tl)
            self.tel.add_span("request", tl.submit_t, t, rid=req.rid,
                              tokens=len(req.output))
            if np.isfinite(tl.first_token_t) and t > tl.first_token_t:
                self.tel.add_span("decode", tl.first_token_t, t, slot=i,
                                  rid=req.rid)

    def _preempt_victim(self, priority: int) -> Optional[int]:
        """Pick the slot to evict for a `priority`-class admission: the
        lowest-priority active slot strictly below the admitting class
        (equal classes never preempt each other — no livelock), least
        decode progress first within a class (least work discarded)."""
        best = None
        best_key = None
        for i, r in enumerate(self.slots):
            if r is None or r.priority >= priority:
                continue
            key = (r.priority, len(r.output))
            if best is None or key < best_key:
                best, best_key = i, key
        return best

    def _preempt(self, i: int, t: float) -> None:
        """Evict slot `i` and requeue its request. Pages return through the
        ordinary retire path (the occupancy trace stays conservative); the
        partial output is discarded and the prompt re-prefills from scratch
        on re-admission — resuming mid-decode would not be bit-exact (the
        prefill reduction tree differs from the decode kernel's), while a
        greedy restart reproduces the uncontended tokens exactly."""
        req = self.slots[i]
        req.output.clear()
        req.logits.clear()
        req.preemptions += 1
        self.slots[i] = None
        n = self.ledger.retire(i, t)
        self.stats.pages_freed += n
        self.stats.retired_kv_bytes += n * self.page_bytes
        self.stats.preemptions += 1
        self._reserved[i] = 0
        self._ctx[i] = 0
        self._table[i, :] = 0
        self._c_preempt.inc()
        self._c_freed.inc(n)
        self._set_page_gauges()
        if req.timeline is not None:
            req.timeline.reset_admission()
        if self.tel.enabled:
            self.tel.add_span("preempt", t, t, slot=i, rid=req.rid)
        self.queue.push(req)     # fresh seq: re-enters behind its own class

    def _preempt_for(self, priority: int, worst: int) -> bool:
        """Free pages for a `priority`-class admission by preempting
        strictly-lower-priority slots, lowest class / least progress first.
        Returns False when eligible victims run out before `worst` pages
        are coverable (the head then backpressure-waits as before)."""
        while worst > self._available_pages():
            v = self._preempt_victim(priority)
            if v is None:
                return False
            self._preempt(v, self._sim_t)
        return True

    def _admit(self, done: List[Request]) -> None:
        while self.queue:
            i = next((k for k, s in enumerate(self.slots) if s is None), None)
            if i is None:
                # every slot is busy: a strictly-higher-priority head may
                # evict the lowest-priority slot instead of queueing
                v = self._preempt_victim(self.queue.peek().priority)
                if v is None:
                    break
                self._preempt(v, self._sim_t)
                continue
            if self.prefix_cache:
                if not self._admit_prefix(i, done):
                    break                  # wait for pages to free up
                continue
            req = self.queue.peek()
            prompt_len = int(len(req.tokens))
            worst = pages_for(prompt_len + max(req.max_new_tokens - 1, 0),
                              self.page_size)
            if worst > self._available_pages() \
                    and not self._preempt_for(req.priority, worst):
                self._c_wait.inc()
                break                      # wait for pages to free up
            self.queue.pop()
            if (self.prefill_chunk_tokens is not None
                    and prompt_len > self.prefill_chunk_tokens):
                self._admit_chunked(i, req, done, worst)
                continue
            npg = pages_for(prompt_len, self.page_size)
            t_pre = self._sim_t

            batch = {"tokens": jnp.asarray(np.asarray(req.tokens)[None, :],
                                           jnp.int32)}
            logits, dense = self._prefill(self.params, batch,
                                          npg * self.page_size)
            tok = int(jnp.argmax(logits[0, -1]))
            self._sim_t += prompt_len * self.prefill_tok_s
            pages = self.ledger.admit(i, npg, self._sim_t)
            self._reserved[i] = worst - npg
            self.stats.pages_allocated += npg
            self.stats.peak_pages = max(self.stats.peak_pages,
                                        self.ledger.allocator.n_allocated)
            self.stats.admitted_kv_bytes += npg * self.page_bytes
            self.access.add_write("kv", prompt_len * self.row_bytes)
            self._c_alloc.inc(npg)

            self._cache = self._write(self._cache, dense, i,
                                      jnp.asarray(pages, jnp.int32))
            self._commit_admission(i, req, done, tok, logits, prompt_len,
                                   pages, t_pre)

    def _admit_chunked(self, i: int, req: Request, done: List[Request],
                       worst: int) -> None:
        """Chunked prefill: admit `req` into slot `i` in page-aligned
        slices of `prefill_chunk_tokens`, running one decode chunk for the
        other active slots between consecutive slices so a long prompt no
        longer stalls their token cadence. Slice 0 is a plain prefill;
        every later slice gathers the slot's own pages as a prefix and runs
        the suffix-only shared prefill at fixed attention width — the
        donor-exact property from prefix sharing, so the emitted tokens are
        bit-identical to one monolithic prefill. The slot stays invisible
        to the decode loop (host `active` mask) until the last slice
        commits; the page reservation made up-front keeps interleaved
        chunks from stealing this slot's worst-case pages.

        Tracing: each distinct (resident rows, slice length) pair traces
        once — every slice but the last is exactly `prefill_chunk_tokens`
        long, so long prompts bucket naturally."""
        prompt = np.asarray(req.tokens)
        S = int(len(prompt))
        ps = self.page_size
        C = self.prefill_chunk_tokens
        t_pre = self._sim_t
        pos = 0
        logits = None
        while pos < S:
            take = min(C, S - pos)
            sl = jnp.asarray(prompt[None, pos:pos + take], jnp.int32)
            t0 = self._sim_t
            if pos == 0:
                new_n = pages_for(take, ps)
                logits, dense = self._prefill(self.params, {"tokens": sl},
                                              new_n * ps)
                self._sim_t += take * self.prefill_tok_s
                pages = self.ledger.admit(i, new_n, self._sim_t)
                self._reserved[i] = worst - new_n
                self._cache = self._write(self._cache, dense, i,
                                          jnp.asarray(pages, jnp.int32))
            else:
                held = list(self.ledger.slot_pages[i])
                prefix = self._gather(self._cache,
                                      jnp.asarray(held, jnp.int32), pos)
                if self.kv_quantized:
                    self._c_dequant.inc(len(held))
                head = prefix_tail_rows(prefix, 0)   # pos is page-aligned
                logits, suffix = self._prefill_shared(self.params, sl, prefix)
                self._sim_t += take * self.prefill_tok_s
                fresh = self.ledger.grow(i, pages_for(pos + take, ps),
                                         self._sim_t)
                self._reserved[i] -= len(fresh)
                new_n = len(fresh)
                self._cache = self._write_shared(
                    self._cache, suffix, head, jnp.int32(i),
                    jnp.asarray(held, jnp.int32),
                    jnp.asarray(fresh, jnp.int32))
            self.stats.pages_allocated += new_n
            self.stats.admitted_kv_bytes += new_n * self.page_bytes
            self.stats.peak_pages = max(self.stats.peak_pages,
                                        self.ledger.allocator.n_allocated)
            self.stats.prefill_slices += 1
            self.access.add_write("kv", take * self.row_bytes)
            self._c_alloc.inc(new_n)
            self._c_slices.inc()
            if self.tel.enabled:
                self.tel.add_span("prefill_slice", t0, self._sim_t, slot=i,
                                  rid=req.rid, tokens=take)
            pos += take
            if pos < S:
                # let the active slots stream tokens before the next slice
                self._decode_chunk(done)
        tok = int(jnp.argmax(logits[0, -1]))
        self._commit_admission(i, req, done, tok, logits, S,
                               self.ledger.slot_pages[i], t_pre)

    def _commit_admission(self, i: int, req: Request, done: List[Request],
                          tok: int, logits, ctx: int,
                          table_pages: List[int], t_pre: float) -> None:
        """Shared admission tail for the plain and prefix paths: host
        mirrors, stats, the prefill-produced first token, and the immediate
        retire when that token already satisfies the request. `t_pre` is
        the sim time before the prefill advance (the span start)."""
        self.slots[i] = req
        self._c_admitted.inc()
        self._c_prefills.inc()
        self._set_page_gauges()
        if self.tel.enabled:
            self.tel.add_span("prefill", t_pre, self._sim_t, slot=i,
                              rid=req.rid, tokens=ctx)
            tl = req.timeline
            if tl is not None:
                tl.admit_t = t_pre
                tl.first_token_t = self._sim_t
                tl.token_ts.append(self._sim_t)
        self._ctx[i] = ctx
        self._next_tok[i] = tok
        self._table[i, :] = 0
        self._table[i, :len(table_pages)] = table_pages
        req.output.append(tok)
        if self.collect_logits:
            req.logits.append(np.asarray(logits[0, -1]))
        self.stats.admitted += 1
        self.stats.prefills += 1
        self.stats.peak_active_slots = max(
            self.stats.peak_active_slots,
            sum(s is not None for s in self.slots))
        if (req.max_new_tokens <= 1
                or (req.eos_id is not None and tok == req.eos_id)):
            self._retire(i, req, done, self._sim_t)

    def _admit_prefix(self, i: int, done: List[Request]) -> bool:
        """Prefix-cache admission of the queue head into slot `i`.

        Returns False when the pool (after LRU-evicting cached prefixes and
        preempting strictly-lower-priority slots) still cannot cover the
        request's worst-case *fresh* page demand — the head then waits. The
        worst case reserves the pages the match did not cover, plus one
        page for the deferred COW split of a mid-page prompt boundary."""
        req = self.queue.peek()
        prompt = np.asarray(req.tokens)
        S = int(len(prompt))
        ps = self.page_size
        worst_total = pages_for(S + max(req.max_new_tokens - 1, 0), ps)
        cow_extra = 1 if (S % ps and req.max_new_tokens > 1) else 0

        def demand(match):
            return worst_total - len(match.pages) + cow_extra

        match = self.ledger.index.probe(prompt, limit=S - 1)
        short = demand(match) - self._available_pages()
        while short > 0:
            freed = self.ledger.evict_for(short, self._sim_t)
            if freed:
                self.stats.evicted_pages += freed
                self._c_evicted.inc(freed)
            else:
                # nothing cached left to drop: preempt a lower-priority
                # slot before giving up (pages free via the retire path)
                v = self._preempt_victim(req.priority)
                if v is None:
                    self._c_wait.inc()
                    return False
                self._preempt(v, self._sim_t)
            # eviction/preemption may have changed the matched path: re-probe
            match = self.ledger.index.probe(prompt, limit=S - 1)
            short = demand(match) - self._available_pages()
        self.queue.pop()

        n_full, j = len(match.pages), match.tail_tokens
        m = n_full * ps + j
        npg_total = pages_for(S, ps)
        fresh_n = npg_total - n_full

        gather_ids = list(match.pages) + \
            ([match.tail_page] if j else [])
        prefix = self._gather(self._cache,
                              jnp.asarray(gather_ids, jnp.int32), m)
        if self.kv_quantized and gather_ids:
            self._c_dequant.inc(len(gather_ids))
        head = prefix_tail_rows(prefix, j)
        logits, suffix = self._prefill_shared(
            self.params, jnp.asarray(prompt[None, m:], jnp.int32), prefix)
        tok = int(jnp.argmax(logits[0, -1]))
        t_pre = self._sim_t
        self._sim_t += (S - m) * self.prefill_tok_s   # prefill skip: suffix only

        fresh = self.ledger.admit(i, fresh_n, self._sim_t,
                                  shared=match.pages)
        self._reserved[i] = demand(match) - fresh_n
        self.stats.pages_allocated += fresh_n
        self.stats.peak_pages = max(self.stats.peak_pages,
                                    self.ledger.allocator.n_allocated)
        self.stats.admitted_kv_bytes += fresh_n * self.page_bytes
        self.access.add_write("kv", (S - m) * self.row_bytes)
        self._c_alloc.inc(fresh_n)
        if m:
            self.stats.prefix_hits += 1
            self.stats.prefix_tokens_reused += m
            self._c_hits.inc()
            self._c_reused.inc(m)
        else:
            self._c_miss.inc()

        self._cache = self._write_shared(
            self._cache, suffix, head, jnp.int32(i),
            jnp.asarray(match.pages, jnp.int32),
            jnp.asarray(fresh, jnp.int32))
        # cache this run for later requests (index refs its pages)
        self.ledger.insert_run(prompt, self.ledger.slot_pages[i], self._sim_t)
        self._commit_admission(i, req, done, tok, logits, S,
                               self.ledger.slot_pages[i], t_pre)
        return True

    def _cow_for_chunk(self, i: int, steps_i: int, t: float) -> None:
        """Copy-on-write split every shared page this chunk will write.

        Decode appends rows [ctx, ctx + steps_i); only the page holding the
        prompt's mid-page boundary can be shared (with the prefix index, or
        with slots that mapped the same run), so at most one split fires per
        slot — but the scan is range-exact regardless. The reservation made
        at admission covers the extra page, so `alloc` cannot fail."""
        ps = self.page_size
        ctx = int(self._ctx[i])
        pages = self.ledger.slot_pages[i]
        first, last = ctx // ps, (ctx + steps_i - 1) // ps
        for idx in range(first, min(last + 1, len(pages))):
            page = pages[idx]
            if self.ledger.allocator.refcount(page) <= 1:
                continue
            new = self.ledger.cow(i, idx, t)
            self._cache = self._copy(self._cache, jnp.int32(page),
                                     jnp.int32(new))
            self._table[i, idx] = new
            self._reserved[i] -= 1
            self.stats.cow_splits += 1
            self.stats.pages_allocated += 1
            self._c_cow.inc()
            self._c_alloc.inc()
            self.tel.add_span("cow", t, t, slot=i, page=new)

    def _decode_chunk(self, done: List[Request]) -> None:
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return
        t0 = self._sim_t
        # grow page tables to cover this chunk's worst case (reservation at
        # admission guarantees these allocations succeed)
        remaining = np.zeros(self.num_slots, np.int32)
        for i in live:
            req = self.slots[i]
            remaining[i] = req.max_new_tokens - len(req.output)
            steps_i = min(self.chunk_steps, int(remaining[i]))
            new_pages = self.ledger.grow(
                i, pages_for(int(self._ctx[i]) + steps_i, self.page_size), t0)
            if new_pages:
                npg_have = len(self.ledger.slot_pages[i])
                self._table[i, npg_have - len(new_pages):npg_have] = new_pages
                self._reserved[i] -= len(new_pages)
                self.stats.pages_allocated += len(new_pages)
                self.stats.admitted_kv_bytes += len(new_pages) * self.page_bytes
                self._c_alloc.inc(len(new_pages))
            if self.prefix_cache:
                self._cow_for_chunk(i, steps_i, t0)
        self.stats.peak_pages = max(self.stats.peak_pages,
                                    self.ledger.allocator.n_allocated)

        cache = self._cache
        # host is the source of truth between chunks: push the page-table
        # mirror and the liveness mask (covers slots retired host-side at
        # admission, whose device `active` flag was never flipped in-scan)
        cache["page_table"] = jnp.asarray(self._table)
        cache["active"] = jnp.asarray(
            [s is not None for s in self.slots])
        emitted, cache, tok, _ = self._loop(
            self.params, cache, jnp.asarray(self._next_tok[:, None]),
            jnp.asarray([(self.slots[i].eos_id if self.slots[i] is not None
                          and self.slots[i].eos_id is not None else -1)
                         for i in range(self.num_slots)], jnp.int32),
            jnp.asarray(remaining))
        self._cache = cache
        self.stats.chunks += 1
        step_logits = None
        if self.collect_logits:
            emitted, step_logits = emitted
            step_logits = np.asarray(step_logits)  # (steps, num_slots, V)
        emitted = np.asarray(emitted)                    # (steps, num_slots)
        self._next_tok = np.array(tok[:, 0])
        still_active = np.array(cache["active"])
        self._sim_t = t0 + self.chunk_steps * self.step_time_s
        self._c_chunks.inc()
        self.tel.add_span("decode_chunk", t0, self._sim_t, slots=len(live))

        for i in live:
            req = self.slots[i]
            col = emitted[:, i]
            neg = np.nonzero(col < 0)[0]
            g = int(neg[0]) if len(neg) else len(col)
            req.output.extend(int(t) for t in col[:g])
            if step_logits is not None:
                req.logits.extend(step_logits[:g, i])
            self.stats.decode_steps += g
            # page-granular access accounting: each step streams the resident
            # pages and appends one row
            ctxs = int(self._ctx[i]) + 1 + np.arange(g)
            pages_read = int((np.ceil(ctxs / self.page_size)).sum())
            self.access.add_read("kv", pages_read * self.page_bytes)
            self.access.add_write("kv", g * self.row_bytes)
            if self.kv_quantized and pages_read:
                # every page the fused kernel streams is dequantized
                # in-register
                self._c_dequant.inc(pages_read)
            self._c_steps.inc(g)
            if req.timeline is not None and g:
                req.timeline.token_ts.extend(
                    (t0 + self.step_time_s * np.arange(1, g + 1)).tolist())
            self._ctx[i] += g
            if not still_active[i]:
                self._retire(i, req, done, t0 + g * self.step_time_s)


def loop_compile_count() -> int:
    """How many times the chunk decode loop has been traced/compiled
    process-wide (tests assert it does not grow across chunks) —
    compatibility shim over the `serve.paged.loop_compiles` registry
    counter (the old module-global it replaced)."""
    return int(_COMPILES.value)
