from repro.serve.engine import BatchedServer, ServeConfig, ServeStats  # noqa: F401
from repro.serve.paged import (OutOfPages, PageAllocator,  # noqa: F401
                               PagedContinuousBatcher, PagedKVLedger,
                               page_bytes, pages_for)
from repro.serve.prefix import (PrefixMatch, RadixPrefixIndex,  # noqa: F401
                                SharedKVLedger, SharedPageAllocator)
from repro.serve.scheduler import (AdmissionQueue, ContinuousBatcher,  # noqa: F401
                                   Request, kv_slot_budget)
