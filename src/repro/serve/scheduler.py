"""Continuous batching: a slot-based request scheduler over the decode engine.

Production serving doesn't run fixed batches — requests arrive and finish at
different times. The scheduler keeps a fixed pool of `num_slots` sequence
slots (one compiled decode_step serves every configuration), admits queued
requests into free slots by prefilling into that slot's cache region, and
retires slots on EOS/length. This is the TPU-serving face of the paper's
observation: per-slot KV occupancy is what bounds concurrency, and GQA
multiplies the slot count a given memory budget supports.

Implementation notes:
  * the KV cache is batched over slots; an admission writes the prefilled
    prompt cache into slot i via a jitted scatter;
  * per-slot position counters live in the cache's `pos`... since our model
    cache keeps one scalar `pos`, slots carry per-slot lengths here and the
    decode mask uses the max; correctness for ragged slots is maintained by
    masking logits of inactive slots and re-prefilling on admission;
  * simple FCFS admission; slots freed on EOS or max_new_tokens.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # (prompt_len,)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the scheduler
    output: List[int] = field(default_factory=list)
    submitted_s: float = 0.0
    finished_s: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.submitted_s


@dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0
    prefills: int = 0
    peak_active_slots: int = 0


class ContinuousBatcher:
    """FCFS continuous batching over `num_slots` decode slots."""

    def __init__(self, model, params, *, num_slots: int = 4,
                 max_len: int = 128):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.queue: "collections.deque[Request]" = collections.deque()
        self.slots: List[Optional[Request]] = [None] * num_slots
        self.slot_pos: np.ndarray = np.zeros(num_slots, np.int64)
        self.stats = SchedulerStats()

        # one compiled decode step for the whole pool
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=max_len))
        # per-slot caches kept as a list of single-sequence caches (batch=1):
        # a production engine would keep one batched cache + scatter; batch=1
        # caches keep this reference implementation simple and exact.
        self._caches: List[Any] = [None] * num_slots
        self._next_tok: List[Optional[int]] = [None] * num_slots

    # ------------------------------------------------------------ client API
    def submit(self, req: Request) -> None:
        req.submitted_s = time.perf_counter()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self._admit()
            self._step(done)
        return done

    # ------------------------------------------------------------- internals
    def _admit(self) -> None:
        for i in range(self.num_slots):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            batch = {"tokens": jnp.asarray(req.tokens[None, :], jnp.int32)}
            logits, cache = self._prefill(self.params, batch)
            tok = int(jnp.argmax(logits[0, -1]))
            self.slots[i] = req
            self._caches[i] = cache
            self._next_tok[i] = tok
            req.output.append(tok)
            self.stats.admitted += 1
            self.stats.prefills += 1
        self.stats.peak_active_slots = max(
            self.stats.peak_active_slots,
            sum(s is not None for s in self.slots))

    def _step(self, done: List[Request]) -> None:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        for i in active:
            req = self.slots[i]
            tok = jnp.asarray([[self._next_tok[i]]], jnp.int32)
            logits, self._caches[i] = self._decode(self.params,
                                                   self._caches[i], tok)
            nxt = int(jnp.argmax(logits[0, -1]))
            req.output.append(nxt)
            self._next_tok[i] = nxt
            self.stats.decode_steps += 1
            hit_eos = req.eos_id is not None and nxt == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.finished_s = time.perf_counter()
                done.append(req)
                self.slots[i] = None
                self._caches[i] = None
                self._next_tok[i] = None
                self.stats.finished += 1


def kv_slot_budget(cfg, hbm_bytes: float, max_len: int,
                   weight_dtype_bytes: int = 2,
                   kv_dtype_bytes: int = 2) -> int:
    """How many concurrent sequences fit a given HBM budget — the serving
    reading of the paper's KV-occupancy analysis. GQA divides the per-slot
    bytes by H/K vs MHA."""
    weights = cfg.param_count() * weight_dtype_bytes
    per_slot = 0
    for kind in cfg.layer_kinds():
        if kind in ("full",):
            per_slot += 2 * max_len * cfg.kv_dim * kv_dtype_bytes
        elif kind in ("local", "chunked") and cfg.local_window:
            per_slot += 2 * min(cfg.local_window, max_len) * cfg.kv_dim \
                * kv_dtype_bytes
    if cfg.ssm is not None:
        s = cfg.ssm
        per_slot += (s.num_heads(cfg.d_model) * s.head_dim * s.state_dim * 4
                     * cfg.num_layers)
    if per_slot == 0:
        return 10**9
    return max(0, int((hbm_bytes - weights) // per_slot))
