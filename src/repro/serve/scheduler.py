"""Continuous batching: a slot-based request scheduler over the decode engine.

Production serving doesn't run fixed batches — requests arrive and finish at
different times. The scheduler keeps a fixed pool of `num_slots` sequence
slots (one compiled decode_step serves every configuration), admits queued
requests into free slots by prefilling into that slot's cache region, and
retires slots on EOS/length. This is the TPU-serving face of the paper's
observation: per-slot KV occupancy is what bounds concurrency, and GQA
multiplies the slot count a given memory budget supports.

Implementation notes:
  * this is the dense *reference* batcher: each slot holds its own batch=1
    `max_len` cache and decodes one token per host round-trip — exact but
    host-bound. The production path is `serve.paged.PagedContinuousBatcher`,
    which keeps one batched paged cache with true per-slot positions (the
    old max-slot-length decode mask is gone: every slot embeds, ropes and
    attends at exactly its own context length) and runs multi-token chunks
    as a single donated `lax.scan` on device;
  * priority admission (FIFO within a class) via `AdmissionQueue`; slots
    freed on EOS or max_new_tokens. Preemption lives only on the paged
    batcher, where freeing a slot actually returns pages.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.slo import (RequestTimeline, SLOSummary, SLOTracker,
                           attach_energy_percentiles)
from repro.obs.telemetry import noop_registry
from repro.sim.trace import AccessStats, OccupancyTrace, TraceBundle


# ---------------------------------------------------------------------------
# KV-cache geometry (shared by the batcher's trace emission and the analytic
# traffic simulator in repro.traffic.occupancy)
# ---------------------------------------------------------------------------

def slot_state_bytes(cfg) -> int:
    """Sequence-length-independent per-slot state (SSM + RG-LRU blocks)."""
    total = 0
    kinds = cfg.layer_kinds()
    if cfg.ssm is not None:
        s = cfg.ssm
        n_ssm = sum(1 for k in kinds if k == "ssm")
        total += s.num_heads(cfg.d_model) * s.head_dim * s.state_dim * 4 * n_ssm
    if cfg.rglru is not None:
        r = cfg.rglru
        w = r.lru_width(cfg.d_model)
        n_rg = sum(1 for k in kinds if k == "rglru")
        # fp32 recurrent state + the causal-conv tail window (fp16)
        total += n_rg * (w * 4 + r.conv_width * w * 2)
    return total


def kv_bytes_at(cfg, pos: int, kv_dtype_bytes: int = 2) -> int:
    """KV-cache bytes held by ONE sequence at context length `pos`.

    Full-attention layers grow linearly; sliding-window layers saturate at
    `local_window` tokens; SSM/RG-LRU blocks contribute nothing here (their
    fixed state is `slot_state_bytes`). This is the per-request curve the
    paper's time-resolved occupancy analysis composes over a batch."""
    per_full = 0
    per_local = 0
    for kind in cfg.layer_kinds():
        if kind == "full":
            per_full += 1
        elif kind in ("local", "chunked") and cfg.local_window:
            per_local += 1
    row = 2 * cfg.kv_dim * kv_dtype_bytes            # K + V for one token
    total = per_full * pos * row
    if per_local:
        total += per_local * min(cfg.local_window, pos) * row
    return total


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # (prompt_len,)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # admission class: higher admits first; on the paged batcher a queued
    # higher-priority request may preempt a lower-priority active slot
    # instead of backpressure-waiting (ties decode FCFS)
    priority: int = 0
    # billing identity for per-tenant energy attribution (None = untagged)
    tenant: Optional[str] = None
    # filled by the scheduler
    output: List[int] = field(default_factory=list)
    # per-token last-position logits, filled only by engines running with
    # collect_logits=True (the bit-identity regressions compare these)
    logits: List[np.ndarray] = field(default_factory=list)
    # lifecycle stamps on BOTH clocks: `submitted_s`/`finished_s` are on the
    # engine's *logical sim clock* — the time base of the occupancy trace
    # and the SLO percentiles, so `latency_s` agrees with the reported e2e
    # distribution; `*_wall_s` are time.perf_counter stamps for host-side
    # profiling (jit/compile/dispatch overhead included)
    submitted_s: float = 0.0
    finished_s: float = 0.0
    submitted_wall_s: float = 0.0
    finished_wall_s: float = 0.0
    # times this request was preempted-and-requeued (paged batcher only)
    preemptions: int = 0
    # lifecycle on the engine's logical clock, stamped when the engine runs
    # with an enabled Telemetry registry (None otherwise)
    timeline: Optional[RequestTimeline] = None

    @property
    def latency_s(self) -> float:
        """Submit-to-finish on the engine's logical sim clock (matches the
        e2e SLO percentiles; wall time is `wall_latency_s`)."""
        return self.finished_s - self.submitted_s

    @property
    def wall_latency_s(self) -> float:
        return self.finished_wall_s - self.submitted_wall_s


class AdmissionQueue:
    """Priority admission queue shared by both batchers.

    Orders by descending `Request.priority`, FIFO within a class; a request
    requeued after preemption re-enters at the *back* of its class (a fresh
    sequence number), so a preempt/re-admit cycle cannot starve its peers."""

    def __init__(self):
        self._heap: List = []
        self._seq = itertools.count()

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (-req.priority, next(self._seq), req))

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Request:
        return self._heap[0][2]

    def __len__(self) -> int:
        return len(self._heap)

    def __iter__(self) -> Iterator[Request]:
        return (item[2] for item in sorted(self._heap))


@dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0
    prefills: int = 0
    preemptions: int = 0
    peak_active_slots: int = 0
    admitted_kv_bytes: int = 0
    retired_kv_bytes: int = 0
    # prefix-cache reuse (stays zero on engines without a prefix index)
    prefix_hits: int = 0
    prefix_tokens_reused: int = 0
    # per-request serving SLOs on the logical sim clock (populated when the
    # engine runs with an enabled Telemetry registry; zero otherwise)
    ttft_p50_s: float = 0.0
    ttft_p99_s: float = 0.0
    tbt_p50_s: float = 0.0
    tbt_p99_s: float = 0.0
    e2e_p50_s: float = 0.0
    e2e_p99_s: float = 0.0


class ContinuousBatcher:
    """Priority continuous batching (FIFO within a class) over `num_slots`
    dense decode slots.

    When the model carries an `ArchConfig` (`model.cfg`), the batcher also
    emits a time-resolved slot-occupancy trace: every admission, decoded
    token, and retirement becomes an `OccupancyTrace` event on a logical
    clock (`step_time_s` per decode iteration, `prefill_tok_s` per prefilled
    token), so the live serving engine produces the exact Stage-I artifact
    that `core.explorer.sweep` / `core.gating.evaluate` consume offline.
    """

    def __init__(self, model, params, *, num_slots: int = 4,
                 max_len: int = 128, kv_dtype_bytes: int = 2,
                 step_time_s: float = 1e-3, prefill_tok_s: float = 5e-5,
                 on_long_prompt: str = "reject", telemetry=None,
                 meter=None):
        if on_long_prompt not in ("reject", "truncate"):
            raise ValueError("on_long_prompt must be 'reject' or 'truncate'")
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.on_long_prompt = on_long_prompt
        # spans/SLOs record on the batcher's logical sim clock — the same
        # time base the occupancy trace uses — so a passed-in registry has
        # its clock bound here (one shared Perfetto timeline); bind_clock
        # raises if another engine already owns the registry's clock
        self.tel = telemetry if telemetry is not None else noop_registry()
        if telemetry is not None:
            telemetry.bind_clock(lambda: self._sim_t, owner=self)
        self._slo = (SLOTracker(self.tel, "serve.dense")
                     if self.tel.enabled else None)
        self.queue = AdmissionQueue()
        self.slots: List[Optional[Request]] = [None] * num_slots
        self._tokens_by_rid: Dict[int, int] = {}   # retired, for J/token
        self.slot_pos: np.ndarray = np.zeros(num_slots, np.int64)
        self.stats = SchedulerStats()

        # one compiled decode step for the whole pool
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=max_len))
        # per-slot caches kept as a list of single-sequence caches (batch=1):
        # a production engine would keep one batched cache + scatter; batch=1
        # caches keep this reference implementation simple and exact.
        self._caches: List[Any] = [None] * num_slots
        self._next_tok: List[Optional[int]] = [None] * num_slots

        # ---- slot-occupancy trace (logical clock) -------------------------
        self.cfg = getattr(model, "cfg", None)
        self.kv_dtype_bytes = kv_dtype_bytes
        self.step_time_s = step_time_s
        self.prefill_tok_s = prefill_tok_s
        self._sim_t = 0.0
        self._slot_bytes = [0] * num_slots           # resident KV per slot
        self._slot_ctx = [0] * num_slots             # context length per slot
        cap = 0
        if self.cfg is not None:
            cap = num_slots * (kv_bytes_at(self.cfg, max_len, kv_dtype_bytes)
                               + slot_state_bytes(self.cfg))
        self.trace = OccupancyTrace("kv", cap)
        # optional streaming BankEnergyMeter: every trace delta below is
        # mirrored to it with the owning request/tenant tag
        self.meter = meter
        self.access = AccessStats()

    # ------------------------------------------------------------ client API
    def submit(self, req: Request) -> None:
        S = int(len(req.tokens))
        if S > self.max_len:
            if self.on_long_prompt == "truncate":
                req.tokens = np.asarray(req.tokens)[: self.max_len]
            else:
                raise ValueError(
                    f"prompt of {S} tokens exceeds max_len={self.max_len}; "
                    "truncate it or construct the batcher with "
                    "on_long_prompt='truncate'")
        req.submitted_wall_s = time.perf_counter()
        req.submitted_s = self._sim_t
        if self.tel.enabled:
            req.timeline = RequestTimeline(rid=req.rid, submit_t=self._sim_t)
        self.queue.push(req)

    def slo_summary(self) -> SLOSummary:
        """TTFT / time-between-tokens / e2e percentiles of retired requests
        (empty unless constructed with an enabled Telemetry). Quantiles are
        computed at read time, never per retire."""
        if self._slo is None:
            return SLOSummary()
        s = self._slo.summary()
        st = self.stats
        st.ttft_p50_s, st.ttft_p99_s = s.ttft_p50_s, s.ttft_p99_s
        st.tbt_p50_s, st.tbt_p99_s = s.tbt_p50_s, s.tbt_p99_s
        st.e2e_p50_s, st.e2e_p99_s = s.e2e_p50_s, s.e2e_p99_s
        if self.meter is not None:
            attach_energy_percentiles(s, self.meter.request_energy_j(),
                                      self._tokens_by_rid)
        return s

    def run(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self._admit(done)
            self._step(done)
        if self._slo is not None:
            self.slo_summary()           # refresh stats percentiles once
        return done

    def occupancy_bundle(self) -> TraceBundle:
        """The Stage-II view of this serving run: feed to explorer.sweep()."""
        if self.cfg is None:
            raise ValueError("model carries no ArchConfig; no trace emitted")
        return TraceBundle(graph_name=f"{self.cfg.name}-serve",
                           total_time=max(self._sim_t, self.step_time_s),
                           traces={"kv": self.trace}, access=self.access)

    # ------------------------------------------------------------- internals
    def _retire(self, i: int, req: Request, done: List[Request]) -> None:
        req.finished_wall_s = time.perf_counter()
        req.finished_s = self._sim_t
        done.append(req)
        self.slots[i] = None
        self._caches[i] = None
        self._next_tok[i] = None
        self.stats.finished += 1
        if self._slot_bytes[i]:
            self.trace.event(self._sim_t, -self._slot_bytes[i], 0)
            if self.meter is not None:
                self.meter.record(self._sim_t, -self._slot_bytes[i], 0,
                                  rid=req.rid, tenant=req.tenant)
            self.stats.retired_kv_bytes += self._slot_bytes[i]
        self._slot_bytes[i] = 0
        self._slot_ctx[i] = 0
        if self.meter is not None:
            self._tokens_by_rid[req.rid] = len(req.output)
            if req.timeline is not None:
                req.timeline.energy_j = self.meter.request_energy_live(
                    req.rid)
        if self.tel.enabled:
            self.tel.counter("serve.dense.retired").inc()
            tl = req.timeline
            if tl is not None:
                tl.finish_t = self._sim_t
                self._slo.observe(tl)
                self.tel.add_span("request", tl.submit_t, self._sim_t,
                                  rid=req.rid, tokens=len(req.output))

    def _admit(self, done: List[Request]) -> None:
        for i in range(self.num_slots):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.pop()
            t_pre = self._sim_t
            batch = {"tokens": jnp.asarray(req.tokens[None, :], jnp.int32)}
            logits, cache = self._prefill(self.params, batch)
            tok = int(jnp.argmax(logits[0, -1]))
            self.slots[i] = req
            self._caches[i] = cache
            self._next_tok[i] = tok
            req.output.append(tok)
            self.stats.admitted += 1
            self.stats.prefills += 1
            self.stats.peak_active_slots = max(
                self.stats.peak_active_slots,
                sum(s is not None for s in self.slots))
            # trace: the prefill writes the whole prompt's KV into the slot
            # (submit() guarantees len(tokens) <= max_len, so the trace and
            # the jitted compute see the same context)
            ctx = int(len(req.tokens))
            self._sim_t += ctx * self.prefill_tok_s
            if self.cfg is not None:
                b = (kv_bytes_at(self.cfg, ctx, self.kv_dtype_bytes)
                     + slot_state_bytes(self.cfg))
                self._slot_bytes[i] = b
                self._slot_ctx[i] = ctx
                self.trace.event(self._sim_t, b, 0)
                if self.meter is not None:
                    self.meter.record(self._sim_t, b, 0, rid=req.rid,
                                      tenant=req.tenant, cause="admission")
                self.access.add_write("kv", b)
                self.stats.admitted_kv_bytes += b
            if self.tel.enabled:
                self.tel.counter("serve.dense.admitted").inc()
                self.tel.counter("serve.dense.prefills").inc()
                self.tel.add_span("prefill", t_pre, self._sim_t,
                                  slot=i, rid=req.rid, tokens=ctx)
                tl = req.timeline
                if tl is not None:
                    tl.admit_t = t_pre
                    tl.first_token_t = self._sim_t
                    tl.token_ts.append(self._sim_t)
            # the prefill already produced the first new token: retire now if
            # it satisfies the request (counts against max_new_tokens / EOS)
            if (req.max_new_tokens <= 1
                    or (req.eos_id is not None and tok == req.eos_id)):
                self._retire(i, req, done)

    def _step(self, done: List[Request]) -> None:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        self._sim_t += self.step_time_s
        for i in active:
            req = self.slots[i]
            tok = jnp.asarray([[self._next_tok[i]]], jnp.int32)
            logits, self._caches[i] = self._decode(self.params,
                                                   self._caches[i], tok)
            nxt = int(jnp.argmax(logits[0, -1]))
            req.output.append(nxt)
            self._next_tok[i] = nxt
            self.stats.decode_steps += 1
            if self.tel.enabled:
                self.tel.counter("serve.dense.decode_steps").inc()
                if req.timeline is not None:
                    req.timeline.token_ts.append(self._sim_t)
            if self.cfg is not None:
                # attention reads the whole resident KV, then appends one row
                # (the bounded cache stops growing at max_len)
                ctx = self._slot_ctx[i]
                self.access.add_read("kv", self._slot_bytes[i])
                nxt_ctx = min(ctx + 1, self.max_len)
                d = (kv_bytes_at(self.cfg, nxt_ctx, self.kv_dtype_bytes)
                     - kv_bytes_at(self.cfg, ctx, self.kv_dtype_bytes))
                self._slot_ctx[i] = nxt_ctx
                if d:
                    self._slot_bytes[i] += d
                    self.trace.event(self._sim_t, d, 0)
                    if self.meter is not None:
                        self.meter.record(self._sim_t, d, 0, rid=req.rid,
                                          tenant=req.tenant,
                                          cause="decode_growth")
                    self.access.add_write("kv", d)
                    self.stats.admitted_kv_bytes += d
            hit_eos = req.eos_id is not None and nxt == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens:
                self._retire(i, req, done)


def kv_slot_budget(cfg, hbm_bytes: float, max_len: int,
                   weight_dtype_bytes: int = 2,
                   kv_dtype_bytes: int = 2) -> Optional[int]:
    """How many concurrent sequences fit a given HBM budget — the serving
    reading of the paper's KV-occupancy analysis. GQA divides the per-slot
    bytes by H/K vs MHA.

    Returns ``None`` when the architecture holds no per-sequence state at all
    (stateless w.r.t. context): concurrency is then unbounded by memory."""
    weights = cfg.param_count() * weight_dtype_bytes
    per_slot = kv_bytes_at(cfg, max_len, kv_dtype_bytes) + slot_state_bytes(cfg)
    if per_slot == 0:
        return None
    return max(0, int((hbm_bytes - weights) // per_slot))
