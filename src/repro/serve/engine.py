"""Batched serving engine: prefill + device-resident decode loop.

The decode path is exactly what the decode_32k / long_500k dry-run cells
lower; on CPU the examples run it with reduced configs. KV caches are
preallocated to `max_len` (static shapes — one compiled decode loop serves
every position). Decoding runs as a single jitted `jax.lax.scan` over steps
with the cache pytree donated: no per-token Python dispatch, no per-token
host sync, and the cache is updated in place buffer-wise.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.telemetry import default_registry, noop_registry


@dataclass
class ServeConfig:
    max_len: int = 256
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    seed: int = 0


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_generated: int = 0
    # serving SLOs of the lockstep batch: every sequence sees its first
    # token at prefill end and one token per scan step after that
    ttft_s: float = 0.0
    tbt_s: float = 0.0

    @property
    def decode_tokens_per_s(self) -> float:
        return self.tokens_generated / self.decode_s if self.decode_s else 0.0


def _sample(temperature: float, logits: jax.Array, rng: jax.Array) -> jax.Array:
    logits = logits[:, -1, :]
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        rng, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


# traced once per XLA compilation — tests assert repeated generate() calls
# with stable shapes never re-trace the decode loop; the count lives on the
# process-wide telemetry registry (loop_compile_count() is the shim view)
_COMPILES = default_registry().counter("serve.engine.loop_compiles")


def _generate_loop(model, temperature: float, collect_logits: bool,
                   steps: int, params, cache, tok, rng):
    """`steps` greedy/sampled decode steps as one on-device scan.

    Returns the emitted tokens (steps, B) — plus each step's last-position
    logits (steps, B, V) when `collect_logits` — the donated cache is
    consumed."""
    _COMPILES.inc()

    def step(carry, _):
        cache, tok, rng = carry
        logits, cache = model.decode_step(params, cache, tok)
        rng, k = jax.random.split(rng)
        tok = _sample(temperature, logits, k)
        out = (tok[:, 0], logits[:, -1, :]) if collect_logits else tok[:, 0]
        return (cache, tok, rng), out

    (cache, tok, rng), toks = jax.lax.scan(
        step, (cache, tok, rng), None, length=steps)
    return toks


class BatchedServer:
    def __init__(self, model, params, cfg: ServeConfig,
                 collect_logits: bool = False, telemetry=None, meter=None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.collect_logits = collect_logits
        # wall-clock spans (this engine has no logical sim clock); claim
        # the registry's clock anyway so mixing this engine and a batcher
        # on one registry fails loudly instead of mixing time bases
        self.tel = telemetry if telemetry is not None else noop_registry()
        if telemetry is not None:
            telemetry.bind_clock(time.perf_counter, owner=self)
        # optional BankEnergyMeter: this engine has no page ledger, so each
        # generate() meters as a wall-clock square wave — the batch's dense
        # KV footprint admitted at prefill end, grown over decode, freed at
        # the end of the call
        self.meter = meter
        self._gen_seq = 0
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cfg.max_len))
        # static `steps`, donated cache: one compile per generation length,
        # zero host round-trips inside the loop
        self._loop = jax.jit(
            functools.partial(_generate_loop, model, cfg.temperature,
                              collect_logits),
            static_argnums=(0,), donate_argnums=(2,))

    def generate(self, batch: Dict[str, Any],
                 max_new_tokens: Optional[int] = None) -> Dict[str, Any]:
        """batch: model inputs with 'tokens' (B, S_prompt) [+ frames/prefix].

        Returns {'tokens': (B, S_new), 'stats': ServeStats}; with
        `collect_logits` also 'logits' (B, S_new, V) — the last-position
        logits that produced each emitted token (prefill step included),
        the reference side of the prefix-sharing bit-identity regression."""
        n_new = max_new_tokens or self.cfg.max_new_tokens
        rng = jax.random.PRNGKey(self.cfg.seed)
        stats = ServeStats()

        t0 = time.perf_counter()
        with self.tel.span("prefill", tokens=int(batch["tokens"].shape[1])):
            logits, cache = self._prefill(self.params, batch)
            logits.block_until_ready()
        stats.prefill_s = time.perf_counter() - t0

        rid = None
        kv0 = 0
        mcfg = getattr(self.model, "cfg", None)
        if self.meter is not None and mcfg is not None:
            from repro.serve.scheduler import kv_bytes_at
            B, S = batch["tokens"].shape
            rid = f"gen{self._gen_seq}"
            self._gen_seq += 1
            kv0 = B * kv_bytes_at(mcfg, int(S), 2)
            self.meter.record(time.perf_counter(), kv0, 0, rid=rid,
                              cause="admission")

        rng, k = jax.random.split(rng)
        tok = _sample(self.cfg.temperature, logits, k)
        first = np.asarray(tok)
        first_logits = (np.asarray(logits[:, -1, :])
                        if self.collect_logits else None)
        stats.ttft_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        step_logits = None
        if n_new > 1:
            with self.tel.span("decode", steps=n_new - 1):
                toks = self._loop(n_new - 1, self.params, cache, tok, rng)
                if self.collect_logits:
                    toks, step_logits = toks
                    step_logits = np.asarray(step_logits)   # (steps, B, V)
                toks.block_until_ready()
            rest = np.asarray(toks).T                       # (B, steps)
        else:
            rest = np.zeros((first.shape[0], 0), first.dtype)
        stats.decode_s = time.perf_counter() - t0
        stats.tokens_generated = n_new * first.shape[0]
        stats.tbt_s = stats.decode_s / (n_new - 1) if n_new > 1 else 0.0
        if rid is not None:
            from repro.serve.scheduler import kv_bytes_at
            B, S = batch["tokens"].shape
            t_end = time.perf_counter()
            grown = B * kv_bytes_at(mcfg, int(S) + n_new, 2) - kv0
            if grown:
                self.meter.record(t_end, grown, 0, rid=rid,
                                  cause="decode_growth")
            self.meter.record(t_end, -(kv0 + grown), 0, rid=rid)
        self.tel.counter("serve.engine.generate_calls").inc()
        self.tel.counter("serve.engine.tokens_generated").inc(
            stats.tokens_generated)
        out = {"tokens": np.concatenate([first, rest], axis=1),
               "stats": stats}
        if self.collect_logits:
            parts = [first_logits[:, None]]
            if step_logits is not None:
                parts.append(step_logits.transpose(1, 0, 2))
            out["logits"] = np.concatenate(parts, axis=1)   # (B, n_new, V)
        return out


def loop_compile_count() -> int:
    """Process-wide compile count of the BatchedServer decode loop —
    compatibility shim over the `serve.engine.loop_compiles` registry
    counter (the old module-global it replaced)."""
    return int(_COMPILES.value)
