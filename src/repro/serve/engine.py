"""Batched serving engine: prefill + device-resident decode loop.

The decode path is exactly what the decode_32k / long_500k dry-run cells
lower; on CPU the examples run it with reduced configs. KV caches are
preallocated to `max_len` (static shapes — one compiled decode loop serves
every position). Decoding runs as a single jitted `jax.lax.scan` over steps
with the cache pytree donated: no per-token Python dispatch, no per-token
host sync, and the cache is updated in place buffer-wise.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ServeConfig:
    max_len: int = 256
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    seed: int = 0


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_generated: int = 0

    @property
    def decode_tokens_per_s(self) -> float:
        return self.tokens_generated / self.decode_s if self.decode_s else 0.0


def _sample(temperature: float, logits: jax.Array, rng: jax.Array) -> jax.Array:
    logits = logits[:, -1, :]
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        rng, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


# traced once per XLA compilation — tests assert repeated generate() calls
# with stable shapes never re-trace the decode loop
LOOP_COMPILES = [0]


def _generate_loop(model, temperature: float, steps: int, params, cache,
                   tok, rng):
    """`steps` greedy/sampled decode steps as one on-device scan.

    Returns the emitted tokens (steps, B); the donated cache is consumed."""
    LOOP_COMPILES[0] += 1

    def step(carry, _):
        cache, tok, rng = carry
        logits, cache = model.decode_step(params, cache, tok)
        rng, k = jax.random.split(rng)
        tok = _sample(temperature, logits, k)
        return (cache, tok, rng), tok[:, 0]

    (cache, tok, rng), toks = jax.lax.scan(
        step, (cache, tok, rng), None, length=steps)
    return toks


class BatchedServer:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cfg.max_len))
        # static `steps`, donated cache: one compile per generation length,
        # zero host round-trips inside the loop
        self._loop = jax.jit(
            functools.partial(_generate_loop, model, cfg.temperature),
            static_argnums=(0,), donate_argnums=(2,))

    def generate(self, batch: Dict[str, Any],
                 max_new_tokens: Optional[int] = None) -> Dict[str, Any]:
        """batch: model inputs with 'tokens' (B, S_prompt) [+ frames/prefix].

        Returns {'tokens': (B, S_new), 'stats': ServeStats}."""
        n_new = max_new_tokens or self.cfg.max_new_tokens
        rng = jax.random.PRNGKey(self.cfg.seed)
        stats = ServeStats()

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        stats.prefill_s = time.perf_counter() - t0

        rng, k = jax.random.split(rng)
        tok = _sample(self.cfg.temperature, logits, k)
        first = np.asarray(tok)

        t0 = time.perf_counter()
        if n_new > 1:
            toks = self._loop(n_new - 1, self.params, cache, tok, rng)
            toks.block_until_ready()
            rest = np.asarray(toks).T                       # (B, steps)
        else:
            rest = np.zeros((first.shape[0], 0), first.dtype)
        stats.decode_s = time.perf_counter() - t0
        stats.tokens_generated = n_new * first.shape[0]
        return {"tokens": np.concatenate([first, rest], axis=1),
                "stats": stats}


def loop_compile_count() -> int:
    """Process-wide compile count of the BatchedServer decode loop."""
    return LOOP_COMPILES[0]
