"""Batched serving engine: prefill + decode loop with sampling.

The decode path is exactly what the decode_32k / long_500k dry-run cells
lower; on CPU the examples run it with reduced configs. KV caches are
preallocated to `max_len` (static shapes — one compiled decode_step serves
every position).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ServeConfig:
    max_len: int = 256
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    seed: int = 0


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_generated: int = 0

    @property
    def decode_tokens_per_s(self) -> float:
        return self.tokens_generated / self.decode_s if self.decode_s else 0.0


class BatchedServer:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cfg.max_len))
        self._decode = jax.jit(model.decode_step)

    def _sample(self, logits: jax.Array, rng: jax.Array) -> jax.Array:
        logits = logits[:, -1, :]
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.cfg.temperature, axis=-1)[:, None].astype(
            jnp.int32)

    def generate(self, batch: Dict[str, Any],
                 max_new_tokens: Optional[int] = None) -> Dict[str, Any]:
        """batch: model inputs with 'tokens' (B, S_prompt) [+ frames/prefix].

        Returns {'tokens': (B, S_new), 'stats': ServeStats}."""
        n_new = max_new_tokens or self.cfg.max_new_tokens
        rng = jax.random.PRNGKey(self.cfg.seed)
        stats = ServeStats()

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        stats.prefill_s = time.perf_counter() - t0

        rng, k = jax.random.split(rng)
        tok = self._sample(logits, k)
        out = [np.asarray(tok)]

        t0 = time.perf_counter()
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, cache, tok)
            rng, k = jax.random.split(rng)
            tok = self._sample(logits, k)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        stats.decode_s = time.perf_counter() - t0
        stats.tokens_generated = n_new * tok.shape[0]
        return {"tokens": np.concatenate(out, axis=1), "stats": stats}
