"""Prefix-sharing KV reuse: radix prefix index + refcounted COW page sharing.

At serving scale the dominant KV-occupancy lever beyond GQA is cross-request
reuse: real traffic (chat system prompts, few-shot templates, agentic
fan-out) repeats long prompt prefixes across concurrent slots. Because the
paged cache already reaches KV rows *through a page table*, sharing needs no
kernel change — two slots whose tables point at the same page read the same
rows. Three host-side pieces make that safe and time-resolved:

  * :class:`SharedPageAllocator` — refcount facade over the free-list
    allocator: a page is freed only when its last reference (slot table
    entries + the prefix index) drops;
  * :class:`RadixPrefixIndex` — radix tree over token sequences at page
    granularity. Interior nodes are full pages shared read-only; a run's
    last, partially-filled page is a leaf that is **copy-on-write split**
    on the first divergent write (a new request extending it, or the
    owning slot's own decode append). Unreferenced leaves are LRU-evicted
    under page pressure — eviction only ever frees index-only pages, never
    one a live slot references;
  * :class:`SharedKVLedger` — drop-in for `PagedKVLedger` that emits **dual
    occupancy traces**: *logical* (sum of per-slot page demand — what a
    no-sharing allocator would pin) and *physical* (unique slot-referenced
    pages as `needed`, cached-but-unreferenced pages as `obsolete`). The
    physical trace is a plain Stage-I `OccupancyTrace`, so Stage II sweeps
    banking/gating configs against true residency unchanged, and the
    logical-minus-physical gap is exactly the gating headroom sharing
    unlocks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.paged import PageAllocator, pages_for
from repro.sim.trace import OccupancyTrace


# ---------------------------------------------------------------------------
# Refcounted allocator
# ---------------------------------------------------------------------------

class SharedPageAllocator:
    """Refcount layer over :class:`PageAllocator`.

    Every live reference — one per slot page-table entry, one for the prefix
    index's cache entry — holds the page. `alloc` hands out pages at
    refcount 1; `retain`/`release` move the count; the base free list gets
    the page back only at zero. Conservation (`n_free + n_allocated ==
    num_pages - 1`, page 0 reserved) holds at every step."""

    def __init__(self, num_pages: int):
        self._base = PageAllocator(num_pages)
        self.num_pages = num_pages
        self._refs: Dict[int, int] = {}

    @property
    def n_free(self) -> int:
        return self._base.n_free

    @property
    def n_allocated(self) -> int:
        return self._base.n_allocated

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def alloc(self, n: int) -> List[int]:
        pages = self._base.alloc(n)
        for p in pages:
            self._refs[p] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        for p in pages:
            if self._refs.get(p, 0) < 1:
                raise ValueError(f"retain of unallocated page {p}")
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the pages actually freed."""
        freed = []
        for p in pages:
            c = self._refs.get(p, 0)
            if c < 1:
                raise ValueError(f"release of unallocated page {p}")
            if c == 1:
                del self._refs[p]
                self._base.free([p])
                freed.append(p)
            else:
                self._refs[p] = c - 1
        return freed


# ---------------------------------------------------------------------------
# Radix prefix index
# ---------------------------------------------------------------------------

@dataclass
class PrefixMatch:
    """Longest cached prefix of a probed prompt, page-granular.

    `pages` are fully-matched pages (`page_size` tokens each, safe to map
    read-only); `tail_page`/`tail_tokens` describe a partially-matched
    cached page whose first `tail_tokens` rows are valid for this prompt —
    usable only through a copy (COW at admission)."""
    pages: List[int] = field(default_factory=list)
    tail_page: Optional[int] = None
    tail_tokens: int = 0

    def tokens(self, page_size: int) -> int:
        return len(self.pages) * page_size + self.tail_tokens


class _Node:
    __slots__ = ("tokens", "page", "children", "parent", "stamp")

    def __init__(self, tokens: Tuple[int, ...], page: int,
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.stamp = 0

    def key(self) -> Tuple[int, ...]:
        return self.tokens


class RadixPrefixIndex:
    """Radix tree over token sequences, one page per node.

    A node's key is the exact token tuple its page holds (`page_size`
    tokens for interior/full nodes, fewer for partial leaves). The index
    owns one allocator reference per cached node, taken at `insert` and
    dropped at eviction; probing touches the matched path so eviction is
    leaf-LRU."""

    def __init__(self, page_size: int, allocator: SharedPageAllocator,
                 telemetry=None):
        from repro.obs.telemetry import noop_registry
        tel = telemetry if telemetry is not None else noop_registry()
        self._c_probe_hits = tel.counter("serve.prefix.probe_hits")
        self._c_probe_miss = tel.counter("serve.prefix.probe_misses")
        self._c_matched = tel.counter("serve.prefix.tokens_matched")
        self._c_inserted = tel.counter("serve.prefix.pages_inserted")
        self.page_size = page_size
        self.allocator = allocator
        self._root = _Node((), -1, None)
        self._clock = 0
        self.n_nodes = 0

    # ----------------------------------------------------------------- probe
    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.stamp = self._clock

    def probe(self, tokens: np.ndarray, limit: Optional[int] = None
              ) -> PrefixMatch:
        """Longest cached prefix of `tokens[:limit]`.

        Full pages match by exact key lookup; at the frontier the child
        with the longest common token prefix (if any) becomes the partial
        tail. Matched nodes are LRU-touched."""
        ps = self.page_size
        toks = [int(t) for t in tokens]
        if limit is not None:
            toks = toks[:limit]
        m = PrefixMatch()
        node = self._root
        pos = 0
        while True:
            rem = toks[pos:]
            nxt = None
            if len(rem) >= ps:
                nxt = node.children.get(tuple(rem[:ps]))
            if nxt is not None:
                m.pages.append(nxt.page)
                self._touch(nxt)
                node = nxt
                pos += ps
                continue
            # frontier: best partial match among children
            best, best_j = None, 0
            for child in node.children.values():
                j = 0
                for a, b in zip(child.tokens, rem):
                    if a != b:
                        break
                    j += 1
                if j > best_j:
                    best, best_j = child, j
            if best is not None:
                m.tail_page = best.page
                m.tail_tokens = best_j
                self._touch(best)
            matched = m.tokens(ps)
            if matched:
                self._c_probe_hits.inc()
                self._c_matched.inc(matched)
            else:
                self._c_probe_miss.inc()
            return m

    # ---------------------------------------------------------------- insert
    def insert(self, tokens: np.ndarray, pages: Sequence[int]) -> int:
        """Cache a run: `pages` hold the KV of `tokens`, page-aligned
        (`len(pages) == pages_for(len(tokens), page_size)`; the last page
        may be partial). For every *newly created* node the index retains
        its page. Existing nodes with identical keys are kept (the caller's
        duplicate page simply stays private). Returns #pages newly cached."""
        ps = self.page_size
        toks = [int(t) for t in tokens]
        assert len(pages) == pages_for(len(toks), ps), \
            (len(pages), len(toks), ps)
        node = self._root
        new = 0
        for i, page in enumerate(pages):
            chunk = tuple(toks[i * ps:(i + 1) * ps])
            existing = node.children.get(chunk)
            if existing is not None:
                self._touch(existing)
                node = existing
                continue
            child = _Node(chunk, int(page), node)
            self.allocator.retain([page])
            node.children[chunk] = child
            self._touch(child)
            self.n_nodes += 1
            new += 1
            if len(chunk) < ps:
                break            # partial pages are leaves (never descended)
            node = child
        self._c_inserted.inc(new)
        return new

    # --------------------------------------------------------------- queries
    def pages(self) -> List[int]:
        out = []
        stack = [self._root]
        while stack:
            n = stack.pop()
            if n is not self._root:
                out.append(n.page)
            stack.extend(n.children.values())
        return out

    @property
    def n_cached_pages(self) -> int:
        return self.n_nodes

    def runs(self) -> List[List[int]]:
        """Token sequences of every root-to-leaf path (invariant checks)."""
        out = []

        def walk(node, acc):
            if node is not self._root:
                acc = acc + list(node.tokens)
            if not node.children:
                if node is not self._root:
                    out.append(acc)
                return
            for c in node.children.values():
                walk(c, acc)
        walk(self._root, [])
        return out

    # -------------------------------------------------------------- eviction
    def evict(self, n_pages: int) -> List[int]:
        """Free >= `n_pages` pages by dropping LRU leaves whose page has no
        reference beyond the index itself. Dropping a leaf may expose its
        parent (pushed as a new candidate); the cascade continues until
        enough pages are freed or no evictable leaf remains. Never frees a
        slot-referenced page. One tree traversal + a heap: O((k+n) log n)
        for k evictions over n cached nodes."""
        import heapq
        freed: List[int] = []
        heap = [(n.stamp, id(n), n) for n in self._iter_nodes()
                if not n.children]
        heapq.heapify(heap)
        while heap and len(freed) < n_pages:
            _, _, victim = heapq.heappop(heap)
            if self.allocator.refcount(victim.page) != 1:
                continue     # slot-shared: not evictable (and stays a leaf)
            freed.extend(self.allocator.release([victim.page]))
            parent = victim.parent
            del parent.children[victim.key()]
            self.n_nodes -= 1
            if parent is not self._root and not parent.children:
                heapq.heappush(heap, (parent.stamp, id(parent), parent))
        return freed

    def _iter_nodes(self):
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())


# ---------------------------------------------------------------------------
# Dual-trace ledger
# ---------------------------------------------------------------------------

class SharedKVLedger:
    """Page ledger with prefix sharing and dual occupancy traces.

    Drop-in for `PagedKVLedger` where it matters to the batcher (`admit` /
    `grow` / `retire` / `occupancy_bytes`), plus sharing verbs (`map_shared`
    via `admit`, `cow`, `evict_for`) and an owned :class:`RadixPrefixIndex`.

    Trace semantics (synced after every mutation):
      * `trace`   ("kv", physical): needed = unique pages referenced by at
        least one slot; obsolete = allocated pages held only by the index
        (the reuse cache — resident, retained, but gateable against demand);
      * `logical` ("kv_logical"): sum over slots of their page counts — the
        occupancy a non-sharing allocator would report. physical needed <=
        logical always; the gap is the sharing win."""

    def __init__(self, num_pages: int, page_bytes_: int, page_size: int,
                 num_slots: int = 0, max_pages_per_slot: int = 0,
                 telemetry=None):
        from repro.obs.telemetry import noop_registry
        tel = telemetry if telemetry is not None else noop_registry()
        self.tel = tel
        self._c_evicted = tel.counter("serve.prefix.evicted_pages")
        self._c_cow = tel.counter("serve.prefix.cow_splits")
        self._g_physical = tel.gauge("serve.prefix.pages_physical")
        self._g_cached = tel.gauge("serve.prefix.pages_cached")
        self._g_logical = tel.gauge("serve.prefix.pages_logical")
        self.allocator = SharedPageAllocator(num_pages)
        self.index = RadixPrefixIndex(page_size, self.allocator,
                                      telemetry=telemetry)
        self.page_bytes = page_bytes_
        self.page_size = page_size
        cap = (num_pages - 1) * page_bytes_
        logical_cap = (num_slots * max_pages_per_slot * page_bytes_
                       if num_slots and max_pages_per_slot else cap)
        self.trace = OccupancyTrace("kv", cap)
        self.logical = OccupancyTrace("kv_logical", logical_cap)
        self.slot_pages: Dict[int, List[int]] = {}
        self._last = (0, 0, 0)      # (needed, obsolete, logical) in pages
        # Speculative-decoding draft lane: per-slot private pages drawn from
        # the SAME allocator/page-id space but never shared, never indexed.
        self.draft_pages: Dict[int, List[int]] = {}
        self.draft_page_bytes: Optional[int] = None
        self._last_draft = 0
        # optional streaming energy meter (obs.energy.BankEnergyMeter):
        # sync() mirrors every physical delta to it, tagged with the
        # mutating slot's request/tenant; the attribution weight is the
        # slot's *logical* holdings change (shared pages sustain banks for
        # every referencing request, so each holds its full logical share)
        self.meter = None
        self.slot_meta: Dict[int, tuple] = {}
        self._meter_w: Dict[int, int] = {}

    def set_slot_meta(self, slot: int, rid, tenant=None) -> None:
        """Tag a slot so mirrored meter events attribute to its request."""
        self.slot_meta[slot] = (rid, tenant)

    # ------------------------------------------------------------ accounting
    def occupancy_bytes(self) -> int:
        nd = sum(len(p) for p in self.draft_pages.values())
        db = (self.draft_page_bytes if self.draft_page_bytes is not None
              else self.page_bytes)
        return (self.allocator.n_allocated - nd) * self.page_bytes + nd * db

    def logical_bytes(self) -> int:
        """Sum over slots of their page footprint — what a non-sharing
        allocator would hold. `occupancy_bytes` <= this; gap = sharing win."""
        return sum(len(p) for p in self.slot_pages.values()) * self.page_bytes

    def _counts(self) -> Tuple[int, int, int]:
        sref = set()
        logical = 0
        for pages in self.slot_pages.values():
            sref.update(pages)
            logical += len(pages)
        needed = len(sref)
        ndraft = sum(len(p) for p in self.draft_pages.values())
        obsolete = self.allocator.n_allocated - needed - ndraft
        return needed, obsolete, logical

    def sync(self, t: float, slot: Optional[int] = None,
             cause: Optional[str] = None) -> None:
        """Emit the delta between the live page counts and the last synced
        state on both traces. Call after any out-of-band index mutation.
        Draft-lane pages count as `needed` (they back live slots) at the
        draft lane's own page bytes; with the lane unused the accounting is
        bit-identical to the pre-speculation ledger. `slot`/`cause` tag the
        mutation for the (optional) energy meter mirror."""
        needed, obsolete, logical = self._counts()
        ndraft = sum(len(p) for p in self.draft_pages.values())
        pn, po, pl = self._last
        pd = self._last_draft
        pb = self.page_bytes
        db = (self.draft_page_bytes if self.draft_page_bytes is not None
              else pb)
        dn = (needed - pn) * pb + (ndraft - pd) * db
        do = (obsolete - po) * pb
        self.trace.event(t, dn, do)
        self.logical.event(t, (logical - pl) * pb + (ndraft - pd) * db, 0)
        self._last = (needed, obsolete, logical)
        self._last_draft = ndraft
        self._g_physical.set(needed)
        self._g_cached.set(obsolete)
        self._g_logical.set(logical)
        if self.meter is not None:
            wd = 0
            if slot is not None:
                w = (len(self.slot_pages.get(slot, ())) * pb
                     + len(self.draft_pages.get(slot, ())) * db)
                wd = w - self._meter_w.pop(slot, 0)
                if w:
                    self._meter_w[slot] = w
            if dn or do or wd:
                rid, tenant = ((None, None) if slot is None
                               else self.slot_meta.get(slot, (None, None)))
                self.meter.record(t, dn, do, rid=rid, tenant=tenant,
                                  cause=cause, weight_delta=wd)

    # ------------------------------------------------------------------ verbs
    def admit(self, slot: int, n_pages: int, t: float,
              shared: Sequence[int] = ()) -> List[int]:
        """Create the slot: map `shared` pages (refcount++) and allocate
        `n_pages` fresh private pages after them. Returns the fresh pages."""
        assert slot not in self.slot_pages, f"slot {slot} already admitted"
        shared = list(shared)
        self.allocator.retain(shared)
        try:
            fresh = self.allocator.alloc(n_pages)
        except Exception:
            self.allocator.release(shared)
            raise
        self.slot_pages[slot] = shared + fresh
        self.sync(t, slot, "admission")
        return fresh

    def grow(self, slot: int, total_pages: int, t: float,
             cause: str = "decode_growth") -> List[int]:
        have = self.slot_pages[slot]
        extra = total_pages - len(have)
        if extra <= 0:
            return []
        fresh = self.allocator.alloc(extra)
        have.extend(fresh)
        self.sync(t, slot, cause)
        return fresh

    def cow(self, slot: int, table_idx: int, t: float) -> int:
        """Copy-on-write split of the slot's `table_idx`-th page: allocate a
        private page, swap it into the slot's list, drop the slot's
        reference on the shared original (which survives wherever else it
        is referenced — index or other slots). Returns the new page id; the
        caller copies the device contents."""
        old = self.slot_pages[slot][table_idx]
        if self.allocator.refcount(old) <= 1:
            raise ValueError(f"page {old} is private; COW is for shared pages")
        new = self.allocator.alloc(1)[0]
        self.slot_pages[slot][table_idx] = new
        self.allocator.release([old])
        self._c_cow.inc()
        self.sync(t, slot, "cow")
        return new

    def retire(self, slot: int, t: float) -> int:
        """Release every page the slot references — target lane and (if
        present) draft lane. Pages the index still caches become `obsolete`
        occupancy (the reuse cache); the rest return to the free list.
        Returns the pages *actually freed*."""
        pages = self.slot_pages.pop(slot)
        pages = list(pages) + self.draft_pages.pop(slot, [])
        freed = self.allocator.release(pages)
        self.sync(t, slot)
        self.slot_meta.pop(slot, None)
        return len(freed)

    # ------------------------------------------------- speculative draft lane
    def enable_draft_lane(self, draft_page_bytes: int) -> None:
        """Declare the byte width of draft-lane pages (the draft model's
        per-page KV footprint). Draft pages come out of the same allocator
        and page-id space as target pages but are strictly private: never
        radix-indexed, never shared, never COW'd."""
        self.draft_page_bytes = int(draft_page_bytes)

    def admit_draft(self, slot: int, n_pages: int, t: float) -> List[int]:
        assert slot not in self.draft_pages, \
            f"slot {slot} already has a draft lane"
        fresh = self.allocator.alloc(n_pages)
        self.draft_pages[slot] = fresh
        self.sync(t, slot, "admission")
        return fresh

    def grow_draft(self, slot: int, total_pages: int, t: float) -> List[int]:
        have = self.draft_pages[slot]
        extra = total_pages - len(have)
        if extra <= 0:
            return []
        fresh = self.allocator.alloc(extra)
        have.extend(fresh)
        self.sync(t, slot, "decode_growth")
        return fresh

    def truncate_rows(self, slot: int, n_rows: int, t: float
                      ) -> Tuple[List[int], List[int]]:
        """Rollback-by-page-truncation: drop the slot's references to every
        page past `pages_for(n_rows)`, in both lanes. Shared prefix pages
        merely lose one reference (the refcount layer guarantees they are
        never mutated or reclaimed while the index or another slot holds
        them); private tail pages return to the free list. Returns the
        (target, draft) pages actually freed."""
        keep = pages_for(n_rows, self.page_size)
        have = self.slot_pages[slot]
        freed_t: List[int] = []
        dirty = False
        if keep < len(have):
            tail = have[keep:]
            del have[keep:]
            freed_t = self.allocator.release(tail)
            dirty = True
        freed_d: List[int] = []
        dhave = self.draft_pages.get(slot)
        if dhave is not None and keep < len(dhave):
            dtail = dhave[keep:]
            del dhave[keep:]
            freed_d = self.allocator.release(dtail)
            dirty = True
        if dirty:
            self.sync(t, slot, "spec_rollback")
        return freed_t, freed_d

    def evict_for(self, n_pages: int, t: float) -> int:
        """LRU-evict cached prefixes until `n_pages` are freed (or nothing
        evictable remains). Returns pages actually freed."""
        freed = self.index.evict(n_pages)
        if freed:
            self._c_evicted.inc(len(freed))
            self.sync(t)
        return len(freed)

    def insert_run(self, tokens: np.ndarray, pages: Sequence[int],
                   t: float) -> int:
        new = self.index.insert(tokens, pages)
        if new:
            self.sync(t)
        return new
