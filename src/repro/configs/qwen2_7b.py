"""Qwen2-7B — dense GQA decoder, QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig, register

QWEN2_7B = register(ArchConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    attn_bias=True,
    ffn_kind="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671; hf",
))
