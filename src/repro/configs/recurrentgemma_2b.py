"""RecurrentGemma-2B — Griffin: RG-LRU recurrent blocks + local attention in a
2:1 pattern (two recurrent blocks per local-attention block), MQA kv=1.
[arXiv:2402.19427; hf]
"""
from repro.configs.base import ArchConfig, RGLRUConfig, register

RECURRENTGEMMA_2B = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    ffn_kind="geglu",
    norm="rmsnorm",
    pos_emb="rope",
    tie_embeddings=True,
    rglru=RGLRUConfig(conv_width=4),
    source="arXiv:2402.19427; hf",
))
