"""InternVL2-2B — InternViT frontend (STUB: precomputed patch embeddings) +
InternLM2-1.8B language backbone (llama-style GQA kv=8). [arXiv:2404.16821; hf]
"""
from repro.configs.base import ArchConfig, FrontendConfig, register

INTERNVL2_2B = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    ffn_kind="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    frontend=FrontendConfig(kind="vision", num_prefix_tokens=256),
    source="arXiv:2404.16821; hf",
))
