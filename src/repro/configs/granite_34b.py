"""Granite-34B-Code — llama-arch with MQA (kv=1). [arXiv:2405.04324; hf]"""
from repro.configs.base import ArchConfig, register

GRANITE_34B = register(ArchConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    ffn_kind="gelu_mlp",
    norm="layernorm",
    pos_emb="learned",
    tie_embeddings=True,
    source="arXiv:2405.04324; hf",
))
