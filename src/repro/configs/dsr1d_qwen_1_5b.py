"""DeepSeek-R1-Distill-Qwen-1.5B — the paper's GQA workload (TRAPTI Table I):
28L, d=1536, 12 query heads / 2 KV heads (head_dim 128), d_ff=8960 SwiGLU.
[Guo et al. 2025; paper Table I]
"""
from repro.configs.base import ArchConfig, register

DSR1D_QWEN_1_5B = register(ArchConfig(
    name="dsr1d-qwen-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    attn_bias=True,
    ffn_kind="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    vocab_size=151936,
    tie_embeddings=True,
    source="paper Table I (TRAPTI); DeepSeek-R1 distill",
))
