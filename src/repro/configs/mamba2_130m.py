"""Mamba2-130m — attention-free SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, SSMConfig, register

MAMBA2_130M = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,           # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                # mamba blocks carry no MLP
    vocab_size=50280,
    block_pattern=("ssm",),
    norm="rmsnorm",
    pos_emb="none",
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, expand=2, head_dim=64, conv_width=4,
                  chunk_size=256),
    source="arXiv:2405.21060; unverified",
))
