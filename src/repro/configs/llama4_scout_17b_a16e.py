"""Llama-4-Scout-17B-16E — top-1 MoE with shared expert; chunked local attention
(8192) on 3/4 layers with global (NoPE) attention every 4th layer.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig, MoEConfig, register

LLAMA4_SCOUT = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,            # per-expert hidden dim
    vocab_size=202048,
    ffn_kind="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=500_000.0,
    block_pattern=("chunked", "chunked", "chunked", "full"),
    local_window=8192,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192, shared_expert=True),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
