"""TinyLlama-1.1B — llama2-style small dense GQA. [arXiv:2401.02385; hf]"""
from repro.configs.base import ArchConfig, register

TINYLLAMA_1_1B = register(ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    ffn_kind="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    source="arXiv:2401.02385; hf",
))
