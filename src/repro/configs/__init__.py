"""Arch config registry — importing this package registers every config."""
from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, RGLRUConfig, FrontendConfig,
    ShapeConfig, SHAPES, LONG_CONTEXT_OK,
    get_arch, list_archs, reduced, register, resolve_arch, shape_supported,
)

# Assigned architectures (10)
from repro.configs.qwen2_7b import QWEN2_7B
from repro.configs.tinyllama_1_1b import TINYLLAMA_1_1B
from repro.configs.deepseek_coder_33b import DEEPSEEK_CODER_33B
from repro.configs.granite_34b import GRANITE_34B
from repro.configs.olmoe_1b_7b import OLMOE_1B_7B
from repro.configs.llama4_scout_17b_a16e import LLAMA4_SCOUT
from repro.configs.seamless_m4t_large_v2 import SEAMLESS_M4T_LARGE_V2
from repro.configs.mamba2_130m import MAMBA2_130M
from repro.configs.recurrentgemma_2b import RECURRENTGEMMA_2B
from repro.configs.internvl2_2b import INTERNVL2_2B

# Paper workloads (TRAPTI Table I)
from repro.configs.gpt2_xl import GPT2_XL
from repro.configs.dsr1d_qwen_1_5b import DSR1D_QWEN_1_5B

ASSIGNED_ARCHS = (
    "qwen2-7b", "tinyllama-1.1b", "deepseek-coder-33b", "granite-34b",
    "olmoe-1b-7b", "llama4-scout-17b-a16e", "seamless-m4t-large-v2",
    "mamba2-130m", "recurrentgemma-2b", "internvl2-2b",
)
PAPER_ARCHS = ("gpt2-xl", "dsr1d-qwen-1.5b")

__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "RGLRUConfig", "FrontendConfig",
    "ShapeConfig", "SHAPES", "LONG_CONTEXT_OK", "get_arch", "list_archs",
    "reduced", "register", "resolve_arch", "shape_supported",
    "ASSIGNED_ARCHS", "PAPER_ARCHS",
]
