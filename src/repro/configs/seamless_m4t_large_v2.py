"""SeamlessM4T-large-v2 — encoder-decoder multimodal backbone; the audio
frontend is a STUB (input_specs() yields precomputed frame embeddings).
[arXiv:2308.11596; hf]

The assignment specifies the transformer backbone only: 24L, d=1024, 16H,
d_ff=8192, vocab=256206. We realize it as 24 encoder + 24 decoder layers with
cross-attention, matching the seamless text-to-text path.
"""
from repro.configs.base import ArchConfig, FrontendConfig, register

SEAMLESS_M4T_LARGE_V2 = register(ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,            # decoder depth
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    ffn_kind="gelu_mlp",
    norm="layernorm",
    pos_emb="learned",
    frontend=FrontendConfig(kind="audio", num_prefix_tokens=1024),
    source="arXiv:2308.11596; hf",
))
