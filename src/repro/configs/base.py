"""Config system: one ArchConfig per supported architecture + the shape registry.

Every subsystem (JAX model zoo, TRAPTI Stage-I simulator, dry-run launcher,
roofline) is driven from these dataclasses, so a single `--arch` flag selects a
coherent workload everywhere.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # llama4-style shared expert that always runs alongside routed experts.
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block parameters."""
    state_dim: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block parameters."""
    conv_width: int = 4
    # Griffin uses a small expansion on the recurrent branch.
    lru_width_multiplier: float = 1.0

    def lru_width(self, d_model: int) -> int:
        return int(d_model * self.lru_width_multiplier)


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend ([audio]/[vlm]): input_specs() yields precomputed
    frame/patch embeddings of shape (batch, num_prefix_tokens, d_model)."""
    kind: str  # "audio" | "vision"
    num_prefix_tokens: int = 1024


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | audio | ssm | hybrid | vlm
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention options -------------------------------------------------
    attn_bias: bool = False                      # qwen2 uses QKV bias
    # Per-layer block pattern, cycled over the depth. Entries:
    #   "full"    — global causal self-attention
    #   "local"   — sliding-window attention (window = local_window)
    #   "chunked" — llama4-style chunked local attention (chunk = local_window)
    #   "rglru"   — RG-LRU recurrent block (no attention)
    #   "ssm"     — Mamba-2 SSD block
    block_pattern: tuple = ("full",)
    local_window: int = 0

    # --- ffn ----------------------------------------------------------------
    ffn_kind: str = "swiglu"     # swiglu | gelu_mlp | geglu
    # --- norms / embeddings ---------------------------------------------------
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    pos_emb: str = "rope"        # rope | learned | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    max_seq_len: int = 524_288   # cap for learned position tables / rope cache

    # --- family extensions ----------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder_layers: int = 0      # > 0 => encoder-decoder
    frontend: Optional[FrontendConfig] = None

    # --- bookkeeping ----------------------------------------------------------
    source: str = ""             # citation tag from the assignment
    # vocab padded to this multiple before sharding (standard production trick
    # so the embedding table shards evenly over the model axis).
    pad_vocab_multiple: int = 128

    # ------------------------------------------------------------------ helpers
    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(b in ("rglru", "ssm") for b in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no block does global attention over the full sequence
        (SSM / RG-LRU / local / chunked only) — or when global-attention blocks
        are a bounded minority with O(N) decode cost (llama4 chunked+full mix is
        handled by the shape-skip table, not here)."""
        return all(b in ("rglru", "ssm", "local", "chunked") for b in self.block_pattern)

    def layer_kinds(self, n: Optional[int] = None):
        """The cycled per-layer block pattern over the decoder depth."""
        n = self.num_layers if n is None else n
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(n))

    # --- analytic parameter count (used for MODEL_FLOPS + sanity tests) -------
    def param_count(self) -> int:
        D, Dff, V = self.d_model, self.d_ff, self.padded_vocab
        total = V * D                      # token embedding
        if not self.tie_embeddings:
            total += V * D                 # lm head
        if self.pos_emb == "learned":
            total += min(self.max_seq_len, 32768) * D

        def attn_params() -> int:
            p = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            if self.attn_bias:
                p += self.q_dim + 2 * self.kv_dim
            return p

        def ffn_params(dff: int) -> int:
            mult = 3 if self.ffn_kind in ("swiglu", "geglu") else 2
            return mult * D * dff

        def moe_params() -> int:
            assert self.moe is not None
            p = self.moe.num_experts * ffn_params(self.moe.d_ff_expert)
            p += D * self.moe.num_experts          # router
            if self.moe.shared_expert:
                p += ffn_params(self.moe.d_ff_expert)
            return p

        def ssm_params() -> int:
            assert self.ssm is not None
            di = self.ssm.d_inner(D)
            nh = self.ssm.num_heads(D)
            ns = self.ssm.state_dim
            # in_proj produces [z, x, B, C, dt]; out_proj back to D.
            p = D * (2 * di + 2 * ns + nh) + di * D
            p += self.ssm.conv_width * (di + 2 * ns)   # causal conv
            p += nh * 2                                 # A_log, D per head
            return p

        def rglru_params() -> int:
            assert self.rglru is not None
            w = self.rglru.lru_width(D)
            # gated branches in/out + conv + input/forget gates (diagonal-ish)
            return 2 * D * w + w * D + self.rglru.conv_width * w + 2 * w * w // max(1, w // 256)

        for kind in self.layer_kinds():
            if kind in ("full", "local", "chunked"):
                total += attn_params()
            elif kind == "ssm":
                total += ssm_params()
            elif kind == "rglru":
                total += rglru_params()
            # FFN for every block except pure-SSM archs (mamba blocks have no MLP)
            if kind != "ssm":
                total += moe_params() if self.moe is not None else ffn_params(Dff)
            total += 2 * D                      # norms

        if self.is_encdec:
            # encoder self-attn + ffn, decoder additionally cross-attn
            enc = self.encoder_layers * (attn_params() + ffn_params(Dff) + 2 * D)
            cross = self.num_layers * (attn_params() + D)
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m = self.moe

        def ffn_p(dff):
            mult = 3 if self.ffn_kind in ("swiglu", "geglu") else 2
            return mult * self.d_model * dff

        moe_layers = sum(1 for k in self.layer_kinds() if k != "ssm")
        inactive = moe_layers * (m.num_experts - m.top_k) * ffn_p(m.d_ff_expert)
        return full - inactive


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}

# Archs allowed to run long_500k (sub-quadratic or bounded-KV attention).
LONG_CONTEXT_OK = frozenset({
    "mamba2-130m", "recurrentgemma-2b", "llama4-scout-17b-a16e",
})


def shape_supported(arch: "ArchConfig", shape: ShapeConfig) -> tuple:
    """(supported, reason) — encodes the assignment's skip rules."""
    if shape.name == "long_500k" and arch.name not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: 500k-token KV skip per assignment"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, f"duplicate arch {cfg.name}"
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    # import side-effect registration
    from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def resolve_arch(name: str) -> ArchConfig:
    """`get_arch` that also accepts module-style spellings: separators and
    case are ignored, so "dsr1d_qwen_1_5b" == "dsr1d-qwen-1.5b"."""
    from repro import configs as _c  # noqa: F401
    if name in _REGISTRY:
        return _REGISTRY[name]

    def canon(s: str) -> str:
        return "".join(ch for ch in s.lower() if ch.isalnum())

    matches = [k for k in _REGISTRY if canon(k) == canon(name)]
    if len(matches) != 1:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[matches[0]]


def list_archs() -> list:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced(cfg: ArchConfig, *, layers: Optional[int] = None) -> ArchConfig:
    """A tiny config of the same family: same block pattern/features, small dims.

    Used by smoke tests and CPU examples; the FULL configs are only ever
    lowered via ShapeDtypeStructs in the dry-run.
    """
    pat = len(cfg.block_pattern)
    n_layers = layers if layers is not None else max(2, 2 * pat)
    # keep the pattern intact across the reduced depth
    n_layers = max(n_layers, pat)
    head_dim = 16
    n_heads = max(2, min(4, cfg.num_heads or 2))
    n_kv = max(1, min(cfg.num_kv_heads, n_heads)) if cfg.num_heads else 0
    # preserve MQA/GQA/MHA character
    if cfg.num_heads and cfg.num_kv_heads == cfg.num_heads:
        n_kv = n_heads
    elif cfg.num_heads and cfg.num_kv_heads == 1:
        n_kv = 1
    elif cfg.num_heads:
        n_kv = max(1, n_heads // 2)
    d_model = 64
    kw = dict(
        name=cfg.name + "-reduced",
        num_layers=n_layers,
        d_model=d_model,
        num_heads=n_heads if cfg.num_heads else 0,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=128,
        vocab_size=256,
        max_seq_len=512,
        local_window=min(cfg.local_window, 64) if cfg.local_window else 0,
        pad_vocab_multiple=32,
    )
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, num_experts=4,
                            top_k=min(cfg.moe.top_k, 2), d_ff_expert=64)
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, state_dim=16, head_dim=16, chunk_size=32)
    if cfg.rglru is not None:
        kw["rglru"] = cfg.rglru
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
    if cfg.frontend is not None:
        kw["frontend"] = replace(cfg.frontend, num_prefix_tokens=8)
    out = replace(cfg, **kw)
    # registry guard: reduced configs are never registered
    return out
