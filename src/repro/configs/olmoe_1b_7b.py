"""OLMoE-1B-7B — 64-expert top-8 MoE, full-head attention. [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig, MoEConfig, register

OLMOE_1B_7B = register(ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,            # per-expert hidden dim
    vocab_size=50304,
    ffn_kind="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    source="arXiv:2409.02060; hf",
))
