"""GPT-2 XL — the paper's MHA workload (TRAPTI Table I): 48L, d=1600, 25H MHA,
d_ff=6400, vocab 50257, learned positions, GELU MLP. [Radford et al. 2019]
"""
from repro.configs.base import ArchConfig, register

GPT2_XL = register(ArchConfig(
    name="gpt2-xl",
    family="dense",
    num_layers=48,
    d_model=1600,
    num_heads=25,
    num_kv_heads=25,       # MHA
    head_dim=64,
    d_ff=6400,
    vocab_size=50257,
    ffn_kind="gelu_mlp",
    norm="layernorm",
    pos_emb="learned",
    tie_embeddings=True,
    max_seq_len=2048,
    source="paper Table I (TRAPTI); Radford et al. 2019",
))
