"""Training launcher: builds model/optimizer/data from an arch config and
runs the fault-tolerant loop. On the production mesh this is the entry point
a scheduler (re)starts on every elastic event; on CPU it drives the reduced
configs for the examples.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 200 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced as make_reduced
from repro.data import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.optim import AdamW, cosine_with_warmup
from repro.train import LoopConfig, TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8ef"])
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    opt = AdamW(lr=cosine_with_warmup(args.lr, args.steps // 10, args.steps))
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))
    loop = TrainLoop(model, opt, data, LoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, compression=args.compression),
        fail_at_step=args.fail_at)
    out = loop.run()
    h = out["history"]
    print(f"steps {h[0]['step']}..{h[-1]['step']}  "
          f"loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}  "
          f"stragglers={len(out['stragglers'])}")


if __name__ == "__main__":
    main()
