import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (The two lines above MUST run before any other import — jax locks the
# device count at first init. Only the dry-run sees 512 placeholder devices;
# tests/benches keep 1.)

# Multi-pod dry-run: prove the distribution config is coherent by lowering +
# compiling every (architecture x input shape) cell on the production meshes,
# then extract memory/cost analysis + roofline terms from the compiled
# artifacts.
#
# Usage:
#     PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
#         --shape train_4k --mesh single
#     PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCHS, SHAPES, get_arch,
                           shape_supported)
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl
from repro.models import build_model, batch_struct, cache_struct
from repro.models.meshctx import use_mesh
from repro.models.sharding import (SERVE_RULES, TRAIN_RULES, batch_spec,
                                   template_shardings)
from repro.models.transformer import cache_specs
from repro.optim import AdamW, AdamWState, cosine_with_warmup
from repro.train.step import make_train_step


def _batch_shardings(batch_abs: Dict[str, Any], mesh, kind: str):
    bspec = batch_spec(mesh, next(iter(batch_abs.values())).shape[0], kind)
    out = {}
    for k, v in batch_abs.items():
        spec = P(*(bspec + P(*([None] * (v.ndim - 1)))))
        out[k] = NamedSharding(mesh, spec)
    return out


def _encdec_cache_shardings(cache_abs, mesh):
    def f(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2:
            b = leaf.shape[1]
            if "data" in mesh.shape and b % mesh.shape["data"] == 0:
                spec[1] = "data"
        if leaf.ndim >= 5:
            if leaf.shape[-1] % mesh.shape["model"] == 0:
                spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(f, cache_abs)


def _compile_step(cfg, shape, mesh, *, remat: str, unroll: bool,
                  donate: bool = True, microbatches: int = 1):
    """Lower + compile one cell's step function; returns the Compiled."""
    kind = shape.kind
    kvb = 1024
    model = build_model(cfg, compute_dtype=jnp.bfloat16, remat=remat,
                        kv_block=kvb, unroll=unroll)
    template = model.template()

    with use_mesh(mesh):
        if kind == "train":
            rules = TRAIN_RULES
            params_abs = model.abstract()                      # fp32 master
            param_sh = template_shardings(template, mesh, rules)
            opt = AdamW(lr=cosine_with_warmup(3e-4, 100, 10000))
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_sh = AdamWState(NamedSharding(mesh, P()), param_sh,
                                jax.tree.map(lambda s: s, param_sh))
            batch_abs = batch_struct(cfg, shape)
            batch_sh = _batch_shardings(batch_abs, mesh, kind)
            step = make_train_step(model, opt, microbatches=microbatches)
            jitted = jax.jit(
                step, in_shardings=(param_sh, opt_sh, batch_sh),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif kind == "prefill":
            rules = SERVE_RULES
            params_abs = model.abstract("bfloat16")
            param_sh = template_shardings(template, mesh, rules)
            batch_abs = batch_struct(cfg, shape)
            batch_sh = _batch_shardings(batch_abs, mesh, kind)
            cache_len = shape.seq_len
            fn = lambda p, b: model.prefill(p, b, cache_len=cache_len)  # noqa: E731
            # pin the output cache to the decode-consumable sharding — the
            # inferred sharding replicates the (huge) cache over "model"
            # (Perf iteration B3)
            from repro.models.factory import cache_struct as _cs
            decode_like = SHAPES.get("decode_32k")
            import dataclasses as _dc
            dshape = _dc.replace(decode_like, seq_len=cache_len,
                                 global_batch=shape.global_batch)
            cache_abs = cache_struct(cfg, dshape)
            if cfg.is_encdec:
                cache_sh = _encdec_cache_shardings(cache_abs, mesh)
            else:
                specs = cache_specs(cfg, shape.global_batch, cache_len, mesh)
                cache_sh = jax.tree.map(
                    lambda s: NamedSharding(mesh, s), specs,
                    is_leaf=lambda x: isinstance(x, P))
            logits_sh = NamedSharding(mesh, P(None, None, None))
            jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh),
                             out_shardings=(logits_sh, cache_sh))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            rules = SERVE_RULES
            params_abs = model.abstract("bfloat16")
            param_sh = template_shardings(template, mesh, rules)
            batch_abs = batch_struct(cfg, shape)
            batch_sh = _batch_shardings(batch_abs, mesh, kind)
            cache_abs = cache_struct(cfg, shape)
            if cfg.is_encdec:
                cache_sh = _encdec_cache_shardings(cache_abs, mesh)
            else:
                specs = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                    mesh)
                cache_sh = jax.tree.map(
                    lambda s: NamedSharding(mesh, s), specs,
                    is_leaf=lambda x: isinstance(x, P))
            jitted = jax.jit(
                model.decode_step,
                in_shardings=(param_sh, cache_sh, batch_sh["tokens"]),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_abs, cache_abs,
                                   batch_abs["tokens"])
        return lowered.compile()


def _cost_terms(compiled) -> Dict[str, Any]:
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        cost = dict(ca or {})
    except Exception:  # noqa: BLE001
        pass
    coll = rl.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def _probe_cfg(cfg, n_groups: int):
    """Reduced-depth clone: n_groups pattern repetitions (full width)."""
    import dataclasses
    P_len = len(cfg.block_pattern)
    kw = {"num_layers": n_groups * P_len, "name": f"{cfg.name}-probe{n_groups}"}
    if cfg.is_encdec:
        kw["encoder_layers"] = n_groups
        kw["num_layers"] = n_groups
    # bypass the registry (probe configs are never registered)
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               compile_: bool = True, remat: str = "full",
               donate: bool = True, probe_costs: bool = True,
               microbatches: int = 1) -> Dict[str, Any]:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = shape_supported(cfg, shape)
    mesh_label = "multi" if multi_pod else "single"
    cell = {"arch": arch_name, "shape": shape_name, "mesh": mesh_label}
    if not ok:
        cell["status"] = "SKIP"
        cell["reason"] = reason
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    # ---- 1) the real artifact: full model, scanned layers ------------------
    t0 = time.perf_counter()
    compiled = _compile_step(cfg, shape, mesh, remat=remat, unroll=False,
                             donate=donate, microbatches=microbatches)
    cell["compile_s"] = round(time.perf_counter() - t0, 1)

    peak = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                if hasattr(ma, attr):
                    peak[attr] = int(getattr(ma, attr))
            cell["memory_analysis"] = peak
    except Exception as e:  # noqa: BLE001
        cell["memory_analysis_error"] = str(e)
    hlo_len = len(compiled.as_text())

    # ---- 2) cost terms ------------------------------------------------------
    # HLO cost analysis visits loop bodies once, so the scanned module
    # understates FLOPs/bytes/collectives. We compile two reduced-depth
    # clones (1 and 2 pattern groups) with scans fully unrolled and
    # extrapolate linearly in depth — exact for the homogeneous layer stack,
    # and cheap enough to run for every cell.
    if probe_costs:
        t1 = time.perf_counter()
        c1 = _cost_terms(_compile_step(_probe_cfg(cfg, 1), shape, mesh,
                                       remat=remat, unroll=True,
                                       donate=donate,
                                       microbatches=microbatches))
        c2 = _cost_terms(_compile_step(_probe_cfg(cfg, 2), shape, mesh,
                                       remat=remat, unroll=True,
                                       donate=donate,
                                       microbatches=microbatches))
        cell["probe_s"] = round(time.perf_counter() - t1, 1)
        n_groups = cfg.num_layers / len(cfg.block_pattern)
        if cfg.is_encdec:
            n_groups = cfg.num_layers  # enc+dec scale together in the probes

        def extrap(a, b):
            body = b - a
            return max(a + (n_groups - 1) * body, 0.0)

        cost = {"flops": extrap(c1["flops"], c2["flops"]),
                "bytes accessed": extrap(c1["bytes"], c2["bytes"])}
        coll = {k: extrap(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}
    else:
        ct = _cost_terms(compiled)
        cost = {"flops": ct["flops"], "bytes accessed": ct["bytes"]}
        coll = ct["coll"]

    mf = rl.model_flops(cfg, shape)
    peak_bytes = None
    if peak:
        peak_bytes = (peak.get("argument_size_in_bytes", 0)
                      + peak.get("temp_size_in_bytes", 0)
                      + peak.get("output_size_in_bytes", 0)
                      - peak.get("alias_size_in_bytes", 0))
    rep = rl.build_report(arch_name, shape_name, mesh_label, chips, cost,
                          "", mf, peak_bytes,
                          min_bytes=rl.min_hbm_bytes(cfg, shape, chips))
    rep.coll_breakdown = {k: int(v) for k, v in coll.items()}
    rep.coll_bytes_per_device = float(sum(coll.values()))
    cell["roofline"] = rep.to_dict()
    cell["status"] = "OK"
    cell["hlo_bytes"] = hlo_len
    return cell


def all_cells(include_paper: bool = True):
    archs = list(ASSIGNED_ARCHS) + (list(PAPER_ARCHS) if include_paper else [])
    for a in archs:
        shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
        if a in PAPER_ARCHS:
            shapes = ["train_4k", "prefill_32k", "decode_32k"]
        for s in shapes:
            yield a, s


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--remat", default="full")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])

    results = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                cell = lower_cell(arch, shape, multi_pod=mp,
                                  compile_=not args.no_compile,
                                  remat=args.remat)
            except Exception as e:  # noqa: BLE001
                cell = {"arch": arch, "shape": shape,
                        "mesh": "multi" if mp else "single",
                        "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:]}
            results.append(cell)
            r = cell.get("roofline", {})
            print(f"[{cell['status']:5s}] {arch:24s} {shape:12s} "
                  f"{cell['mesh']:6s} compile={cell.get('compile_s', '-')}s "
                  f"probe={cell.get('probe_s', '-')}s "
                  f"dom={r.get('dominant', '-')} "
                  f"useful={r.get('useful_flops_ratio', 0):.2f} "
                  f"roofl={r.get('roofline_fraction', 0)*100:.1f}% "
                  f"{cell.get('reason', '')}{cell.get('error', '')}",
                  flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
