"""Observability CLI: run a small paged serve, report metrics or export a
Perfetto timeline.

Drives `PagedContinuousBatcher` with an enabled `Telemetry` registry over a
seeded shared-prefix workload, then either prints the registry + SLO
percentiles (`report`) or writes a Chrome-trace-event JSON (`export`) that
ui.perfetto.dev / chrome://tracing load directly — request lifecycle spans,
per-slot prefill lanes, decode chunks and the KV-occupancy counter track
all on the batcher's one logical timeline.

The `energy` subcommand streams a `BankEnergyMeter` over the same event
stream: per-request/per-tenant energy attribution, wake-cause counters and
the exact Stage-II integral (bit-identical to the offline evaluation), as a
one-shot report, a `--watch` live dashboard, or a Perfetto export with
bank-state timeline lanes and energy counter tracks (`--out`).

Usage:
    PYTHONPATH=src python -m repro.launch.obs report --arch dsr1d_qwen_1_5b
    PYTHONPATH=src python -m repro.launch.obs export --arch dsr1d_qwen_1_5b \
        --requests 4 --new-tokens 8 --slots 2 --out obs_trace.json
    PYTHONPATH=src python -m repro.launch.obs energy --meter 32,8,0.9,conservative \
        --rate 6 --horizon 8 --watch
"""
from __future__ import annotations

import argparse
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced, resolve_arch
from repro.models import build_model
from repro.obs import Telemetry, export_chrome_trace
from repro.serve import PagedContinuousBatcher, Request
from repro.traffic.generators import (LengthModel, generate_workload,
                                      materialize_tokens)


def run_serve(args, meter=None) -> tuple:
    """One telemetry-enabled paged serve; returns (tel, batcher, done)."""
    cfg = reduced(resolve_arch(args.arch), layers=args.layers)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    lengths = LengthModel(prompt_mean=16.0, prompt_sigma=0.4,
                          output_mean=args.new_tokens, max_len=96)
    specs = generate_workload("chat_sysprompt", rate=4.0,
                              horizon_s=float(args.requests), seed=args.seed,
                              lengths=lengths, prefix_len=args.prefix_len,
                              sharing=args.sharing)[:args.requests]
    tokens = materialize_tokens(specs, cfg.vocab_size, seed=args.seed)

    tel = Telemetry(enabled=True)        # spans on; clock -> batcher sim time
    cb = PagedContinuousBatcher(
        model, params, num_slots=args.slots, page_size=args.page_size,
        num_pages=args.num_pages, chunk_steps=args.chunk_steps,
        attn_backend="ref", prefix_cache=args.prefix, telemetry=tel,
        meter=meter)
    for s, toks in zip(specs, tokens):
        tenant = None if s.prefix_id is None else f"tenant{s.prefix_id}"
        cb.submit(Request(rid=s.rid, tokens=np.asarray(toks),
                          max_new_tokens=max(s.output_len, 2),
                          tenant=tenant))
    done = cb.run()
    return tel, cb, done


def run_energy(args) -> None:
    """The `energy` subcommand: stream a meter over a serve or a model-free
    sim, then report attribution (and optionally watch/export)."""
    from repro.core.gating import evaluate
    from repro.obs.energy import BankEnergyMeter

    meter = BankEnergyMeter.from_spec(args.meter)
    if args.watch:
        interval = max(float(args.interval), 1e-6)
        orig_record = meter.record
        state = {"next": interval}

        def record(t, *a, **kw):
            orig_record(t, *a, **kw)
            if t >= state["next"]:
                print(meter.format_dashboard(float(t)))
                state["next"] = float(t) + interval
        meter.record = record

    if args.serve:
        tel, cb, done = run_serve(args, meter=meter)
        summary = cb.slo_summary()
        end = cb.occupancy_bundle().total_time
        source_trace = cb.ledger.trace
        n_served = len(done)
    else:
        from repro.traffic.generators import generate, generate_workload
        from repro.traffic.occupancy import (simulate_prefix_traffic,
                                             simulate_traffic)
        cfg = resolve_arch(args.arch)
        lengths = LengthModel(max_len=args.max_len)
        if args.workload == "plain":
            reqs = generate("poisson", args.rate, args.horizon,
                            seed=args.seed, lengths=lengths)
            sim = simulate_traffic(cfg, reqs, num_slots=args.slots,
                                   max_len=args.max_len, meter=meter)
        else:
            reqs = generate_workload(args.workload, args.rate, args.horizon,
                                     seed=args.seed, lengths=lengths,
                                     prefix_len=args.prefix_len,
                                     sharing=args.sharing)
            sim = simulate_prefix_traffic(cfg, reqs, num_slots=args.slots,
                                          max_len=args.max_len,
                                          seed=args.seed, meter=meter)
        summary = None
        end = sim.total_time
        source_trace = sim.trace
        n_served = len(reqs)

    rep = meter.report(end)
    # exactness receipt: the streamed integral against the offline scalar
    # reference on the source trace (not the meter's own mirror)
    dur, occ = source_trace.occupancy_series(end, use="needed")
    ref = evaluate(dur, occ, capacity=meter.capacity, banks=meter.banks,
                   policy=meter.policy, n_reads=0, n_writes=0,
                   char=meter.char)
    exact = (rep.result.e_leak == ref.e_leak
             and rep.result.e_sw == ref.e_sw
             and rep.result.n_transitions == ref.n_transitions)
    print(f"metered {n_served} requests over {end:.3f}s "
          f"({meter.n_events} ledger events)")
    print()
    print(rep.format())
    print(f"  exact vs offline gating.evaluate: "
          f"{'MATCH (bit-identical f64)' if exact else 'MISMATCH'}")
    if not exact:
        raise SystemExit(1)
    if summary is not None:
        print()
        print(summary.format())
    if args.out:
        export_chrome_trace(args.out, meter=meter, end_time=end)
        print(f"\nwrote {args.out} ({meter.banks} bank-state lanes + energy "
              f"counters) — load it at ui.perfetto.dev")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("report", "export"):
        p = sub.add_parser(name)
        p.add_argument("--arch", default="dsr1d_qwen_1_5b")
        p.add_argument("--layers", type=int, default=2,
                       help="reduced-config layer count (CPU-sized)")
        p.add_argument("--requests", type=int, default=8)
        p.add_argument("--new-tokens", type=int, default=8)
        p.add_argument("--slots", type=int, default=2)
        p.add_argument("--page-size", type=int, default=8)
        p.add_argument("--num-pages", type=int, default=64)
        p.add_argument("--chunk-steps", type=int, default=4)
        p.add_argument("--prefix", action="store_true",
                       help="enable the prefix cache (adds COW/eviction "
                            "spans and the dual kv_logical track)")
        p.add_argument("--prefix-len", type=int, default=24)
        p.add_argument("--sharing", type=int, default=4)
        p.add_argument("--seed", type=int, default=0)
        if name == "export":
            p.add_argument("--out", default="obs_trace.json")
    pe = sub.add_parser(
        "energy", help="streaming bank-energy meter: report, live "
                       "dashboard (--watch) or Perfetto export (--out)")
    pe.add_argument("--arch", default="dsr1d_qwen_1_5b")
    pe.add_argument("--meter", default="32,8,0.9,conservative",
                    metavar="C,B[,alpha[,policy]]",
                    help="meter candidate: capacity [MiB], banks, alpha, "
                         "policy")
    pe.add_argument("--serve", action="store_true",
                    help="drive the real paged serve (reduced model) "
                         "instead of the model-free traffic simulator")
    pe.add_argument("--workload", default="chat_sysprompt",
                    choices=["plain", "chat_sysprompt", "fewshot",
                             "agentic_fanout"])
    pe.add_argument("--rate", type=float, default=6.0)
    pe.add_argument("--horizon", type=float, default=8.0)
    pe.add_argument("--slots", type=int, default=4)
    pe.add_argument("--max-len", type=int, default=512)
    pe.add_argument("--sharing", type=int, default=4)
    pe.add_argument("--prefix-len", type=int, default=128)
    pe.add_argument("--seed", type=int, default=0)
    pe.add_argument("--watch", action="store_true",
                    help="print the live dashboard as the stream advances")
    pe.add_argument("--interval", type=float, default=1.0,
                    help="--watch refresh interval [sim s]")
    pe.add_argument("--out", default=None,
                    help="also export a Perfetto trace with bank-state "
                         "lanes + energy counter tracks")
    # serve-path knobs (reduced model)
    pe.add_argument("--layers", type=int, default=2)
    pe.add_argument("--requests", type=int, default=8)
    pe.add_argument("--new-tokens", type=int, default=8)
    pe.add_argument("--page-size", type=int, default=8)
    pe.add_argument("--num-pages", type=int, default=64)
    pe.add_argument("--chunk-steps", type=int, default=4)
    pe.add_argument("--prefix", action="store_true")
    args = ap.parse_args()

    if args.cmd == "energy":
        run_energy(args)
        return

    tel, cb, done = run_serve(args)
    summary = cb.slo_summary()
    print(f"served {len(done)} requests on {args.slots} slots "
          f"({cb.stats.chunks} chunks, {cb.stats.decode_steps} decode steps)")

    if args.cmd == "report":
        print()
        print(tel.format())
        print()
        print(summary.format())
        return

    bundle = cb.occupancy_bundle()
    export_chrome_trace(args.out, tel, traces=bundle.traces.values(),
                        end_time=bundle.total_time,
                        other_data={"slo": asdict(summary),
                                    "counters": tel.snapshot()["counters"]})
    print(f"wrote {args.out} ({len(tel.spans)} spans, "
          f"{len(bundle.traces)} counter tracks) — load it at "
          f"ui.perfetto.dev or chrome://tracing")
    print(f"ttft p99 = {summary.ttft_p99_s:.4f}s, "
          f"tbt p99 = {summary.tbt_p99_s:.4f}s over "
          f"{summary.n_requests} requests")


if __name__ == "__main__":
    main()
