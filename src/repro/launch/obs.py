"""Observability CLI: run a small paged serve, report metrics or export a
Perfetto timeline.

Drives `PagedContinuousBatcher` with an enabled `Telemetry` registry over a
seeded shared-prefix workload, then either prints the registry + SLO
percentiles (`report`) or writes a Chrome-trace-event JSON (`export`) that
ui.perfetto.dev / chrome://tracing load directly — request lifecycle spans,
per-slot prefill lanes, decode chunks and the KV-occupancy counter track
all on the batcher's one logical timeline.

Usage:
    PYTHONPATH=src python -m repro.launch.obs report --arch dsr1d_qwen_1_5b
    PYTHONPATH=src python -m repro.launch.obs export --arch dsr1d_qwen_1_5b \
        --requests 4 --new-tokens 8 --slots 2 --out obs_trace.json
"""
from __future__ import annotations

import argparse
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced, resolve_arch
from repro.models import build_model
from repro.obs import Telemetry, export_chrome_trace
from repro.serve import PagedContinuousBatcher, Request
from repro.traffic.generators import (LengthModel, generate_workload,
                                      materialize_tokens)


def run_serve(args) -> tuple:
    """One telemetry-enabled paged serve; returns (tel, batcher, done)."""
    cfg = reduced(resolve_arch(args.arch), layers=args.layers)
    model = build_model(cfg, compute_dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))

    lengths = LengthModel(prompt_mean=16.0, prompt_sigma=0.4,
                          output_mean=args.new_tokens, max_len=96)
    specs = generate_workload("chat_sysprompt", rate=4.0,
                              horizon_s=float(args.requests), seed=args.seed,
                              lengths=lengths, prefix_len=args.prefix_len,
                              sharing=args.sharing)[:args.requests]
    tokens = materialize_tokens(specs, cfg.vocab_size, seed=args.seed)

    tel = Telemetry(enabled=True)        # spans on; clock -> batcher sim time
    cb = PagedContinuousBatcher(
        model, params, num_slots=args.slots, page_size=args.page_size,
        num_pages=args.num_pages, chunk_steps=args.chunk_steps,
        attn_backend="ref", prefix_cache=args.prefix, telemetry=tel)
    for s, toks in zip(specs, tokens):
        cb.submit(Request(rid=s.rid, tokens=np.asarray(toks),
                          max_new_tokens=max(s.output_len, 2)))
    done = cb.run()
    return tel, cb, done


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("report", "export"):
        p = sub.add_parser(name)
        p.add_argument("--arch", default="dsr1d_qwen_1_5b")
        p.add_argument("--layers", type=int, default=2,
                       help="reduced-config layer count (CPU-sized)")
        p.add_argument("--requests", type=int, default=8)
        p.add_argument("--new-tokens", type=int, default=8)
        p.add_argument("--slots", type=int, default=2)
        p.add_argument("--page-size", type=int, default=8)
        p.add_argument("--num-pages", type=int, default=64)
        p.add_argument("--chunk-steps", type=int, default=4)
        p.add_argument("--prefix", action="store_true",
                       help="enable the prefix cache (adds COW/eviction "
                            "spans and the dual kv_logical track)")
        p.add_argument("--prefix-len", type=int, default=24)
        p.add_argument("--sharing", type=int, default=4)
        p.add_argument("--seed", type=int, default=0)
        if name == "export":
            p.add_argument("--out", default="obs_trace.json")
    args = ap.parse_args()

    tel, cb, done = run_serve(args)
    summary = cb.slo_summary()
    print(f"served {len(done)} requests on {args.slots} slots "
          f"({cb.stats.chunks} chunks, {cb.stats.decode_steps} decode steps)")

    if args.cmd == "report":
        print()
        print(tel.format())
        print()
        print(summary.format())
        return

    bundle = cb.occupancy_bundle()
    export_chrome_trace(args.out, tel, traces=bundle.traces.values(),
                        end_time=bundle.total_time,
                        other_data={"slo": asdict(summary),
                                    "counters": tel.snapshot()["counters"]})
    print(f"wrote {args.out} ({len(tel.spans)} spans, "
          f"{len(bundle.traces)} counter tracks) — load it at "
          f"ui.perfetto.dev or chrome://tracing")
    print(f"ttft p99 = {summary.ttft_p99_s:.4f}s, "
          f"tbt p99 = {summary.tbt_p99_s:.4f}s over "
          f"{summary.n_requests} requests")


if __name__ == "__main__":
    main()
