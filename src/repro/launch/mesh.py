"""Production mesh factory.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax init,
and smoke tests must keep seeing 1 device.

Production topology: one pod = 16x16 = 256 chips, axes ("data", "model");
multi-pod adds a leading "pod" axis (2 x 256 = 512 chips). Designed so DP
spans ("pod","data") — the slowest collectives (cross-pod) carry only
gradient all-reduces, while TP stays inside the pod's fast ICI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate mesh over whatever devices exist (CPU smoke tests,
    elastic re-mesh on partial failures)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
