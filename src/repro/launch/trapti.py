"""TRAPTI co-design CLI — the paper's two-stage flow as a framework command.

Usage:
    PYTHONPATH=src python -m repro.launch.trapti --arch dsr1d-qwen-1.5b
    PYTHONPATH=src python -m repro.launch.trapti --arch qwen2-7b \
        --seq 4096 --scheduler mempeak --policy drowsy --json out.json
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_arch, list_archs
from repro.core.candidates import Candidate, evaluate_candidates
from repro.core.energy import assemble_energy
from repro.core.explorer import min_capacity_mib, sweep
from repro.core.sensitivity import policy_sensitivity
from repro.core.workload import build_decode_graph, build_graph
from repro.sim.accelerator import baseline_accelerator, multilevel_accelerator
from repro.sim.engine import find_min_sram, simulate
from repro.sim.pss import simulate_decode

MIB = 2**20


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="dsr1d-qwen-1.5b",
                    help=f"one of {list_archs()}")
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--phase", choices=["prefill", "decode"],
                    default="prefill")
    ap.add_argument("--decode-batch", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=0,
                    help="simulate a decode *horizon* of this many steps "
                         "(0 = single decode step / prefill as before)")
    ap.add_argument("--fidelity", choices=["exact", "pss", "auto"],
                    default="exact",
                    help="Stage-I decode-horizon engine: pss/auto probe a "
                         "few context lengths and tile the periodic steady "
                         "state; exact runs the DES per step. pss/auto "
                         "imply --phase decode")
    ap.add_argument("--memoize-layers", action="store_true",
                    help="replay structurally identical decoder layers "
                         "inside the DES (timestamps exact to float "
                         "translation error)")
    ap.add_argument("--scheduler", choices=["fifo", "mempeak"],
                    default="fifo")
    ap.add_argument("--policy", choices=["conservative", "aggressive",
                                         "drowsy"], default="conservative")
    ap.add_argument("--multilevel", action="store_true")
    ap.add_argument("--banks", type=int, nargs="+",
                    default=[1, 2, 4, 8, 16, 32])
    ap.add_argument("--sensitivity", action="store_true")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "numpy", "ref", "pallas", "interpret"],
                    help="batched Stage-II engine backend")
    ap.add_argument("--prune", action="store_true",
                    help="lower-bound prune before exact grid evaluation")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.fidelity != "exact" and args.phase != "decode":
        print(f"--fidelity {args.fidelity} targets the decode phase; "
              f"switching --phase decode")
        args.phase = "decode"
    if args.fidelity != "exact" and args.decode_steps <= 0:
        args.decode_steps = 64

    # ---- Stage I: size the SRAM, extract the trace --------------------------
    accel = (multilevel_accelerator(64) if args.multilevel
             else baseline_accelerator(128))
    if args.phase == "decode" and args.decode_steps > 0:
        # decode horizon: PSS probe-and-tile (or exact per-step) Stage I
        sim = simulate_decode(
            cfg, accel, start_ctx=args.seq, steps=args.decode_steps,
            batch=args.decode_batch, fidelity=args.fidelity,
            policy=args.scheduler, memoize_layers=args.memoize_layers)
        mib = next(m.capacity for m in accel.memories
                   if m.name == "sram") // MIB
        energy = assemble_energy(sim, accel)
        n_ev = sum(t.n_events for t in sim.traces.values())
        print(f"workload: {sim.graph_name}  "
              f"{sim.total_macs/1e12:.2f} TMACs over {sim.steps} steps")
        print(f"Stage I [fidelity={sim.fidelity}]: "
              f"t={sim.total_time*1e3:.1f} ms  "
              f"probes={len(sim.probes)}/{sim.steps} steps  "
              f"events={n_ev}  E_onchip={energy.total:.1f} J  "
              f"write-backs={sim.writebacks}"
              + (f"  [fallback: {sim.fallback_reason}]"
                 if sim.fallback_reason else ""))
    else:
        if args.phase == "decode":
            graph = build_decode_graph(cfg, context_len=args.seq,
                                       batch=args.decode_batch)
        else:
            graph = build_graph(cfg, M=args.seq, subops=4)
        print(f"workload: {graph.name}  {graph.total_macs()/1e12:.2f} "
              f"TMACs, {len(graph.ops)} ops, weights "
              f"{graph.total_weight_bytes()/MIB:.0f} MiB")
        if args.multilevel:
            sim = simulate(graph, accel, policy=args.scheduler,
                           memoize_layers=args.memoize_layers)
            mib = 64
        else:
            mib, sim = find_min_sram(graph, accel, lo_mib=16, hi_mib=256,
                                     step_mib=16)
            if args.scheduler != "fifo" or args.memoize_layers:
                sim = simulate(graph, accel.with_sram_capacity(mib * MIB),
                               policy=args.scheduler,
                               memoize_layers=args.memoize_layers)
        energy = assemble_energy(sim, accel)
        print(f"Stage I [{args.scheduler}]: t={sim.total_time*1e3:.1f} ms  "
              f"util={sim.pe_utilization*100:.1f}%  "
              f"E_onchip={energy.total:.1f} J  min SRAM={mib} MiB  "
              f"write-backs={sim.writebacks}")

    # horizon mode runs at the accelerator's fixed SRAM (no bisection), so
    # min_sram_mib would be misleading there; report the capacity instead
    horizon = args.phase == "decode" and args.decode_steps > 0
    report = {"arch": args.arch, "seq": args.seq, "phase": args.phase,
              "scheduler": args.scheduler, "fidelity": args.fidelity,
              "decode_steps": args.decode_steps,
              "min_sram_mib": None if horizon else mib,
              "sram_capacity_mib": mib,
              "time_ms": sim.total_time * 1e3,
              "energy_onchip_j": energy.total, "memories": {}}

    # ---- Stage II: banking + gating per on-chip memory ----------------------
    for mem in sim.traces:
        if mem == "dram":
            continue
        trace = sim.traces[mem]
        if trace.peak_needed() == 0:
            continue
        lo = min_capacity_mib(trace.peak_needed())
        table = sweep(sim, mem_name=mem, capacities_mib=[lo],
                      banks=tuple(args.banks), backend=args.backend,
                      prune=args.prune)
        best = table.best()
        print(f"\nStage II [{mem}] peak={trace.peak_needed()/MIB:.1f} MiB:")
        print(table.format())
        line = (f"--> {mem}: C={best.capacity_mib} MiB, B={best.banks} "
                f"({best.delta_e_pct:+.1f}% E, {best.delta_a_pct:+.1f}% A)")
        if args.policy == "drowsy":
            dur, occ = trace.occupancy_series(sim.total_time, use="needed")
            res = evaluate_candidates(
                dur, occ, [Candidate(best.capacity_mib * MIB, best.banks,
                                     policy="drowsy")],
                n_reads=sim.access.n_reads(mem),
                n_writes=sim.access.n_writes(mem), backend=args.backend)
            dr = res.drowsy_result(0)
            gain = (1 - dr.e_total / best.result.e_total) * 100
            line += (f"  drowsy: {dr.e_total*1e3:.1f} mJ "
                     f"({gain:+.1f}% vs off-only)")
        print(line)
        report["memories"][mem] = {
            "peak_mib": trace.peak_needed() / MIB,
            "best_capacity_mib": best.capacity_mib,
            "best_banks": best.banks,
            "best_delta_e_pct": best.delta_e_pct,
        }

        if args.sensitivity and mem == "sram":
            dur, occ = trace.occupancy_series(sim.total_time, use="needed")
            sens = policy_sensitivity(
                dur, occ, capacity=best.capacity_mib * MIB,
                banks=best.banks, n_reads=sim.access.n_reads(mem),
                n_writes=sim.access.n_writes(mem), backend=args.backend)
            print("    sensitivity (E_tot mJ):")
            for k, row in sens.items():
                vals = " ".join(f"{p}:{v*1e3:.1f}" for p, v in row.items())
                print(f"      {k:10s} {vals}")
            report["sensitivity"] = {
                k: {str(p): v for p, v in row.items()}
                for k, row in sens.items()}

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
