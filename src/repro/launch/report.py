"""Render dry-run JSON into the EXPERIMENTS.md §Dry-run / §Roofline tables.

Usage: PYTHONPATH=src python -m repro.launch.report results/dryrun_baseline.json
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}G"


def dryrun_table(cells) -> str:
    out = ["| arch | shape | mesh | status | compile s | peak HBM/dev | "
           "coll bytes/dev | notes |",
           "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        r = c.get("roofline", {})
        peak = r.get("peak_memory_per_device")
        coll = r.get("coll_bytes_per_device")
        note = c.get("reason", "")
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['status']} | "
            f"{c.get('compile_s', '-')} | {fmt_bytes(peak)} | "
            f"{fmt_bytes(coll)} | {note} |")
    return "\n".join(out)


def roofline_table(cells) -> str:
    out = ["| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | "
           "dominant | useful | t_ideal ms | roofl% |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["status"] != "OK":
            continue
        r = c["roofline"]
        ideal = max(r["model_flops_total"] / r["chips"] / 197e12 * 1e3,
                    r.get("t_min_memory_ms", 0.0))
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{r['t_compute_ms']:.1f} | {r['t_memory_ms']:.1f} | "
            f"{r['t_collective_ms']:.1f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {ideal:.1f} | "
            f"{r['roofline_fraction']*100:.1f} |")
    return "\n".join(out)


def summarize(cells) -> str:
    ok = [c for c in cells if c["status"] == "OK"]
    skip = [c for c in cells if c["status"] == "SKIP"]
    fail = [c for c in cells if c["status"] == "FAIL"]
    doms = {}
    for c in ok:
        doms[c["roofline"]["dominant"]] = doms.get(
            c["roofline"]["dominant"], 0) + 1
    worst = sorted(ok, key=lambda c: c["roofline"]["roofline_fraction"])[:5]
    most_coll = sorted(ok, key=lambda c: -c["roofline"]["t_collective_ms"])[:5]
    lines = [f"cells: OK={len(ok)} SKIP={len(skip)} FAIL={len(fail)}",
             f"dominant terms: {doms}",
             "worst roofline fraction: "
             + ", ".join(f"{c['arch']}/{c['shape']}/{c['mesh']}"
                         f"={c['roofline']['roofline_fraction']*100:.1f}%"
                         for c in worst),
             "most collective-bound: "
             + ", ".join(f"{c['arch']}/{c['shape']}/{c['mesh']}"
                         f"={c['roofline']['t_collective_ms']:.0f}ms"
                         for c in most_coll)]
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1]
    with open(path) as f:
        cells = json.load(f)
    print("## §Dry-run\n")
    print(summarize(cells))
    print()
    print(dryrun_table(cells))
    print("\n## §Roofline\n")
    print(roofline_table(cells))


if __name__ == "__main__":
    main()
