"""Serving-traffic campaign CLI — traffic as a first-class TRAPTI workload.

Sweeps traffic intensity x model x (C, B) and reports online-controller vs
offline-oracle vs no-gating energy under *identical* request streams, plus a
Stage-II banking sweep run directly on the traffic-generated trace. The MHA
reference (gpt2-xl) is always included next to the requested models, so every
report carries the paper's MHA-vs-GQA comparison under load.

Usage:
    PYTHONPATH=src python -m repro.launch.traffic \
        --model dsr1d_qwen_1_5b --arrival poisson --rate 4 --seed 0
    PYTHONPATH=src python -m repro.launch.traffic \
        --arrival bursty --rate 2 8 --horizon 20 --json out.json
"""
from __future__ import annotations

import argparse
import json

from repro.configs import resolve_arch
from repro.core.explorer import MIB, min_capacity_mib, sweep
from repro.traffic.campaign import DEFAULT_BANKS, CampaignReport, run_campaign
from repro.traffic.controller import ControllerConfig, ForecastConfig
from repro.traffic.generators import LengthModel

MHA_REFERENCE = "gpt2-xl"

KV_DTYPES = ["fp32", "bf16", "fp16", "int8", "fp8"]


def build_report_dict(report: CampaignReport) -> dict:
    rows = []
    for r in report.rows:
        c = r.comparison
        row = {
            "arch": r.scenario.arch, "arrival": r.scenario.arrival,
            "rate": r.scenario.rate, "seed": r.scenario.seed,
            "kv_dtype": r.scenario.kv_dtype,
            "capacity_mib": r.capacity_mib, "banks": r.banks,
            "peak_mib": r.peak_mib, "mean_mib": r.mean_mib,
            "e_none_j": c.none.e_total, "e_oracle_j": c.oracle.e_total,
            "e_online_j": c.online.e_total,
            "online_vs_none_pct": c.online_vs_none_pct,
            "online_vs_oracle_pct": c.online_vs_oracle_pct,
            "wake_violations": c.online.wake_violations,
            "stall_s": c.online.stall_s,
            "p95_latency_s": r.p95_latency_s,
        }
        if r.scenario.speculate_k is not None:
            row.update({
                "speculate_k": r.scenario.speculate_k,
                "spec_acceptance": r.scenario.spec_acceptance,
                "draft_kv_frac": r.scenario.draft_kv_frac,
            })
        if c.forecast is not None:
            row.update({
                "e_forecast_j": c.forecast.e_total,
                "forecast_vs_oracle_pct": c.forecast_vs_oracle_pct,
                "forecast_wake_violations": c.forecast.wake_violations,
                "forecast_stall_s": c.forecast.stall_s,
                "forecast_pre_wakes": c.forecast.pre_wakes,
                "forecast_early_wake_s": c.forecast.early_wake_s,
            })
        if r.energy is not None:
            e = r.energy
            row["energy"] = {
                "meter_capacity_mib": e.result.capacity / MIB,
                "meter_banks": e.result.banks,
                "meter_alpha": e.result.alpha,
                "meter_policy": e.result.policy,
                "e_total_j": e.result.e_total,
                "e_leak_j": e.result.e_leak,
                "e_sw_j": e.result.e_sw,
                "live_e_j": e.live_e_j,
                "floor_j": e.floor_j,
                "stall_s": e.stall_s,
                "wakes": dict(e.wakes),
                "j_per_request_p50_p90_p99": list(e.j_per_request),
                "tenant_j": {str(k): v for k, v in e.tenant_j.items()},
            }
        rows.append(row)
    return {"rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", nargs="+", default=["dsr1d-qwen-1.5b"],
                    help="arch name(s); '_' spellings accepted "
                         "(dsr1d_qwen_1_5b == dsr1d-qwen-1.5b)")
    ap.add_argument("--arrival", nargs="+", default=["poisson"],
                    choices=["poisson", "bursty", "diurnal"])
    ap.add_argument("--workload", default="plain",
                    choices=["plain", "chat_sysprompt", "fewshot",
                             "agentic_fanout"],
                    help="shared-prefix workload family; non-plain runs the "
                         "page-granular prefix-sharing simulator and sweeps "
                         "the grid against PHYSICAL occupancy")
    ap.add_argument("--prefix-len", type=int, default=512,
                    help="mean shared-prefix length [tokens]")
    ap.add_argument("--sharing", type=int, default=8,
                    help="sharing factor (expected requests per prefix; "
                         "fan-out width for agentic_fanout)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size [tokens] for shared workloads")
    ap.add_argument("--speculate", type=int, default=None, metavar="K",
                    help="draft K tokens per round through the model-free "
                         "speculative-decoding simulator (page-granular "
                         "burst/rollback occupancy, both KV lanes); "
                         "plain workload only")
    ap.add_argument("--spec-acceptance", type=float, default=0.7,
                    help="per-draft-token acceptance probability for "
                         "--speculate")
    ap.add_argument("--draft", "--draft-kv-frac", dest="draft_kv_frac",
                    type=float, default=0.5,
                    help="draft lane cost as a fraction of the target "
                         "(KV bytes per page and compute per step; 0.5 = "
                         "half-depth self-speculation)")
    ap.add_argument("--kv-dtype", nargs="+", default=["bf16"],
                    choices=KV_DTYPES,
                    help="KV-cache dtype(s); more than one runs the "
                         "campaign once per dtype on identical request "
                         "streams and prints the quantized-KV "
                         "energy frontier")
    ap.add_argument("--rate", nargs="+", type=float, default=[4.0],
                    help="mean request rate(s) [req/s]")
    ap.add_argument("--seed", nargs="+", type=int, default=[0])
    ap.add_argument("--horizon", type=float, default=30.0)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--capacity", nargs="+", type=int, default=None,
                    help="capacities [MiB]; default: derived from each "
                         "trace's peak")
    ap.add_argument("--banks", nargs="+", type=int,
                    default=list(DEFAULT_BANKS))
    ap.add_argument("--alpha", type=float, default=0.9)
    ap.add_argument("--hysteresis", type=float, default=2.0,
                    help="online gate-off threshold, x break-even time")
    ap.add_argument("--controller", default="reactive",
                    choices=["reactive", "forecast"],
                    help="'forecast' adds the PSS-forecast pre-wake "
                         "controller as a fourth leg next to "
                         "reactive/oracle/none")
    ap.add_argument("--forecast-window", type=float, default=2.0,
                    help="trailing affine-fit window [s] for the forecast "
                         "controller")
    ap.add_argument("--forecast-lead", type=float, default=None,
                    help="pre-wake lead horizon [s]; default window/20")
    ap.add_argument("--resample-dt", type=float, default=None,
                    help="coarsen traces to this grid [s] before evaluation")
    ap.add_argument("--no-mha-ref", action="store_true",
                    help="skip the always-on gpt2-xl MHA reference")
    ap.add_argument("--fast-backend", default="auto",
                    choices=["auto", "numpy", "ref", "pallas", "interpret"],
                    help="lower-bound grid backend")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "numpy", "ref", "pallas", "interpret"],
                    help="exact batched-engine backend (oracle/none legs)")
    ap.add_argument("--prune", action="store_true",
                    help="prune the (C, B) grid with the lower bound "
                         "before exact evaluation")
    ap.add_argument("--fidelity", default="auto",
                    choices=["exact", "pss", "auto"],
                    help="traffic-simulator fast path: pss/auto fast-forward "
                         "uneventful lockstep stretches (bit-identical); "
                         "exact steps every iteration")
    ap.add_argument("--meter", default=None, metavar="C,B[,alpha[,policy]]",
                    help="stream a BankEnergyMeter over every scenario's "
                         "trace (C in MiB); adds per-request/per-tenant "
                         "energy attribution and wake-cause counters to "
                         "the report and --json rows")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    try:
        archs = [resolve_arch(m).name for m in args.model]
    except KeyError as e:
        ap.error(str(e))
    if not args.no_mha_ref and MHA_REFERENCE not in archs:
        archs = [MHA_REFERENCE] + archs
    # dedupe, keep order
    archs = list(dict.fromkeys(archs))

    kv_dtypes = list(dict.fromkeys(args.kv_dtype))
    print(f"traffic campaign: models={archs} arrivals={args.arrival} "
          f"rates={args.rate} seeds={args.seed} horizon={args.horizon}s "
          f"slots={args.slots} max_len={args.max_len} "
          f"kv_dtype={kv_dtypes}")

    fcfg = (ForecastConfig(window_s=args.forecast_window,
                           lead_s=args.forecast_lead)
            if args.controller == "forecast" else None)
    reports = {}
    for dt in kv_dtypes:
        reports[dt] = run_campaign(
            archs, arrivals=args.arrival, rates=args.rate, seeds=args.seed,
            horizon_s=args.horizon, num_slots=args.slots,
            max_len=args.max_len,
            capacities_mib=args.capacity, banks=args.banks,
            ctrl=ControllerConfig(alpha=args.alpha,
                                  hysteresis_multiple=args.hysteresis),
            fcfg=fcfg,
            lengths=LengthModel(max_len=args.max_len),
            resample_dt=args.resample_dt, fast_backend=args.fast_backend,
            backend=args.backend, prune=args.prune, fidelity=args.fidelity,
            workload=args.workload, prefix_len=args.prefix_len,
            sharing=args.sharing, page_size=args.page_size, kv_dtype=dt,
            speculate_k=args.speculate, spec_acceptance=args.spec_acceptance,
            draft_kv_frac=args.draft_kv_frac, meter_spec=args.meter)
    report = reports[kv_dtypes[0]]

    if args.workload != "plain":
        print(f"\n# prefix sharing ({args.workload}, sharing={args.sharing}, "
              f"prefix~{args.prefix_len} tok): logical vs physical occupancy")
        for (arch, tkey), sim in sorted(report.sims.items()):
            tr = sim.bundle.traces["kv"]
            lg = sim.bundle.traces["kv_logical"]
            st = sim.stats
            phys, logi = tr.peak_needed(), lg.peak_needed()
            print(f"  {arch:>20} {tkey[0]}@{tkey[1]:g}/s seed={tkey[2]}: "
                  f"peak {logi / MIB:.1f} -> {phys / MIB:.1f} MiB "
                  f"({logi / max(phys, 1):.2f}x), hits "
                  f"{st.prefix_hits}/{st.admitted}, "
                  f"{st.prefix_tokens_reused} tok reused, "
                  f"{st.cow_splits} COW, {st.evicted_pages} pages evicted")

    if args.speculate is not None:
        print(f"\n# speculative decoding (k={args.speculate}, "
              f"acceptance={args.spec_acceptance:g}, "
              f"draft={args.draft_kv_frac:g}x): burst/rollback occupancy")
        for (arch, tkey), sim in sorted(report.sims.items()):
            st = sim.stats
            V = args.speculate + 1
            toks_per_round = (st.accepted_tokens / st.spec_rounds
                              if st.spec_rounds else 0.0)
            print(f"  {arch:>20} {tkey[0]}@{tkey[1]:g}/s seed={tkey[2]}: "
                  f"{st.spec_rounds} rounds, "
                  f"{toks_per_round:.2f}/{V} tok/round accepted "
                  f"(rate {st.acceptance_rate:.2f}), "
                  f"{st.rolled_back_pages} pages rolled back, "
                  f"peak {sim.trace.peak_needed() / MIB:.1f} MiB")

    legs = ("online reactive+forecast controllers"
            if fcfg is not None else "online controller")
    print(f"\n# {legs} vs offline oracle vs no gating")
    print(report.format())
    if not report.rows:
        print("  (no rows: every requested --capacity sits below the traffic "
              "peak; drop --capacity to derive it from the trace)")

    print("\n# best (C, B) per scenario by online energy")
    for r in sorted(report.best_per_scenario(),
                    key=lambda r: (r.scenario.traffic_key, r.scenario.arch)):
        c = r.comparison
        print(f"  {r.scenario.arch:>20} {r.scenario.arrival}@"
              f"{r.scenario.rate:g}/s seed={r.scenario.seed}: "
              f"C={r.capacity_mib} MiB B={r.banks}  peak={r.peak_mib:.1f} MiB  "
              f"{c.format()}")

    # ---- MHA vs GQA headline under identical traffic ------------------------
    # group best rows by traffic key so each comparison really uses the same
    # request stream for both architectures
    by_traffic = {}
    for r in report.best_per_scenario():
        by_traffic.setdefault(r.scenario.traffic_key, {})[r.scenario.arch] = r
    for tkey, by_arch in sorted(by_traffic.items()):
        ref = by_arch.get(MHA_REFERENCE)
        if ref is None or len(by_arch) < 2:
            continue
        for a, r in sorted(by_arch.items()):
            if a == MHA_REFERENCE:
                continue
            print(f"\n# {a} vs {MHA_REFERENCE} under identical traffic "
                  f"({tkey[0]}@{tkey[1]:g}/s seed={tkey[2]}): "
                  f"peak {ref.peak_mib / max(r.peak_mib, 1e-9):.2f}x lower, "
                  f"online energy {ref.e_online / max(r.e_online, 1e-12):.2f}x"
                  f" lower")

    # ---- Stage II runs unmodified on the traffic trace ----------------------
    print("\n# Stage-II sweep() on the traffic-generated trace")
    for (arch, tkey), sim in report.sims.items():
        if arch != archs[-1]:
            continue
        table = sweep(sim.bundle, mem_name="kv",
                      max_capacity_mib=max(
                          128, int(sim.trace.peak_needed() / MIB) + 16))
        print(table.format())
        break

    # ---- quantized-KV energy frontier ---------------------------------------
    # every dtype leg saw the identical request stream; Stage II is swept at
    # the capacity the WIDEST dtype's trace needs, so shrinking bytes shows
    # up as gating headroom (dB1% = banked+gated energy vs monolithic B=1)
    # rather than as a smaller memory
    if len(reports) > 1:
        wide = max(kv_dtypes,
                   key=lambda d: reports[d].rows[0].scenario.kv_dtype_bytes
                   if reports[d].rows else 0)
        print(f"\n# quantized-KV energy frontier (Stage-II at the "
              f"{wide}-trace capacity)")
        for (arch, tkey), wide_sim in sorted(reports[wide].sims.items()):
            cap_mib = max(min_capacity_mib(wide_sim.trace.peak_needed()), 16)
            print(f"  {arch} {tkey[0]}@{tkey[1]:g}/s seed={tkey[2]} "
                  f"(C={cap_mib} MiB):")
            print(f"    {'kv_dtype':>8} {'B/el':>4} {'peak[MiB]':>9} "
                  f"{'E_online[mJ]':>12} {'dNone%':>7} {'E_bank[mJ]':>10} "
                  f"{'dB1%':>7}")
            for dt in kv_dtypes:
                rep = reports[dt]
                best = {(r.scenario.arch, r.scenario.traffic_key): r
                        for r in rep.best_per_scenario()}.get((arch, tkey))
                sim = rep.sims.get((arch, tkey))
                if best is None or sim is None:
                    continue
                brow = sweep(sim.bundle, mem_name="kv",
                             capacities_mib=[cap_mib]).best()
                print(f"    {dt:>8} {best.scenario.kv_dtype_bytes:>4} "
                      f"{best.peak_mib:>9.1f} {best.e_online * 1e3:>12.2f} "
                      f"{best.comparison.online_vs_none_pct:>+7.1f} "
                      f"{brow.result.e_total * 1e3:>10.2f} "
                      f"{brow.delta_e_pct:>+7.1f}")

    if args.json:
        payload = build_report_dict(report) if len(reports) == 1 else {
            "rows": [row for dt in kv_dtypes
                     for row in build_report_dict(reports[dt])["rows"]]}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
