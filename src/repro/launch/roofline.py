"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Terms per (arch x shape x mesh), TPU v5e-class constants:
    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / LINK_BW

HLO_FLOPs / bytes come from compiled.cost_analysis() of the post-SPMD module
(per-device program). collective_bytes is not in cost_analysis: we parse
compiled.as_text() and sum the operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
(operand types are inline in HLO text, so this is exact per-device traffic
entering the collective).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_RESULT_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+([a-z0-9-]+)\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(result: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(result):
        total += _shape_bytes(dt, dims)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by collectives, from post-SPMD HLO text.

    Post-optimization HLO prints operand names without types, so we use the
    result shape (exact for all-reduce / all-to-all / collective-permute;
    bytes received for all-gather). reduce-scatter results are 1/group of the
    wire traffic, so they're scaled by the replica group size. Async pairs
    (-start/-done) are counted once.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _RESULT_RE.search(stripped)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        base = None
        for k in COLLECTIVE_OPS:
            if op == k or op == k + "-start":
                base = k
                break
        if base is None:
            continue
        b = _result_bytes(result_type)
        if base == "reduce-scatter":
            g = _GROUPS_RE.search(stripped)
            if g:
                b *= int(g.group(2))
        out[base] += b
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops_total: float = 0.0
    peak_memory_per_device: Optional[float] = None
    min_bytes_per_device: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips) — remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops_total / total if total else 0.0

    @property
    def t_min_memory(self) -> float:
        """Analytic lower bound on HBM time: bytes that MUST move (weights,
        KV/state, batch io) even with perfect fusion."""
        return self.min_bytes_per_device / HBM_BW

    @property
    def roofline_fraction(self) -> float:
        """Headline score: ideal-time / modeled-bound-time, where ideal time
        is the larger of the useful-FLOPs bound and the mandatory-bytes bound
        (decode is legitimately bandwidth-bound — reading the weights and KV
        once is the roofline, not the MXU)."""
        if self.bound_time <= 0:
            return 0.0
        useful_t = max((self.model_flops_total / self.chips) / PEAK_FLOPS,
                       self.t_min_memory)
        return min(useful_t / self.bound_time, 1.0)

    def row(self) -> str:
        return (f"{self.arch:24s} {self.shape:12s} {self.mesh:6s} "
                f"{self.t_compute*1e3:9.2f} {self.t_memory*1e3:9.2f} "
                f"{self.t_collective*1e3:9.2f} {self.dominant:10s} "
                f"{self.useful_flops_ratio:6.2f} "
                f"{self.roofline_fraction*100:6.1f}%")

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "model_flops_total": self.model_flops_total,
            "t_compute_ms": self.t_compute * 1e3,
            "t_memory_ms": self.t_memory * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_memory_per_device": self.peak_memory_per_device,
            "min_bytes_per_device": self.min_bytes_per_device,
            "t_min_memory_ms": self.t_min_memory * 1e3,
        }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell (6ND train / 2ND inference; MoE uses
    active params; decode processes one token per sequence)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention reads over the KV length
    tokens = shape.global_batch
    flops = 2.0 * n_active * tokens
    if cfg.num_heads:
        ctx = shape.seq_len
        if cfg.local_window:
            kinds = cfg.layer_kinds()
            n_local = sum(1 for k in kinds if k in ("local", "chunked"))
            n_full = sum(1 for k in kinds if k == "full")
            eff_layers = n_full + n_local * min(
                1.0, cfg.local_window / max(ctx, 1))
        else:
            eff_layers = sum(1 for k in cfg.layer_kinds()
                             if k in ("full", "local", "chunked"))
        flops += (4.0 * tokens * ctx * cfg.num_kv_heads * cfg.head_dim
                  * eff_layers)
    return flops


def min_hbm_bytes(cfg, shape, chips: int) -> float:
    """Mandatory per-device HBM traffic per step (perfect-fusion floor)."""
    if shape.kind == "train":
        # fp32 params read+write, m/v read+write, bf16 batch io, one
        # activation checkpoint per layer each way
        w = cfg.param_count() * 4.0 * 6.0
        act = (shape.global_batch * shape.seq_len * cfg.d_model * 2.0
               * cfg.num_layers * 2.0)
        return (w + act) / chips
    w = cfg.active_param_count() * 2.0
    if shape.kind == "prefill":
        act = (shape.global_batch * shape.seq_len * cfg.d_model * 2.0
               * cfg.num_layers)
        return (w + act) / chips
    # decode: weights + the KV cache / recurrent state read once
    kv = 0.0
    if cfg.num_heads:
        for kind in cfg.layer_kinds():
            if kind == "full":
                ctx = shape.seq_len
            elif kind in ("local", "chunked"):
                ctx = min(cfg.local_window or shape.seq_len, shape.seq_len)
            else:
                continue
            kv += 2.0 * shape.global_batch * ctx * cfg.kv_dim * 2.0
    if cfg.ssm is not None:
        s = cfg.ssm
        kv += (shape.global_batch * s.num_heads(cfg.d_model) * s.head_dim
               * s.state_dim * 4.0 * cfg.num_layers)
    return (w + kv) / chips


def build_report(arch_name: str, shape_name: str, mesh_label: str,
                 chips: int, cost: dict, hlo_text: str,
                 model_flops_total: float,
                 peak_mem: Optional[float] = None,
                 min_bytes: float = 0.0) -> RooflineReport:
    coll = collective_bytes(hlo_text)
    return RooflineReport(
        arch=arch_name, shape=shape_name, mesh=mesh_label, chips=chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops_total=model_flops_total,
        peak_memory_per_device=peak_mem,
        min_bytes_per_device=min_bytes)


HEADER = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'t_comp ms':>9} "
          f"{'t_mem ms':>9} {'t_coll ms':>9} {'dominant':10s} {'useful':>6} "
          f"{'roofl%':>7}")
