from repro.train.step import make_train_step, make_eval_step  # noqa: F401
from repro.train.loop import LoopConfig, StragglerMonitor, TrainLoop  # noqa: F401
from repro.train import checkpoint  # noqa: F401
