"""Jitted training step: loss -> grads -> (optional compression) -> AdamW.

Sharding contract (GSPMD does the collectives):
  * params fp32, FSDP+TP sharded per models/sharding.TRAIN_RULES;
  * optimizer state sharded like the params (ZeRO-1: m/v live fully sharded —
    no replica holds a full copy);
  * batch sharded over ("pod","data").
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import AdamW, AdamWState, apply_compression


def make_train_step(model, optimizer: AdamW, compression: str = "none",
                    microbatches: int = 1) -> Callable:
    """Returns step(params, opt_state, batch[, comp_err]) -> (...).

    `microbatches > 1` enables gradient accumulation: the global batch is
    split into chunks scanned sequentially, so peak activation memory scales
    with the chunk size while the optimizer still sees the full-batch mean
    gradient (Perf iteration G1 — fits the 33B-class train cells in HBM).
    """

    def grads_of(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(model.loss)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        chunks = jax.tree.map(split, batch)

        def body(carry, chunk):
            loss_sum, grad_sum = carry
            loss, grads = jax.value_and_grad(model.loss)(params, chunk)
            grad_sum = jax.tree.map(jnp.add, grad_sum, grads)
            return (loss_sum + loss, grad_sum), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        # unroll when the model is in dry-run cost-probe mode so HLO cost
        # analysis counts every chunk (loop bodies are visited once)
        (loss_sum, grad_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), chunks,
            unroll=microbatches if getattr(model, "unroll", False) else 1)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)

    def step(params, opt_state: AdamWState, batch, comp_err=None):
        loss, grads = grads_of(params, batch)
        grads, new_err = apply_compression(grads, compression, comp_err)
        new_params, new_state, metrics = optimizer.update(
            grads, opt_state, params)
        metrics["loss"] = loss
        if compression == "int8ef":
            return new_params, new_state, metrics, new_err
        return new_params, new_state, metrics

    return step


def make_eval_step(model) -> Callable:
    def step(params, batch):
        return model.loss(params, batch)
    return step
