"""Atomic, async, multihost-aware checkpointing.

Layout:  <dir>/step_<N>/
            index.json            (tree structure, shapes, dtypes, metadata)
            p<proc>_l<leaf>.npy   (one file per leaf, per process)

Writes go to a tmp dir + os.rename (atomic on POSIX), so a crash mid-save
never corrupts the latest checkpoint. `AsyncCheckpointer` runs saves on a
background thread (training continues); `latest_step`/`restore` implement
preemption recovery. Each process writes only its addressable leaves — on a
real multihost pod process 0 additionally writes the index.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat], treedef


def save(ckpt_dir: str, step: int, tree: Any,
         metadata: Optional[Dict] = None,
         process_index: Optional[int] = None) -> str:
    proc = jax.process_index() if process_index is None else process_index
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp_p{proc}"
    os.makedirs(tmp, exist_ok=True)

    flat, _ = _leaf_paths(tree)
    index = {"step": int(step), "metadata": metadata or {},
             "leaves": []}
    for i, (kpath, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"p{proc}_l{i:05d}.npy"
        # store raw bytes as uint8 so extension dtypes (bfloat16, ...) survive
        np.save(os.path.join(tmp, fname),
                np.frombuffer(arr.tobytes(), np.uint8))
        index["leaves"].append({"key": kpath, "file": fname,
                                "shape": list(arr.shape),
                                "dtype": str(arr.dtype)})
    if proc == 0:
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
    if os.path.exists(final):
        shutil.rmtree(tmp)            # another process/run already committed
    else:
        os.rename(tmp, final)         # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and "tmp_p" not in name:
            try:
                steps.append(int(name.split("_")[1]))
            except (ValueError, IndexError):
                continue
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            process_index: Optional[int] = None) -> Tuple[int, Any]:
    """Restore into the structure of `tree_like` (shapes validated)."""
    proc = jax.process_index() if process_index is None else process_index
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)
    flat, treedef = _leaf_paths(tree_like)
    assert len(flat) == len(index["leaves"]), \
        f"leaf count mismatch: {len(flat)} vs {len(index['leaves'])}"
    leaves = []
    for (kpath, like), meta in zip(flat, index["leaves"]):
        assert kpath == meta["key"], (kpath, meta["key"])
        raw = np.load(os.path.join(d, meta["file"].replace(
            "p0_", f"p{proc}_") if proc else meta["file"]))
        arr = np.frombuffer(raw.tobytes(), _resolve_dtype(
            meta["dtype"])).reshape(meta["shape"])
        assert list(arr.shape) == list(np.shape(like)), (kpath, arr.shape)
        leaves.append(arr)
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


def _resolve_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def prune(ckpt_dir: str, keep_last: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and "tmp" not in n)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Fire-and-forget background saves; `wait()` joins outstanding work."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save_async(self, step: int, tree: Any,
                   metadata: Optional[Dict] = None) -> None:
        self.wait()
        # device_get on the caller thread so the arrays are snapshot now
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.ckpt_dir, step, host_tree, metadata)
            prune(self.ckpt_dir, self.keep_last)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
