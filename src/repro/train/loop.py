"""Fault-tolerant training loop.

Production behaviors exercised by tests/examples on CPU:
  * resume-from-latest on start (preemption recovery) — with the stateless
    data pipeline this gives bit-exact continuation;
  * async atomic checkpoints every `ckpt_every` steps;
  * straggler monitor: per-step wall time vs a running median — steps slower
    than `straggler_factor` x median are flagged (on a real fleet this feeds
    the scheduler; here it feeds logs + metrics);
  * elastic: batch sharding is re-derived from the devices present at launch.
"""
from __future__ import annotations

import collections
import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.data import DataConfig, SyntheticTokens
from repro.optim import AdamW
from repro.train import checkpoint as ckpt
from repro.train.step import make_train_step


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_window: int = 20
    compression: str = "none"


@dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 20
    times: collections.deque = field(default_factory=lambda:
                                     collections.deque(maxlen=64))
    flagged: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < self.window:
            return False
        med = statistics.median(self.times)
        if dt > self.factor * med:
            self.flagged.append(step)
            return True
        return False


class TrainLoop:
    def __init__(self, model, optimizer: AdamW, data: SyntheticTokens,
                 cfg: LoopConfig, *, jit: bool = True,
                 fail_at_step: Optional[int] = None):
        self.model = model
        self.optimizer = optimizer
        self.data = data
        self.cfg = cfg
        self.fail_at_step = fail_at_step      # fault-injection for tests
        step_fn = make_train_step(model, optimizer, cfg.compression)
        self.step_fn = jax.jit(step_fn) if jit else step_fn
        self.monitor = StragglerMonitor(cfg.straggler_factor,
                                        cfg.straggler_window)
        self.ckpt = ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_last)
        self.history: List[Dict] = []

    # ----------------------------------------------------------------- run
    def run(self, rng: Optional[jax.Array] = None) -> Dict[str, Any]:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        params = self.model.init(rng)
        opt_state = self.optimizer.init(params)
        start = 0

        latest = ckpt.latest_step(self.cfg.ckpt_dir)
        if latest is not None:
            # preemption recovery: restore params + optimizer state + step
            state_like = {"params": params, "opt": opt_state}
            step_restored, tree = ckpt.restore(self.cfg.ckpt_dir, state_like)
            params, opt_state = tree["params"], tree["opt"]
            start = step_restored

        for step in range(start, self.cfg.total_steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                self.ckpt.wait()
                raise RuntimeError(f"injected failure at step {step}")
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            straggle = self.monitor.observe(step, dt)
            self.history.append({"step": step, "loss": loss, "time_s": dt,
                                 "straggler": straggle})
            if (step + 1) % self.cfg.ckpt_every == 0 \
                    or step + 1 == self.cfg.total_steps:
                self.ckpt.save_async(step + 1,
                                     {"params": params, "opt": opt_state},
                                     metadata={"loss": loss})
        self.ckpt.wait()
        return {"params": params, "opt_state": opt_state,
                "history": self.history,
                "stragglers": self.monitor.flagged}
