"""Discrete-event, cycle-level simulator (TRAPTI Stage I).

List-scheduling DES over the workload graph on the accelerator template:

  * ops become ready when every producer has completed;
  * each op runs on one systolic array (matmuls: tiled 128x128 MXU-style time
    model; vector ops: per-array vector unit);
  * every operand is staged in the array's attached on-chip memory — misses
    are fetched from DRAM (or a peer memory in multi-level hierarchies) over
    shared, serialized bandwidth servers (this is where memory-induced stalls
    and port contention come from);
  * the memory manager tracks each tensor as needed/obsolete, evicts LRU
    (obsolete first, matching the paper's policy), and counts capacity-induced
    write-backs of needed tensors;
  * every allocation/transition is recorded into the time-resolved occupancy
    trace — the central Stage-I artifact.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.workload import WorkloadGraph
from repro.sim.accelerator import AcceleratorConfig, MemConfig
from repro.sim.trace import AccessStats, OccupancyTrace, OpStats

REFILL_BYTES = 32 * 1024       # FIFO refill granularity for latency charging


class _BWServer:
    """Per-port bandwidth channels: a transfer occupies the earliest-free
    port at that port's share of bandwidth and pays the access latency once
    per REFILL_BYTES chunk (FIFO refill turnaround)."""

    def __init__(self, cfg: MemConfig):
        self.cfg = cfg
        self.ports = [0.0] * cfg.ports
        self.port_bw = cfg.eff_bw / cfg.ports
        self.busy_time = 0.0

    def transfer(self, t: float, nbytes: int) -> float:
        if nbytes <= 0:
            return t
        chunks = -(-nbytes // REFILL_BYTES)
        dur = nbytes / self.port_bw + chunks * self.cfg.latency_ns * 1e-9
        p = min(range(len(self.ports)), key=lambda i: self.ports[i])
        start = max(t, self.ports[p])
        self.ports[p] = start + dur
        self.busy_time += dur
        return self.ports[p]


class _MemState:
    def __init__(self, cfg: MemConfig):
        self.cfg = cfg
        self.resident: Dict[int, int] = {}        # tid -> bytes
        self.last_touch: Dict[int, float] = {}
        self.needed_bytes = 0
        self.obsolete_bytes = 0
        self.trace = OccupancyTrace(cfg.name, cfg.capacity)
        self.writebacks = 0
        self.writeback_bytes = 0
        self.peak_snapshot: List[Tuple[str, int, str]] = []
        self._peak_seen = 0

    @property
    def used(self) -> int:
        return self.needed_bytes + self.obsolete_bytes


@dataclass
class SimResult:
    graph_name: str
    accel_name: str
    total_time: float
    traces: Dict[str, OccupancyTrace]
    access: AccessStats
    ops: OpStats
    writebacks: int
    writeback_bytes: int
    total_macs: int
    total_vector_ops: int
    dram_traffic_bytes: int
    peak_macs_per_s: float
    peak_snapshots: Dict[str, List[Tuple[str, int, str]]] = field(
        default_factory=dict)
    busy_fraction: float = 0.0

    @property
    def pe_utilization(self) -> float:
        return self.total_macs / (self.total_time * self.peak_macs_per_s)

    def peak_needed(self, mem: str = "sram") -> int:
        return self.traces[mem].peak_needed()


class Engine:
    """`policy` selects the list scheduler:
      * "fifo"    — ready-time order (paper-faithful baseline).
      * "mempeak" — occupancy-aware (beyond-paper): among ops ready by the
        time a unit frees, prefer the one with the smallest net SRAM growth
        (output allocation minus bytes its dying inputs release). This
        drains score/intermediate tensors before producing new ones, cutting
        peak needed occupancy — which Stage II converts into smaller minimum
        SRAM and more gate-eligible banks."""

    def __init__(self, graph: WorkloadGraph, accel: AcceleratorConfig,
                 policy: str = "fifo"):
        assert policy in ("fifo", "mempeak"), policy
        self.g = graph
        self.accel = accel
        self.policy = policy

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        g, accel = self.g, self.accel
        mems = {m.name: _MemState(m) for m in accel.memories}
        bw = {m.name: _BWServer(m) for m in accel.memories}
        dram = accel.dram_name
        access = AccessStats()
        opstats = OpStats()

        # tensor bookkeeping
        remaining = {t.tid: len(t.consumers) for t in g.tensors.values()}
        produced = {t.tid: (t.producer is None) for t in g.tensors.values()}
        # weights / graph inputs start resident in DRAM; set for activations
        # only after a capacity write-back
        in_dram = {t.tid: (t.producer is None) for t in g.tensors.values()}

        pending = {op.oid: sum(0 if produced[i] else 1 for i in op.inputs)
                   for op in g.ops.values()}

        ready: List[Tuple[float, int]] = []
        for op in g.ops.values():
            if pending[op.oid] == 0:
                heapq.heappush(ready, (0.0, op.oid))

        unit_free = [0.0] * accel.sa_count
        unit_mem = list(accel.sa_memory)
        sa_rate = accel.sa_dim * accel.sa_dim * accel.freq_hz
        vpu_rate = accel.vpu_lanes * accel.freq_hz

        def snapshot(mem: _MemState):
            if mem.needed_bytes > mem._peak_seen:
                mem._peak_seen = mem.needed_bytes
                mem.peak_snapshot = [
                    (g.tensors[tid].name, sz, state_bucket(tid))
                    for tid, sz in mem.resident.items()]

        def state_bucket(tid: int) -> str:
            return "needed" if remaining[tid] > 0 or not produced[tid] else "obsolete"

        def add_resident(ms: _MemState, tid: int, t: float):
            if tid in ms.resident:
                ms.last_touch[tid] = t
                return
            sz = g.tensors[tid].size
            ms.resident[tid] = sz
            ms.last_touch[tid] = t
            if state_bucket(tid) == "needed":
                ms.needed_bytes += sz
                ms.trace.event(t, sz, 0)
            else:
                ms.obsolete_bytes += sz
                ms.trace.event(t, 0, sz)
            snapshot(ms)

        def drop_resident(ms: _MemState, tid: int, t: float):
            sz = ms.resident.pop(tid)
            ms.last_touch.pop(tid, None)
            if state_bucket(tid) == "needed":
                ms.needed_bytes -= sz
                ms.trace.event(t, -sz, 0)
            else:
                ms.obsolete_bytes -= sz
                ms.trace.event(t, 0, -sz)

        def find_copy(tid: int, exclude: Optional[str] = None) -> Optional[str]:
            """Preferred source holding tid: any on-chip memory, else DRAM."""
            for name, m in mems.items():
                if name != exclude and tid in m.resident:
                    return name
            t = g.tensors[tid]
            if t.producer is None or in_dram.get(tid, False):
                return dram
            return None

        def evict_for(ms: _MemState, need: int, t: float) -> float:
            """Free `need` bytes; returns time after any write-backs."""
            if ms.used + need <= ms.cfg.capacity:
                return t
            # 1) obsolete victims, LRU order (dead data, free to drop)
            victims = sorted(
                (tid for tid in ms.resident if state_bucket(tid) == "obsolete"),
                key=lambda tid: ms.last_touch.get(tid, 0.0))
            for tid in victims:
                if ms.used + need <= ms.cfg.capacity:
                    break
                drop_resident(ms, tid, t)
            # 2) needed victims: free if a copy exists elsewhere, else write
            #    back to DRAM (counted — the capacity criterion of Stage I)
            if ms.used + need > ms.cfg.capacity:
                victims = sorted(
                    (tid for tid in ms.resident
                     if state_bucket(tid) == "needed"),
                    key=lambda tid: ms.last_touch.get(tid, 0.0))
                for tid in victims:
                    if ms.used + need <= ms.cfg.capacity:
                        break
                    sz = ms.resident[tid]
                    if find_copy(tid, exclude=ms.cfg.name) is None:
                        t = bw[ms.cfg.name].transfer(t, sz)      # SRAM read
                        t = bw[dram].transfer(t, sz)             # DRAM write
                        access.add_read(ms.cfg.name, sz)
                        access.add_write(dram, sz)
                        ms.writebacks += 1
                        ms.writeback_bytes += sz
                        in_dram[tid] = True
                    drop_resident(ms, tid, t)
            return t

        total_macs = 0
        total_vops = 0
        dram_traffic = 0
        end_time = 0.0
        n_done = 0
        busy_total: Dict[int, float] = {}

        pool: List[Tuple[float, int]] = []      # candidates for "mempeak"

        def mem_delta(oid: int) -> int:
            op = g.ops[oid]
            freed = sum(g.tensors[t].size for t in op.inputs
                        if remaining[t] == 1)
            return g.tensors[op.output].size - freed

        while ready or pool:
            if self.policy == "fifo":
                rt, oid = heapq.heappop(ready)
            else:
                # admit everything ready by the time the next unit frees
                horizon = min(unit_free)
                if ready:
                    horizon = max(horizon, ready[0][0])
                while ready and ready[0][0] <= horizon:
                    pool.append(heapq.heappop(ready))
                k = min(range(len(pool)),
                        key=lambda i: (mem_delta(pool[i][1]), pool[i][0],
                                       pool[i][1]))
                rt, oid = pool.pop(k)
            op = g.ops[oid]
            # pick the attached unit that can start earliest
            u = min(range(accel.sa_count),
                    key=lambda i: (max(unit_free[i], rt), i))
            ms = mems[unit_mem[u]]
            t = max(unit_free[u], rt)
            t0_sched = t

            # ---- stage inputs into this unit's memory ----------------------
            in_bytes = 0
            t_mem = t
            for tid in op.inputs:
                sz = g.tensors[tid].size
                in_bytes += sz
                if tid in ms.resident:
                    ms.last_touch[tid] = t
                    continue
                src = find_copy(tid, exclude=ms.cfg.name)
                assert src is not None, \
                    f"lost tensor {g.tensors[tid].name}"
                # Dedicated memories talk only to the shared SRAM (paper
                # Fig. 10): DRAM fetches and DM<->DM hops stage through it,
                # and it keeps the copy as backup storage. This is the data
                # hopping the paper identifies as the multi-level cost.
                if src != "sram" and ms.cfg.name != "sram" and "sram" in mems:
                    stage = mems["sram"]
                    if tid not in stage.resident:
                        t_mem = evict_for(stage, sz, t_mem)
                        t_mem = bw[src].transfer(t_mem, sz)
                        access.add_read(src, sz)
                        if src == dram:
                            dram_traffic += sz
                        t_mem = bw["sram"].transfer(t_mem, sz)
                        access.add_write("sram", sz)
                        add_resident(stage, tid, t_mem)
                    src = "sram"
                t_mem = evict_for(ms, sz, t_mem)
                t_mem = bw[src].transfer(t_mem, sz)
                access.add_read(src, sz)
                if src == dram:
                    dram_traffic += sz
                t_mem = bw[ms.cfg.name].transfer(t_mem, sz)
                access.add_write(ms.cfg.name, sz)
                add_resident(ms, tid, t_mem)

            # ---- allocate output -------------------------------------------
            out_t = g.tensors[op.output]
            t_mem = evict_for(ms, out_t.size, t_mem)

            # ---- operand streaming (SRAM reads into the FIFOs) --------------
            t_stream = bw[ms.cfg.name].transfer(t_mem, in_bytes)
            access.add_read(ms.cfg.name, in_bytes)

            # ---- compute -----------------------------------------------------
            if op.op_type == "matmul":
                R, K, C = op.mnk
                fill = 1.0 + (2.0 * accel.sa_dim) / max(K, 1)
                compute = op.macs / sa_rate * fill
            else:
                compute = op.vector_ops / vpu_rate
            c_start = max(t, t_stream)
            finish = c_start + compute

            # ---- output write (overlapped streaming, charged to BW) ---------
            bw[ms.cfg.name].transfer(finish, out_t.size)
            access.add_write(ms.cfg.name, out_t.size)
            add_resident(ms, op.output, finish)

            unit_free[u] = finish
            busy_total[u] = busy_total.get(u, 0.0) + (finish - t)
            end_time = max(end_time, finish)
            total_macs += op.macs
            total_vops += op.vector_ops
            opstats.add(op.tag, compute, max(0.0, t_stream - t),
                        max(0.0, t - rt))

            # ---- completion: outputs exist; inputs may turn obsolete --------
            produced[op.output] = True
            for tid in op.inputs:
                remaining[tid] -= 1
                if remaining[tid] == 0:
                    for m2 in mems.values():
                        if tid not in m2.resident:
                            continue
                        if (op.op_type == "softmax"
                                and g.tensors[tid].size == out_t.size):
                            # in-place: probabilities overwrite the scores.
                            # The tensor was in the needed bucket until this
                            # very completion event.
                            sz = m2.resident.pop(tid)
                            m2.last_touch.pop(tid, None)
                            m2.needed_bytes -= sz
                            m2.trace.event(finish, -sz, 0)
                            continue
                        sz = m2.resident[tid]
                        m2.needed_bytes -= sz
                        m2.obsolete_bytes += sz
                        m2.trace.event(finish, -sz, sz)
            # output was allocated as needed; fix bucket if it has no readers
            if remaining[op.output] == 0:
                sz = ms.resident.get(op.output)
                if sz is not None:
                    ms.needed_bytes -= sz
                    ms.obsolete_bytes += sz
                    ms.trace.event(finish, -sz, sz)

            for cons in g.tensors[op.output].consumers:
                pending[cons] -= 1
                if pending[cons] == 0:
                    heapq.heappush(ready, (finish, cons))
            n_done += 1

        assert n_done == len(g.ops), (n_done, len(g.ops))
        wb = sum(m.writebacks for m in mems.values())
        wbb = sum(m.writeback_bytes for m in mems.values())
        return SimResult(
            graph_name=g.name, accel_name=accel.name, total_time=end_time,
            traces={name: m.trace for name, m in mems.items()},
            access=access, ops=opstats, writebacks=wb, writeback_bytes=wbb,
            total_macs=total_macs, total_vector_ops=total_vops,
            dram_traffic_bytes=dram_traffic,
            peak_macs_per_s=accel.peak_macs_per_s,
            peak_snapshots={n: m.peak_snapshot for n, m in mems.items()},
            busy_fraction=(sum(busy_total.values())
                           / (accel.sa_count * end_time) if end_time else 0.0))


def simulate(graph: WorkloadGraph, accel: AcceleratorConfig,
             policy: str = "fifo") -> SimResult:
    return Engine(graph, accel, policy=policy).run()


def find_min_sram(graph: WorkloadGraph, accel: AcceleratorConfig,
                  lo_mib: int = 8, hi_mib: int = 256,
                  step_mib: int = 16) -> Tuple[int, SimResult]:
    """Paper's blue loop: smallest SRAM (stepped) with zero capacity-induced
    write-backs; returns (capacity_mib, result at that capacity).

    Write-back count is monotone non-increasing in capacity (a larger SRAM
    strictly relaxes the eviction pressure under the same schedule), so the
    grid scan is a bisection: O(log n) simulations instead of O(n). The
    premise is exact for the "fifo" scheduler used here; capacity-dependent
    timing can in principle reorder a "mempeak" schedule, where this remains
    a first-order assumption."""
    grid = list(range(lo_mib, hi_mib + 1, step_mib)) or [lo_mib]
    if grid[-1] != hi_mib:
        grid.append(hi_mib)          # always probe the stated upper bound
    results: Dict[int, SimResult] = {}

    def run(mib: int) -> SimResult:
        if mib not in results:
            results[mib] = simulate(graph, accel.with_sram_capacity(mib * 2**20))
        return results[mib]

    lo, hi = 0, len(grid) - 1
    if run(grid[hi]).writebacks > 0:          # even the largest still spills
        return grid[hi], run(grid[hi])
    while lo < hi:
        mid = (lo + hi) // 2
        if run(grid[mid]).writebacks == 0:
            hi = mid
        else:
            lo = mid + 1
    return grid[lo], run(grid[lo])
