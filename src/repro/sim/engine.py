"""Discrete-event, cycle-level simulator (TRAPTI Stage I).

List-scheduling DES over the workload graph on the accelerator template:

  * ops become ready when every producer has completed;
  * each op runs on one systolic array (matmuls: tiled 128x128 MXU-style time
    model; vector ops: per-array vector unit);
  * every operand is staged in the array's attached on-chip memory — misses
    are fetched from DRAM (or a peer memory in multi-level hierarchies) over
    shared, serialized bandwidth servers (this is where memory-induced stalls
    and port contention come from);
  * the memory manager tracks each tensor as needed/obsolete, evicts LRU
    (obsolete first, matching the paper's policy), and counts capacity-induced
    write-backs of needed tensors;
  * every allocation/transition is recorded into the time-resolved occupancy
    trace — the central Stage-I artifact.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.workload import WorkloadGraph
from repro.sim.accelerator import AcceleratorConfig, MemConfig
from repro.sim.trace import AccessStats, OccupancyTrace, OpStats

REFILL_BYTES = 32 * 1024       # FIFO refill granularity for latency charging

# Replayed layers shift template-relative times to a new absolute base, so
# memoized timestamps agree with the step-by-step DES only up to float
# translation error (~ulp of the absolute time). Entry-state comparisons use
# the same scale-aware tolerance.
MEMO_REL_TOL = 1e-9


def _close(a: float, b: float, scale: float) -> bool:
    return abs(a - b) <= MEMO_REL_TOL * max(1.0, abs(scale))


class _LayerStructure:
    """Per-layer structural view of the graph for the memoization fast path.

    `cohort` is every tensor that *belongs* to the layer (produced by one of
    its ops, or DRAM-resident with all consumers inside the layer — weights,
    KV caches); `ext` is every boundary tensor (the residual stream from the
    previous layer, shared encoder memory, ...). Two layers whose `sig`
    tuples are equal are isomorphic: op i of one maps to op i of the other,
    cohort/ext entry j to entry j."""

    def __init__(self, g: WorkloadGraph, layer: int, oids: List[int]):
        self.layer = layer
        self.oids = oids
        self.cohort: List[int] = []
        self.ext: List[int] = []
        cohort_idx: Dict[int, int] = {}
        ext_idx: Dict[int, int] = {}
        self.cohort_pos = cohort_idx
        self.ext_pos = ext_idx
        oid_set = set(oids)

        def ref(tid: int) -> Tuple[str, int]:
            t = g.tensors[tid]
            if tid in cohort_idx:
                return ("c", cohort_idx[tid])
            if tid in ext_idx:
                return ("e", ext_idx[tid])
            local = (t.producer in oid_set
                     or (t.producer is None
                         and all(c in oid_set for c in t.consumers)))
            if local:
                cohort_idx[tid] = len(self.cohort)
                self.cohort.append(tid)
                return ("c", cohort_idx[tid])
            ext_idx[tid] = len(self.ext)
            self.ext.append(tid)
            return ("e", ext_idx[tid])

        sig = []
        for oid in oids:
            op = g.ops[oid]
            ins = tuple(ref(t) + (g.tensors[t].size, g.tensors[t].kind)
                        for t in op.inputs)
            out = g.tensors[op.output]
            sig.append((op.op_type, op.tag, op.macs, op.vector_ops, op.mnk,
                        ins, ref(op.output) + (out.size, out.kind,
                                               len(out.consumers))))
        self.sig: Tuple = tuple(sig)


class _LayerRecord:
    """Everything one cleanly-simulated layer mutates, relative to its start
    time t0 — enough to replay an isomorphic layer by pure translation."""

    def __init__(self, layer: int, t0: float):
        self.layer = layer
        self.t0 = t0
        self.valid = True
        self.ops_done = 0
        # entry conditions
        self.heap_pat: List[Tuple[float, int]] = []
        self.needed_entry: Dict[str, int] = {}
        self.port_entry: Dict[str, Tuple[float, ...]] = {}
        self.ext_state: List[Tuple] = []
        self.max_used_delta: Dict[str, int] = {}
        # capacity evictions recorded during the layer (timing-free drops of
        # obsolete / elsewhere-copied tensors). When present, replay demands
        # the full LRU profile of every memory to match at entry, so the
        # eviction decisions provably repeat; write-backs (which cost
        # transfer time) always invalidate the record.
        self.had_drops = False
        self.entry_profile: Dict[str, List[Tuple]] = {}
        self.res_drop: Dict[str, List[Tuple]] = {}
        self.dropped: Dict[str, set] = {}
        # entry snapshots (dropped at finalize)
        self.ev_start: Dict[str, int] = {}
        self.reads0: Dict[str, int] = {}
        self.writes0: Dict[str, int] = {}
        self.busy0: Dict[str, float] = {}
        self.used0: Dict[str, int] = {}
        self.resident0: Dict[str, Dict[int, int]] = {}
        self.touch0: Dict[str, Dict[int, float]] = {}
        self.needed0: Dict[str, int] = {}
        self.obsolete0: Dict[str, int] = {}
        self.unit_busy0: Dict[int, float] = {}
        self.opstats0: Tuple = ()
        self.macs0 = 0
        self.vops0 = 0
        self.dram0 = 0
        # recorded deltas (filled at finalize)
        self.events: Dict[str, Tuple[np.ndarray, List[int], List[int]]] = {}
        self.read_d: Dict[str, int] = {}
        self.write_d: Dict[str, int] = {}
        self.bw_busy_d: Dict[str, float] = {}
        self.ports_exit: Dict[str, List[float]] = {}
        self.units_exit: List[float] = []
        self.unit_busy_d: Dict[int, float] = {}
        self.needed_d: Dict[str, int] = {}
        self.obsolete_d: Dict[str, int] = {}
        self.res_add: Dict[str, List[Tuple[Tuple[str, int], int, float]]] = {}
        self.res_touch: Dict[str, List[Tuple[Tuple[str, int], float]]] = {}
        self.cohort_remaining: List[int] = []
        self.ext_remaining_d: List[int] = []
        self.ext_pushes: List[Tuple[Tuple[str, int], float]] = []
        self.opstats_d: Tuple = ()
        self.macs_d = 0
        self.vops_d = 0
        self.dram_d = 0
        self.rel_end = 0.0


class _BWServer:
    """Per-port bandwidth channels: a transfer occupies the earliest-free
    port at that port's share of bandwidth and pays the access latency once
    per REFILL_BYTES chunk (FIFO refill turnaround)."""

    def __init__(self, cfg: MemConfig):
        self.cfg = cfg
        self.ports = [0.0] * cfg.ports
        self.port_bw = cfg.eff_bw / cfg.ports
        self.busy_time = 0.0

    def transfer(self, t: float, nbytes: int) -> float:
        if nbytes <= 0:
            return t
        chunks = -(-nbytes // REFILL_BYTES)
        dur = nbytes / self.port_bw + chunks * self.cfg.latency_ns * 1e-9
        p = min(range(len(self.ports)), key=lambda i: self.ports[i])
        start = max(t, self.ports[p])
        self.ports[p] = start + dur
        self.busy_time += dur
        return self.ports[p]


class _MemState:
    def __init__(self, cfg: MemConfig):
        self.cfg = cfg
        self.resident: Dict[int, int] = {}        # tid -> bytes
        self.last_touch: Dict[int, float] = {}
        self.needed_bytes = 0
        self.obsolete_bytes = 0
        self.trace = OccupancyTrace(cfg.name, cfg.capacity)
        self.writebacks = 0
        self.writeback_bytes = 0
        self.peak_snapshot: List[Tuple[str, int, str]] = []
        self._peak_seen = 0

    @property
    def used(self) -> int:
        return self.needed_bytes + self.obsolete_bytes


@dataclass
class SimResult:
    graph_name: str
    accel_name: str
    total_time: float
    traces: Dict[str, OccupancyTrace]
    access: AccessStats
    ops: OpStats
    writebacks: int
    writeback_bytes: int
    total_macs: int
    total_vector_ops: int
    dram_traffic_bytes: int
    peak_macs_per_s: float
    peak_snapshots: Dict[str, List[Tuple[str, int, str]]] = field(
        default_factory=dict)
    busy_fraction: float = 0.0
    replayed_layers: int = 0       # layers satisfied from the memo templates

    @property
    def pe_utilization(self) -> float:
        return self.total_macs / (self.total_time * self.peak_macs_per_s)

    def peak_needed(self, mem: str = "sram") -> int:
        return self.traces[mem].peak_needed()


class Engine:
    """`policy` selects the list scheduler:
      * "fifo"    — ready-time order (paper-faithful baseline).
      * "mempeak" — occupancy-aware (beyond-paper): among ops ready by the
        time a unit frees, prefer the one with the smallest net SRAM growth
        (output allocation minus bytes its dying inputs release). This
        drains score/intermediate tensors before producing new ones, cutting
        peak needed occupancy — which Stage II converts into smaller minimum
        SRAM and more gate-eligible banks.

    `memoize_layers` (fifo only) turns on the layer-level fast path: the
    first cleanly-simulated instance of each structurally-identical layer is
    recorded, and later instances whose entry state provably reproduces it —
    same needed occupancy, same boundary-tensor residency, enough capacity
    headroom that no eviction can fire, units idle at the boundary — are
    replayed by time-shifting the recorded sub-trace instead of re-running
    the DES. Occupancy deltas, access counts and event ordering are
    bit-identical to the step-by-step run; absolute timestamps agree up to
    float translation error (MEMO_REL_TOL), which is why the golden/PSS
    probe paths leave it off."""

    def __init__(self, graph: WorkloadGraph, accel: AcceleratorConfig,
                 policy: str = "fifo", memoize_layers: bool = False):
        assert policy in ("fifo", "mempeak"), policy
        self.g = graph
        self.accel = accel
        self.policy = policy
        self.memoize_layers = bool(memoize_layers) and policy == "fifo"
        # why replay attempts missed, by guard name — observability for the
        # fast path (a layer counted here ran through the exact DES instead)
        self.memo_misses: Dict[str, int] = {}

    def _layer_structures(self):
        by_layer: Dict[int, List[int]] = {}
        for op in self.g.ops.values():
            by_layer.setdefault(op.layer, []).append(op.oid)
        structures = {l: _LayerStructure(self.g, l, sorted(oids))
                      for l, oids in by_layer.items()}
        # tid -> (owner layer, cohort index): lets records name *foreign*
        # tensors (older layers' weight slabs picked as eviction victims) in
        # a translation-invariant way: (layer delta, index)
        owner: Dict[int, Tuple[int, int]] = {}
        for l, st in structures.items():
            for i, tid in enumerate(st.cohort):
                owner[tid] = (l, i)
        return structures, owner

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        g, accel = self.g, self.accel
        mems = {m.name: _MemState(m) for m in accel.memories}
        bw = {m.name: _BWServer(m) for m in accel.memories}
        dram = accel.dram_name
        access = AccessStats()
        opstats = OpStats()

        # tensor bookkeeping
        remaining = {t.tid: len(t.consumers) for t in g.tensors.values()}
        produced = {t.tid: (t.producer is None) for t in g.tensors.values()}
        # weights / graph inputs start resident in DRAM; set for activations
        # only after a capacity write-back
        in_dram = {t.tid: (t.producer is None) for t in g.tensors.values()}

        pending = {op.oid: sum(0 if produced[i] else 1 for i in op.inputs)
                   for op in g.ops.values()}

        ready: List[Tuple[float, int]] = []
        for op in g.ops.values():
            if pending[op.oid] == 0:
                heapq.heappush(ready, (0.0, op.oid))

        unit_free = [0.0] * accel.sa_count
        unit_mem = list(accel.sa_memory)
        sa_rate = accel.sa_dim * accel.sa_dim * accel.freq_hz
        vpu_rate = accel.vpu_lanes * accel.freq_hz

        def snapshot(mem: _MemState):
            if mem.needed_bytes > mem._peak_seen:
                mem._peak_seen = mem.needed_bytes
                mem.peak_snapshot = [
                    (g.tensors[tid].name, sz, state_bucket(tid))
                    for tid, sz in mem.resident.items()]

        def state_bucket(tid: int) -> str:
            return "needed" if remaining[tid] > 0 or not produced[tid] else "obsolete"

        def add_resident(ms: _MemState, tid: int, t: float):
            if tid in ms.resident:
                ms.last_touch[tid] = t
                return
            sz = g.tensors[tid].size
            ms.resident[tid] = sz
            ms.last_touch[tid] = t
            if state_bucket(tid) == "needed":
                ms.needed_bytes += sz
                ms.trace.event(t, sz, 0)
            else:
                ms.obsolete_bytes += sz
                ms.trace.event(t, 0, sz)
            snapshot(ms)
            if rec is not None:
                d = ms.used - rec.used0.get(ms.cfg.name, ms.used)
                if d > rec.max_used_delta.get(ms.cfg.name, 0):
                    rec.max_used_delta[ms.cfg.name] = d

        def drop_resident(ms: _MemState, tid: int, t: float):
            if rec is not None and rec.valid:
                # capacity eviction: replayable iff it costs no time (the
                # trace delta is recorded with the other events; write-backs
                # invalidate separately). Victims are re-derived at finalize
                # as layer-relative refs, so isomorphic layers evict their
                # own same-shaped ancestors.
                rec.dropped.setdefault(ms.cfg.name, set()).add(tid)
                rec.had_drops = True
            sz = ms.resident.pop(tid)
            ms.last_touch.pop(tid, None)
            if state_bucket(tid) == "needed":
                ms.needed_bytes -= sz
                ms.trace.event(t, -sz, 0)
            else:
                ms.obsolete_bytes -= sz
                ms.trace.event(t, 0, -sz)

        def find_copy(tid: int, exclude: Optional[str] = None) -> Optional[str]:
            """Preferred source holding tid: any on-chip memory, else DRAM."""
            for name, m in mems.items():
                if name != exclude and tid in m.resident:
                    return name
            t = g.tensors[tid]
            if t.producer is None or in_dram.get(tid, False):
                return dram
            return None

        def evict_for(ms: _MemState, need: int, t: float) -> float:
            """Free `need` bytes; returns time after any write-backs."""
            if ms.used + need <= ms.cfg.capacity:
                return t
            # 1) obsolete victims, LRU order (dead data, free to drop)
            victims = sorted(
                (tid for tid in ms.resident if state_bucket(tid) == "obsolete"),
                key=lambda tid: ms.last_touch.get(tid, 0.0))
            for tid in victims:
                if ms.used + need <= ms.cfg.capacity:
                    break
                drop_resident(ms, tid, t)
            # 2) needed victims: free if a copy exists elsewhere, else write
            #    back to DRAM (counted — the capacity criterion of Stage I)
            if ms.used + need > ms.cfg.capacity:
                victims = sorted(
                    (tid for tid in ms.resident
                     if state_bucket(tid) == "needed"),
                    key=lambda tid: ms.last_touch.get(tid, 0.0))
                for tid in victims:
                    if ms.used + need <= ms.cfg.capacity:
                        break
                    sz = ms.resident[tid]
                    if find_copy(tid, exclude=ms.cfg.name) is None:
                        if rec is not None:
                            rec.valid = False    # write-backs cost time
                        t = bw[ms.cfg.name].transfer(t, sz)      # SRAM read
                        t = bw[dram].transfer(t, sz)             # DRAM write
                        access.add_read(ms.cfg.name, sz)
                        access.add_write(dram, sz)
                        ms.writebacks += 1
                        ms.writeback_bytes += sz
                        in_dram[tid] = True
                    drop_resident(ms, tid, t)
            return t

        total_macs = 0
        total_vops = 0
        dram_traffic = 0
        end_time = 0.0
        n_done = 0
        busy_total: Dict[int, float] = {}

        pool: List[Tuple[float, int]] = []      # candidates for "mempeak"

        def mem_delta(oid: int) -> int:
            op = g.ops[oid]
            freed = sum(g.tensors[t].size for t in op.inputs
                        if remaining[t] == 1)
            return g.tensors[op.output].size - freed

        # ---- layer memoization (fifo-only fast path) ------------------------
        memo, owner_map = (self._layer_structures() if self.memoize_layers
                           else (None, {}))
        templates: Dict[Tuple, List[_LayerRecord]] = {}
        sig_fails: Dict[Tuple, int] = {}    # recordings that never templated
        cur_layer: object = object()            # sentinel != any layer id
        rec: Optional[_LayerRecord] = None
        replayed = 0

        def residency_of(tid: int) -> Tuple:
            return tuple(sorted(
                (name, state_bucket(tid)) for name, m2 in mems.items()
                if tid in m2.resident))

        def ref_of(tid: int, l) -> Optional[Tuple]:
            """Translation-invariant name for `tid` as seen from layer l."""
            st = memo.get(l)
            if st is not None:
                i = st.cohort_pos.get(tid)
                if i is not None:
                    return ("c", i)
                i = st.ext_pos.get(tid)
                if i is not None:
                    return ("e", i)
            own = owner_map.get(tid)
            if own is not None and isinstance(l, int):
                return ("d", l - own[0], own[1])
            return ("t", tid)      # unowned (multi-layer DRAM tensor): by id

        def lru_profile(ms: _MemState, l) -> List[Tuple]:
            """Residents in eviction order — (ref, bucket, size), sorted the
            way `evict_for` sorts victims (last_touch, insertion rank)."""
            pos = {tid: i for i, tid in enumerate(ms.resident)}
            order = sorted(ms.resident,
                           key=lambda tid: (ms.last_touch.get(tid, 0.0),
                                            pos[tid]))
            return [(ref_of(tid, l), state_bucket(tid), ms.resident[tid])
                    for tid in order]

        def units_idle_at(t0: float) -> bool:
            return all(u <= t0 + MEMO_REL_TOL * max(1.0, t0)
                       for u in unit_free)

        def open_record() -> None:
            """Start recording the layer at the top of the ready heap, if its
            boundary is clean (heap homogeneous, units idle)."""
            nonlocal rec
            rec = None
            st = memo.get(cur_layer)
            if st is None or not ready:
                return
            if sig_fails.get(st.sig, 0) >= 3:
                return      # e.g. write-back bound: recording is pure cost
            t0 = ready[0][0]
            if any(g.ops[o].layer != cur_layer for _, o in ready):
                return
            if not units_idle_at(t0):
                return
            r = _LayerRecord(cur_layer, t0)
            base = st.oids[0]
            r.heap_pat = sorted((x - t0, o - base) for x, o in ready)
            for name, m2 in mems.items():
                r.needed_entry[name] = m2.needed_bytes
                r.needed0[name] = m2.needed_bytes
                r.obsolete0[name] = m2.obsolete_bytes
                r.used0[name] = m2.used
                r.ev_start[name] = m2.trace.n_events
                r.resident0[name] = dict(m2.resident)
                r.touch0[name] = dict(m2.last_touch)
                r.port_entry[name] = tuple(sorted(
                    max(p - t0, 0.0) for p in bw[name].ports))
                r.busy0[name] = bw[name].busy_time
                r.entry_profile[name] = lru_profile(m2, cur_layer)
            r.reads0 = dict(access.reads_bytes)
            r.writes0 = dict(access.writes_bytes)
            r.unit_busy0 = dict(busy_total)
            r.opstats0 = (dict(opstats.compute), dict(opstats.memory),
                          dict(opstats.idle), dict(opstats.count))
            r.macs0, r.vops0, r.dram0 = total_macs, total_vops, dram_traffic
            for tid in st.ext:
                r.ext_state.append((remaining[tid], in_dram.get(tid, False),
                                    residency_of(tid)))
            rec = r

        def finalize_record() -> None:
            """Diff the finished layer against its entry snapshots and store
            it as a replay template (discard on any exactness hazard)."""
            nonlocal rec
            r, rec = rec, None
            if r is None:
                return
            st = memo[r.layer]
            if not _finalize(r, st):
                sig_fails[st.sig] = sig_fails.get(st.sig, 0) + 1

        def _finalize(r: _LayerRecord, st: _LayerStructure) -> bool:
            if not r.valid or r.ops_done != len(st.oids):
                return False
            t0 = r.t0
            for name, m2 in mems.items():
                et, edn, edo = m2.trace.events_since(r.ev_start[name])
                r.events[name] = (et - t0, edn, edo)
                r.read_d[name] = (access.reads_bytes.get(name, 0)
                                  - r.reads0.get(name, 0))
                r.write_d[name] = (access.writes_bytes.get(name, 0)
                                   - r.writes0.get(name, 0))
                r.bw_busy_d[name] = bw[name].busy_time - r.busy0[name]
                r.ports_exit[name] = [p - t0 for p in bw[name].ports]
                r.needed_d[name] = m2.needed_bytes - r.needed0[name]
                r.obsolete_d[name] = m2.obsolete_bytes - r.obsolete0[name]
                add, touch = [], []
                ent = r.resident0[name]
                dropped = r.dropped.get(name, set())
                for tid, sz in m2.resident.items():
                    if tid in ent:
                        lt = m2.last_touch.get(tid)
                        if lt is not None and lt != r.touch0[name].get(tid):
                            i = st.ext_pos.get(tid)
                            if i is None:
                                return False   # foreign touch: no replay
                            touch.append((i, lt - t0))
                        continue
                    if tid in st.cohort_pos:
                        ref = ("c", st.cohort_pos[tid])
                    elif tid in st.ext_pos:
                        ref = ("e", st.ext_pos[tid])
                    else:
                        return False           # foreign tensor staged in
                    add.append((ref, sz, m2.last_touch.get(tid, t0) - t0))
                gone = []
                for tid in ent:
                    if tid not in m2.resident:
                        if tid not in dropped:
                            return False   # entry tensor vanished untracked
                        gone.append(ref_of(tid, r.layer))
                r.res_add[name] = add
                r.res_touch[name] = touch
                r.res_drop[name] = gone
            r.cohort_remaining = [remaining[tid] for tid in st.cohort]
            r.ext_remaining_d = [remaining[tid] - r.ext_state[i][0]
                                 for i, tid in enumerate(st.ext)]
            r.opstats_d = tuple(
                {k: cur[k] - prev.get(k, 0) for k in cur}
                for cur, prev in zip(
                    (opstats.compute, opstats.memory, opstats.idle,
                     opstats.count), r.opstats0))
            r.macs_d = total_macs - r.macs0
            r.vops_d = total_vops - r.vops0
            r.dram_d = dram_traffic - r.dram0
            r.units_exit = [u - t0 for u in unit_free]
            r.unit_busy_d = {
                u: busy_total.get(u, 0.0) - r.unit_busy0.get(u, 0.0)
                for u in range(accel.sa_count)}
            r.resident0 = r.touch0 = {}      # free the entry snapshots
            r.reads0 = r.writes0 = {}
            r.opstats0 = ()
            lst = templates.setdefault(st.sig, [])
            if len(lst) < 4:
                lst.append(r)
            return True

        def miss(reason: str) -> bool:
            self.memo_misses[reason] = self.memo_misses.get(reason, 0) + 1
            return False

        def try_replay() -> bool:
            nonlocal end_time, total_macs, total_vops, dram_traffic, \
                n_done, replayed
            if not ready:
                return False
            l = g.ops[ready[0][1]].layer
            st = memo.get(l)
            if st is None:
                return False
            cands = templates.get(st.sig)
            if not cands:
                return miss("no-template")
            if any(g.ops[o].layer != l for _, o in ready):
                return miss("mixed-heap")
            t0 = ready[0][0]
            if not units_idle_at(t0):
                return miss("units-busy")
            base = st.oids[0]
            pat = sorted((x - t0, o - base) for x, o in ready)
            ext_now = [(remaining[tid], in_dram.get(tid, False),
                        residency_of(tid)) for tid in st.ext]
            r = None
            why = "entry-state"
            for cand in cands:
                if len(cand.heap_pat) != len(pat) or any(
                        p[1] != q[1] or not _close(p[0], q[0], t0)
                        for p, q in zip(pat, cand.heap_pat)):
                    why = "heap-pattern"
                    continue
                if ext_now != cand.ext_state:
                    why = "ext-state"
                    continue
                ok = True
                for name, m2 in mems.items():
                    if m2.needed_bytes != cand.needed_entry[name]:
                        ok, why = False, "needed-entry"
                        break
                    if (m2.used + cand.max_used_delta.get(name, 0)
                            > m2.cfg.capacity):
                        ok, why = False, "headroom"
                        break
                    if cand.had_drops and (
                            m2.obsolete_bytes != cand.obsolete0[name]
                            or lru_profile(m2, l)
                            != cand.entry_profile[name]):
                        # the template evicted: victim selection repeats
                        # only from an identical relative LRU state
                        ok, why = False, "lru-profile"
                        break
                    pe = tuple(sorted(
                        max(p - t0, 0.0) for p in bw[name].ports))
                    ce = cand.port_entry[name]
                    if len(pe) != len(ce) or any(
                            not _close(a, b, t0) for a, b in zip(pe, ce)):
                        ok, why = False, "port-state"
                        break
                if ok:
                    r = cand
                    break
            if r is None:
                return miss(why)

            def mtid(ref: Tuple) -> int:
                kind, i = ref[0], ref[1]
                if kind == "c":
                    return st.cohort[i]
                if kind == "e":
                    return st.ext[i]
                if kind == "d":
                    return memo[l - i].cohort[ref[2]]
                return i               # ("t", tid): identity

            ready.clear()
            for name, m2 in mems.items():
                rel_t, dn, do = r.events[name]
                if len(rel_t):
                    m2.trace.extend(rel_t + t0, dn, do)
                if r.read_d[name]:
                    access.add_read(name, r.read_d[name])
                if r.write_d[name]:
                    access.add_write(name, r.write_d[name])
                bw[name].busy_time += r.bw_busy_d[name]
                bw[name].ports = [t0 + p for p in r.ports_exit[name]]
                m2.needed_bytes += r.needed_d[name]
                m2.obsolete_bytes += r.obsolete_d[name]
                for ref in r.res_drop.get(name, ()):
                    tid = mtid(ref)
                    del m2.resident[tid]
                    m2.last_touch.pop(tid, None)
                for ref, sz, lt in r.res_add[name]:
                    tid = mtid(ref)
                    m2.resident[tid] = sz
                    m2.last_touch[tid] = t0 + lt
                for i, lt in r.res_touch[name]:
                    m2.last_touch[st.ext[i]] = t0 + lt
            for i, tid in enumerate(st.cohort):
                remaining[tid] = r.cohort_remaining[i]
            for i, tid in enumerate(st.ext):
                remaining[tid] += r.ext_remaining_d[i]
            for o in st.oids:
                produced[g.ops[o].output] = True
            for u in range(accel.sa_count):
                unit_free[u] = t0 + r.units_exit[u]
                d = r.unit_busy_d.get(u, 0.0)
                if d:
                    busy_total[u] = busy_total.get(u, 0.0) + d
            for dst, dd in zip((opstats.compute, opstats.memory,
                                opstats.idle, opstats.count), r.opstats_d):
                for k, v in dd.items():
                    dst[k] = dst.get(k, 0) + v
            total_macs += r.macs_d
            total_vops += r.vops_d
            dram_traffic += r.dram_d
            end_time = max(end_time, t0 + r.rel_end)
            for ref, rel_f in r.ext_pushes:
                tid = mtid(ref)
                for cons in g.tensors[tid].consumers:
                    if g.ops[cons].layer == l:
                        continue
                    pending[cons] -= 1
                    if pending[cons] == 0:
                        heapq.heappush(ready, (t0 + rel_f, cons))
            n_done += len(st.oids)
            replayed += 1
            return True

        while ready or pool:
            if self.policy == "fifo":
                if memo is not None:
                    if g.ops[ready[0][1]].layer != cur_layer:
                        finalize_record()
                        while try_replay():
                            pass
                        if not ready:
                            break
                        cur_layer = g.ops[ready[0][1]].layer
                        open_record()
                rt, oid = heapq.heappop(ready)
            else:
                # admit everything ready by the time the next unit frees
                horizon = min(unit_free)
                if ready:
                    horizon = max(horizon, ready[0][0])
                while ready and ready[0][0] <= horizon:
                    pool.append(heapq.heappop(ready))
                k = min(range(len(pool)),
                        key=lambda i: (mem_delta(pool[i][1]), pool[i][0],
                                       pool[i][1]))
                rt, oid = pool.pop(k)
            op = g.ops[oid]
            # pick the attached unit that can start earliest
            u = min(range(accel.sa_count),
                    key=lambda i: (max(unit_free[i], rt), i))
            ms = mems[unit_mem[u]]
            t = max(unit_free[u], rt)
            t0_sched = t

            # ---- stage inputs into this unit's memory ----------------------
            in_bytes = 0
            t_mem = t
            for tid in op.inputs:
                sz = g.tensors[tid].size
                in_bytes += sz
                if tid in ms.resident:
                    ms.last_touch[tid] = t
                    continue
                src = find_copy(tid, exclude=ms.cfg.name)
                assert src is not None, \
                    f"lost tensor {g.tensors[tid].name}"
                # Dedicated memories talk only to the shared SRAM (paper
                # Fig. 10): DRAM fetches and DM<->DM hops stage through it,
                # and it keeps the copy as backup storage. This is the data
                # hopping the paper identifies as the multi-level cost.
                if src != "sram" and ms.cfg.name != "sram" and "sram" in mems:
                    stage = mems["sram"]
                    if tid not in stage.resident:
                        t_mem = evict_for(stage, sz, t_mem)
                        t_mem = bw[src].transfer(t_mem, sz)
                        access.add_read(src, sz)
                        if src == dram:
                            dram_traffic += sz
                        t_mem = bw["sram"].transfer(t_mem, sz)
                        access.add_write("sram", sz)
                        add_resident(stage, tid, t_mem)
                    src = "sram"
                t_mem = evict_for(ms, sz, t_mem)
                t_mem = bw[src].transfer(t_mem, sz)
                access.add_read(src, sz)
                if src == dram:
                    dram_traffic += sz
                t_mem = bw[ms.cfg.name].transfer(t_mem, sz)
                access.add_write(ms.cfg.name, sz)
                add_resident(ms, tid, t_mem)

            # ---- allocate output -------------------------------------------
            out_t = g.tensors[op.output]
            t_mem = evict_for(ms, out_t.size, t_mem)

            # ---- operand streaming (SRAM reads into the FIFOs) --------------
            t_stream = bw[ms.cfg.name].transfer(t_mem, in_bytes)
            access.add_read(ms.cfg.name, in_bytes)

            # ---- compute -----------------------------------------------------
            if op.op_type == "matmul":
                R, K, C = op.mnk
                fill = 1.0 + (2.0 * accel.sa_dim) / max(K, 1)
                compute = op.macs / sa_rate * fill
            else:
                compute = op.vector_ops / vpu_rate
            c_start = max(t, t_stream)
            finish = c_start + compute

            # ---- output write (overlapped streaming, charged to BW) ---------
            bw[ms.cfg.name].transfer(finish, out_t.size)
            access.add_write(ms.cfg.name, out_t.size)
            add_resident(ms, op.output, finish)

            unit_free[u] = finish
            busy_total[u] = busy_total.get(u, 0.0) + (finish - t)
            end_time = max(end_time, finish)
            total_macs += op.macs
            total_vops += op.vector_ops
            opstats.add(op.tag, compute, max(0.0, t_stream - t),
                        max(0.0, t - rt))
            if rec is not None:
                if op.layer != rec.layer:
                    rec.valid = False    # interleaved layers: not replayable
                else:
                    rec.ops_done += 1
                    rec.rel_end = max(rec.rel_end, finish - rec.t0)

            # ---- completion: outputs exist; inputs may turn obsolete --------
            produced[op.output] = True
            for tid in op.inputs:
                remaining[tid] -= 1
                if remaining[tid] == 0:
                    for m2 in mems.values():
                        if tid not in m2.resident:
                            continue
                        if (op.op_type == "softmax"
                                and g.tensors[tid].size == out_t.size):
                            # in-place: probabilities overwrite the scores.
                            # The tensor was in the needed bucket until this
                            # very completion event.
                            sz = m2.resident.pop(tid)
                            m2.last_touch.pop(tid, None)
                            m2.needed_bytes -= sz
                            m2.trace.event(finish, -sz, 0)
                            continue
                        sz = m2.resident[tid]
                        m2.needed_bytes -= sz
                        m2.obsolete_bytes += sz
                        m2.trace.event(finish, -sz, sz)
            # output was allocated as needed; fix bucket if it has no readers
            if remaining[op.output] == 0:
                sz = ms.resident.get(op.output)
                if sz is not None:
                    ms.needed_bytes -= sz
                    ms.obsolete_bytes += sz
                    ms.trace.event(finish, -sz, sz)

            if rec is not None and rec.valid and op.layer == rec.layer and \
                    any(g.ops[c].layer != rec.layer
                        for c in g.tensors[op.output].consumers):
                i = memo[rec.layer].cohort_pos.get(op.output)
                if i is None:
                    rec.valid = False
                else:
                    rec.ext_pushes.append((("c", i), finish - rec.t0))
            for cons in g.tensors[op.output].consumers:
                pending[cons] -= 1
                if pending[cons] == 0:
                    heapq.heappush(ready, (finish, cons))
            n_done += 1

        assert n_done == len(g.ops), (n_done, len(g.ops))
        wb = sum(m.writebacks for m in mems.values())
        wbb = sum(m.writeback_bytes for m in mems.values())
        from repro.obs.telemetry import default_registry
        tel = default_registry()
        tel.counter("sim.des.runs").inc()
        tel.counter("sim.des.ops").inc(n_done)
        tel.counter("sim.des.layers_replayed").inc(replayed)
        tel.counter("sim.des.writebacks").inc(wb)
        for reason, k in self.memo_misses.items():
            tel.counter(f"sim.des.memo_miss.{reason}").inc(k)
        return SimResult(
            graph_name=g.name, accel_name=accel.name, total_time=end_time,
            traces={name: m.trace for name, m in mems.items()},
            access=access, ops=opstats, writebacks=wb, writeback_bytes=wbb,
            total_macs=total_macs, total_vector_ops=total_vops,
            dram_traffic_bytes=dram_traffic,
            peak_macs_per_s=accel.peak_macs_per_s,
            peak_snapshots={n: m.peak_snapshot for n, m in mems.items()},
            busy_fraction=(sum(busy_total.values())
                           / (accel.sa_count * end_time) if end_time else 0.0),
            replayed_layers=replayed)


def simulate(graph: WorkloadGraph, accel: AcceleratorConfig,
             policy: str = "fifo", memoize_layers: bool = False) -> SimResult:
    return Engine(graph, accel, policy=policy,
                  memoize_layers=memoize_layers).run()


def find_min_sram(graph: WorkloadGraph, accel: AcceleratorConfig,
                  lo_mib: int = 8, hi_mib: int = 256,
                  step_mib: int = 16) -> Tuple[int, SimResult]:
    """Paper's blue loop: smallest SRAM (stepped) with zero capacity-induced
    write-backs; returns (capacity_mib, result at that capacity).

    Write-back count is monotone non-increasing in capacity (a larger SRAM
    strictly relaxes the eviction pressure under the same schedule), so the
    grid scan is a bisection: O(log n) simulations instead of O(n). The
    premise is exact for the "fifo" scheduler used here; capacity-dependent
    timing can in principle reorder a "mempeak" schedule, where this remains
    a first-order assumption."""
    grid = list(range(lo_mib, hi_mib + 1, step_mib)) or [lo_mib]
    if grid[-1] != hi_mib:
        grid.append(hi_mib)          # always probe the stated upper bound
    results: Dict[int, SimResult] = {}

    def run(mib: int) -> SimResult:
        if mib not in results:
            results[mib] = simulate(graph, accel.with_sram_capacity(mib * 2**20))
        return results[mib]

    lo, hi = 0, len(grid) - 1
    if run(grid[hi]).writebacks > 0:          # even the largest still spills
        return grid[hi], run(grid[hi])
    while lo < hi:
        mid = (lo + hi) // 2
        if run(grid[mid]).writebacks == 0:
            hi = mid
        else:
            lo = mid + 1
    return grid[lo], run(grid[lo])
