"""Accelerator templates for the Stage-I simulator (paper Fig. 4 / Fig. 10).

Baseline: four 128x128 systolic arrays @ 1 GHz (one 8-bit MAC/cycle/PE =
65.5 TMAC/s peak), per-array row/column FIFOs, one shared on-chip SRAM
(128 MiB, 512-bit interface, 4 ports, 32 ns) over a 2 GiB DRAM (2 ports,
80 ns). The multi-level variant (Sec. IV-D) adds two dedicated memories, each
private to a pair of systolic arrays, with the shared SRAM as backup/staging.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MemConfig:
    name: str
    capacity: int                 # bytes
    ports: int
    width_bits: int
    latency_ns: float
    # effective fraction of peak port bandwidth actually sustained (FIFO
    # bubbles, bank conflicts, refill turnaround). Calibrated in DESIGN.md §8.
    bw_derate: float = 1.0

    @property
    def peak_bw(self) -> float:   # bytes/sec at 1 GHz port clock
        return self.ports * (self.width_bits / 8) * 1e9

    @property
    def eff_bw(self) -> float:
        return self.peak_bw * self.bw_derate


def sram_latency_ns(capacity: int) -> float:
    """CACTI-flavoured access latency vs capacity (paper: 32 ns @128 MiB,
    22 ns @64 MiB). Fit: latency ~ a * sqrt(C) + b."""
    mib = capacity / 2**20
    return 2.75 * math.sqrt(mib) + 0.9


@dataclass(frozen=True)
class AcceleratorConfig:
    name: str = "trapti-base"
    sa_count: int = 4
    sa_dim: int = 128
    freq_hz: float = 1.0e9
    vpu_lanes: int = 512          # vector element-ops per cycle per array
    fifo_depth: int = 256         # entries per lane (timing folded into derate)
    memories: Tuple[MemConfig, ...] = (
        MemConfig("sram", 128 * 2**20, 4, 512, 32.0, bw_derate=0.45),
        MemConfig("dram", 2 * 2**30, 2, 512, 80.0, bw_derate=0.70),
    )
    # memory each SA is attached to (reads operands / writes results there)
    sa_memory: Tuple[str, ...] = ("sram", "sram", "sram", "sram")
    dram_name: str = "dram"

    # ---- energy constants (45 nm, int8; calibration notes in DESIGN.md) ----
    e_mac_pj: float = 0.45        # per int8 MAC
    e_vop_pj: float = 0.15        # per vector element-op
    pe_static_w: float = 30.0     # PE array + NoC + FIFOs static power
    e_dram_pj_per_byte: float = 20.0

    def mem(self, name: str) -> MemConfig:
        for m in self.memories:
            if m.name == name:
                return m
        raise KeyError(name)

    @property
    def onchip_names(self) -> List[str]:
        return [m.name for m in self.memories if m.name != self.dram_name]

    @property
    def peak_macs_per_s(self) -> float:
        return self.sa_count * self.sa_dim * self.sa_dim * self.freq_hz

    def with_sram_capacity(self, capacity: int) -> "AcceleratorConfig":
        mems = tuple(
            replace(m, capacity=capacity, latency_ns=sram_latency_ns(capacity))
            if m.name == "sram" else m
            for m in self.memories)
        return replace(self, memories=mems)


def baseline_accelerator(sram_mib: int = 128) -> AcceleratorConfig:
    cfg = AcceleratorConfig()
    return cfg.with_sram_capacity(sram_mib * 2**20)


def multilevel_accelerator(mib: int = 64) -> AcceleratorConfig:
    """Sec. IV-D: shared SRAM + two dedicated memories (one per SA pair)."""
    cap = mib * 2**20
    lat = sram_latency_ns(cap)
    mems = (
        MemConfig("sram", cap, 4, 512, lat, bw_derate=0.45),
        MemConfig("dm1", cap, 4, 512, lat, bw_derate=0.45),
        MemConfig("dm2", cap, 4, 512, lat, bw_derate=0.45),
        MemConfig("dram", 2 * 2**30, 2, 512, 80.0, bw_derate=0.70),
    )
    return AcceleratorConfig(
        name="trapti-multilevel",
        memories=mems,
        sa_memory=("dm1", "dm1", "dm2", "dm2"),
    )
