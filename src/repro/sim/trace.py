"""Time-resolved occupancy traces and access statistics (Stage-I outputs)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np


@dataclass
class OccupancyTrace:
    """Piecewise-constant needed/obsolete occupancy of one memory over time.

    The engine is a list scheduler, so state mutations are emitted in
    processing order with non-monotonic simulated timestamps; we therefore
    record *delta events* (t, d_needed, d_obsolete) and integrate after a
    stable sort by time — the resulting step function is exact. `segments()`
    yields (duration, needed, obsolete, total) rows — the artifact Stage II
    consumes (Eq. 1/4 of the paper)."""
    mem_name: str
    capacity: int
    ev_times: List[float] = field(default_factory=list)
    ev_dneeded: List[int] = field(default_factory=list)
    ev_dobsolete: List[int] = field(default_factory=list)

    def event(self, t: float, d_needed: int, d_obsolete: int) -> None:
        if d_needed == 0 and d_obsolete == 0:
            return
        self.ev_times.append(t)
        self.ev_dneeded.append(int(d_needed))
        self.ev_dobsolete.append(int(d_obsolete))

    # ------------------------------------------------------------- views
    def as_arrays(self):
        """Sorted, integrated (times, needed, obsolete) step function."""
        t = np.asarray(self.ev_times, np.float64)
        dn = np.asarray(self.ev_dneeded, np.int64)
        do = np.asarray(self.ev_dobsolete, np.int64)
        order = np.argsort(t, kind="stable")
        t = t[order]
        n = np.cumsum(dn[order])
        o = np.cumsum(do[order])
        # collapse duplicate timestamps (keep last state at each time)
        if len(t):
            last = np.r_[t[1:] != t[:-1], True]
            t, n, o = t[last], n[last], o[last]
        return t, n, o

    def segments(self, end_time: float):
        """(durations, needed, obsolete, total) arrays, one row per segment."""
        t, n, o = self.as_arrays()
        if len(t) == 0:
            return (np.zeros(0), np.zeros(0, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.int64))
        edges = np.append(t, max(end_time, t[-1]))
        dur = np.diff(edges)
        keep = dur > 0
        return dur[keep], n[keep], o[keep], (n + o)[keep]

    def peak_needed(self) -> int:
        _, n, _ = self.as_arrays()
        return int(n.max()) if len(n) else 0

    def peak_total(self) -> int:
        _, n, o = self.as_arrays()
        return int((n + o).max()) if len(n) else 0

    def time_weighted_mean(self, end_time: float) -> float:
        dur, n, o, tot = self.segments(end_time)
        if dur.sum() <= 0:
            return 0.0
        return float((tot * dur).sum() / dur.sum())

    def occupancy_series(self, end_time: float, use: str = "total"):
        """(durations, bytes) for Stage II; `use` selects needed|total."""
        dur, n, o, tot = self.segments(end_time)
        return dur, (n if use == "needed" else tot)


@dataclass
class AccessStats:
    reads_bytes: Dict[str, int] = field(default_factory=dict)
    writes_bytes: Dict[str, int] = field(default_factory=dict)
    access_width: int = 64         # bytes per SRAM access word

    def add_read(self, mem: str, b: int) -> None:
        self.reads_bytes[mem] = self.reads_bytes.get(mem, 0) + int(b)

    def add_write(self, mem: str, b: int) -> None:
        self.writes_bytes[mem] = self.writes_bytes.get(mem, 0) + int(b)

    def n_reads(self, mem: str) -> int:
        return -(-self.reads_bytes.get(mem, 0) // self.access_width)

    def n_writes(self, mem: str) -> int:
        return -(-self.writes_bytes.get(mem, 0) // self.access_width)


@dataclass
class OpStats:
    """Per-tag latency decomposition (paper Fig. 6)."""
    compute: Dict[str, float] = field(default_factory=dict)
    memory: Dict[str, float] = field(default_factory=dict)
    idle: Dict[str, float] = field(default_factory=dict)
    count: Dict[str, int] = field(default_factory=dict)

    def add(self, tag: str, compute: float, memory: float, idle: float):
        self.compute[tag] = self.compute.get(tag, 0.0) + compute
        self.memory[tag] = self.memory.get(tag, 0.0) + memory
        self.idle[tag] = self.idle.get(tag, 0.0) + idle
        self.count[tag] = self.count.get(tag, 0) + 1
