"""Time-resolved occupancy traces and access statistics (Stage-I outputs)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class OccupancyTrace:
    """Piecewise-constant needed/obsolete occupancy of one memory over time.

    The engine is a list scheduler, so state mutations are emitted in
    processing order with non-monotonic simulated timestamps; we therefore
    record *delta events* (t, d_needed, d_obsolete) and integrate after a
    stable sort by time — the resulting step function is exact. `segments()`
    yields (duration, needed, obsolete, total) rows — the artifact Stage II
    consumes (Eq. 1/4 of the paper).

    Mutate only through `event()` / `extend()`: the integrated step function
    is cached and those are the invalidation points. `event()` appends to
    cheap Python tail lists (the DES hot path); `extend()` stores whole
    numpy chunks (the PSS/traffic bulk path), so million-event synthesized
    traces never round-trip through per-element Python objects. The
    `ev_times`/`ev_dneeded`/`ev_dobsolete` list views materialize chunks on
    first access; insertion order is preserved across both paths (ties in
    the stable time sort resolve in emission order)."""
    mem_name: str
    capacity: int
    _tail_t: List[float] = field(default_factory=list, repr=False,
                                 compare=False)
    _tail_dn: List[int] = field(default_factory=list, repr=False,
                                compare=False)
    _tail_do: List[int] = field(default_factory=list, repr=False,
                                compare=False)
    # sealed (t, dn, do) numpy chunks, in emission order, all before _tail_*
    _chunks: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = field(
        default_factory=list, repr=False, compare=False)
    # (n_events_at_integration, (t, n, o)) — see as_arrays()
    _cache: Optional[Tuple[int, Tuple[np.ndarray, np.ndarray, np.ndarray]]] \
        = field(default=None, init=False, repr=False, compare=False)

    def event(self, t: float, d_needed: int, d_obsolete: int) -> None:
        if d_needed == 0 and d_obsolete == 0:
            return
        self._tail_t.append(t)
        self._tail_dn.append(int(d_needed))
        self._tail_do.append(int(d_obsolete))
        self._cache = None

    def extend(self, times, d_needed, d_obsolete) -> None:
        """Bulk-append delta events (vectorized `event`). Rows where both
        deltas are zero are dropped, matching `event` semantics."""
        t = np.asarray(times, np.float64)
        dn = np.asarray(d_needed, np.int64)
        do = np.asarray(d_obsolete, np.int64)
        keep = (dn != 0) | (do != 0)
        if not keep.all():
            t, dn, do = t[keep], dn[keep], do[keep]
        if len(t) == 0:
            return
        self._seal_tail()
        self._chunks.append((t, dn, do))
        self._cache = None

    def _seal_tail(self) -> None:
        if self._tail_t:
            self._chunks.append((np.asarray(self._tail_t, np.float64),
                                 np.asarray(self._tail_dn, np.int64),
                                 np.asarray(self._tail_do, np.int64)))
            self._tail_t, self._tail_dn, self._tail_do = [], [], []

    def _materialize(self) -> None:
        """Fold sealed chunks back into the tail lists (list-view access)."""
        if not self._chunks:
            return
        self._chunks.append((np.asarray(self._tail_t, np.float64),
                             np.asarray(self._tail_dn, np.int64),
                             np.asarray(self._tail_do, np.int64)))
        self._tail_t = np.concatenate(
            [c[0] for c in self._chunks]).tolist()
        self._tail_dn = np.concatenate(
            [c[1] for c in self._chunks]).tolist()
        self._tail_do = np.concatenate(
            [c[2] for c in self._chunks]).tolist()
        self._chunks = []

    @property
    def ev_times(self) -> List[float]:
        self._materialize()
        return self._tail_t

    @property
    def ev_dneeded(self) -> List[int]:
        self._materialize()
        return self._tail_dn

    @property
    def ev_dobsolete(self) -> List[int]:
        self._materialize()
        return self._tail_do

    @property
    def n_events(self) -> int:
        return (sum(len(c[0]) for c in self._chunks) + len(self._tail_t))

    def events_since(self, n0: int):
        """(times, dn, do) arrays of the events appended after the first
        `n0` — O(tail) when no chunk was sealed since (the DES memoization
        recorder's case)."""
        sealed = sum(len(c[0]) for c in self._chunks)
        if n0 < sealed:
            self._materialize()
            sealed = 0
        i = n0 - sealed
        return (np.asarray(self._tail_t[i:], np.float64),
                np.asarray(self._tail_dn[i:], np.int64),
                np.asarray(self._tail_do[i:], np.int64))

    def _parts(self):
        """Raw event arrays in emission order, without materializing."""
        for c in self._chunks:
            yield c
        if self._tail_t:
            yield (np.asarray(self._tail_t, np.float64),
                   np.asarray(self._tail_dn, np.int64),
                   np.asarray(self._tail_do, np.int64))

    # ------------------------------------------------------------- views
    def as_arrays(self):
        """Sorted, integrated (times, needed, obsolete) step function.

        The result is cached until the next `event()`/`extend()` — repeated
        peak/segment queries on a finished trace integrate once instead of
        re-sorting the (possibly millions of) events per call. Treat the
        returned arrays as read-only."""
        n_ev = self.n_events
        if self._cache is not None and self._cache[0] == n_ev:
            return self._cache[1]
        parts = list(self._parts())
        if parts:
            t = np.concatenate([p[0] for p in parts])
            dn = np.concatenate([p[1] for p in parts])
            do = np.concatenate([p[2] for p in parts])
        else:
            t = np.zeros(0)
            dn = do = np.zeros(0, np.int64)
        order = np.argsort(t, kind="stable")
        t = t[order]
        n = np.cumsum(dn[order])
        o = np.cumsum(do[order])
        # collapse duplicate timestamps (keep last state at each time)
        if len(t):
            last = np.r_[t[1:] != t[:-1], True]
            t, n, o = t[last], n[last], o[last]
        self._cache = (n_ev, (t, n, o))
        return t, n, o

    def segments(self, end_time: float):
        """(durations, needed, obsolete, total) arrays, one row per segment."""
        t, n, o = self.as_arrays()
        if len(t) == 0:
            return (np.zeros(0), np.zeros(0, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.int64))
        edges = np.append(t, max(end_time, t[-1]))
        dur = np.diff(edges)
        keep = dur > 0
        return dur[keep], n[keep], o[keep], (n + o)[keep]

    def peak_needed(self) -> int:
        _, n, _ = self.as_arrays()
        return int(n.max()) if len(n) else 0

    def peak_total(self) -> int:
        _, n, o = self.as_arrays()
        return int((n + o).max()) if len(n) else 0

    def time_weighted_mean(self, end_time: float) -> float:
        dur, n, o, tot = self.segments(end_time)
        if dur.sum() <= 0:
            return 0.0
        return float((tot * dur).sum() / dur.sum())

    def occupancy_series(self, end_time: float, use: str = "total"):
        """(durations, bytes) for Stage II; `use` selects needed|total."""
        dur, n, o, tot = self.segments(end_time)
        return dur, (n if use == "needed" else tot)

    # ------------------------------------------------------- transformations
    def merged(self, *others: "OccupancyTrace",
               mem_name: Optional[str] = None) -> "OccupancyTrace":
        """Superpose delta-event streams from several traces (e.g. per-tenant
        occupancy curves) into one. Exact: deltas commute under the stable
        time sort performed by `as_arrays`."""
        out = OccupancyTrace(mem_name or self.mem_name,
                             self.capacity + sum(t.capacity for t in others))
        for tr in (self, *others):
            for part in tr._parts():
                out.extend(*part)
        return out

    def time_integral(self, end_time: float, use: str = "total") -> float:
        """Byte-seconds under the needed|total occupancy curve."""
        dur, occ = self.occupancy_series(end_time, use=use)
        return float((occ.astype(np.float64) * dur).sum())

    def resampled(self, dt: float, end_time: float) -> "OccupancyTrace":
        """Snap the step function to a uniform `dt` grid (right-edge sample).

        Bounds the segment count to ~end_time/dt regardless of event density
        — the knob that keeps thousand-scenario campaign sweeps inside a
        fixed jit-padded shape. Peak occupancy is preserved up to the grid
        resolution (each grid cell reports its last value, so short spikes
        inside a cell may be clipped; choose dt accordingly)."""
        if dt <= 0:
            raise ValueError(f"dt must be positive, got {dt}")
        t, n, o = self.as_arrays()
        out = OccupancyTrace(self.mem_name, self.capacity)
        if len(t) == 0:
            return out
        grid = np.arange(0.0, max(end_time, t[-1]) + dt, dt)
        # value in force at each grid edge (step function is right-continuous)
        idx = np.searchsorted(t, grid, side="right") - 1
        gn = np.where(idx >= 0, n[np.maximum(idx, 0)], 0)
        go = np.where(idx >= 0, o[np.maximum(idx, 0)], 0)
        prev_n = prev_o = 0
        for g, vn, vo in zip(grid, gn, go):
            out.event(float(g), int(vn - prev_n), int(vo - prev_o))
            prev_n, prev_o = int(vn), int(vo)
        return out


def merge_traces(traces: Sequence["OccupancyTrace"],
                 mem_name: str = "merged") -> "OccupancyTrace":
    """Module-level convenience over `OccupancyTrace.merged`."""
    if not traces:
        return OccupancyTrace(mem_name, 0)
    return traces[0].merged(*traces[1:], mem_name=mem_name)


@dataclass
class AccessStats:
    reads_bytes: Dict[str, int] = field(default_factory=dict)
    writes_bytes: Dict[str, int] = field(default_factory=dict)
    access_width: int = 64         # bytes per SRAM access word

    def add_read(self, mem: str, b: int) -> None:
        self.reads_bytes[mem] = self.reads_bytes.get(mem, 0) + int(b)

    def add_write(self, mem: str, b: int) -> None:
        self.writes_bytes[mem] = self.writes_bytes.get(mem, 0) + int(b)

    def n_reads(self, mem: str) -> int:
        return -(-self.reads_bytes.get(mem, 0) // self.access_width)

    def n_writes(self, mem: str) -> int:
        return -(-self.writes_bytes.get(mem, 0) // self.access_width)


@dataclass
class TraceBundle:
    """The minimal Stage-I artifact contract consumed by Stage II.

    `sim.engine.SimResult` satisfies it structurally; this lightweight form
    lets externally built traces — the analytic traffic simulator, an
    instrumented `ContinuousBatcher`, or a replayed production log — flow
    into `core.explorer.sweep` / `core.gating.evaluate` unchanged."""
    graph_name: str
    total_time: float
    traces: Dict[str, "OccupancyTrace"]
    access: "AccessStats"

    def peak_needed(self, mem: str = "kv") -> int:
        return self.traces[mem].peak_needed()


@dataclass
class OpStats:
    """Per-tag latency decomposition (paper Fig. 6)."""
    compute: Dict[str, float] = field(default_factory=dict)
    memory: Dict[str, float] = field(default_factory=dict)
    idle: Dict[str, float] = field(default_factory=dict)
    count: Dict[str, int] = field(default_factory=dict)

    def add(self, tag: str, compute: float, memory: float, idle: float):
        self.compute[tag] = self.compute.get(tag, 0.0) + compute
        self.memory[tag] = self.memory.get(tag, 0.0) + memory
        self.idle[tag] = self.idle.get(tag, 0.0) + idle
        self.count[tag] = self.count.get(tag, 0) + 1
