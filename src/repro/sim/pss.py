"""Periodic-steady-state (PSS) Stage-I decode fast path.

Decode is a long, highly repetitive memory-bound phase: step t differs from
step t+1 only in the KV-cache context length, and every tensor size, MAC
count and delta-event magnitude in the step graph is an *affine* function of
that context length (scores are Bt*H*ctx bytes, the KV cache Bt*ctx*K*hd,
attention MACs Bt*H*hd*ctx, ...). The PSS path exploits this:

  1. run the exact DES at a few *probe* context lengths — the horizon
     endpoints plus interior validation probes
     (`core.workload.decode_probe_contexts`);
  2. validate affinity on the **structural** event stream (graph-driven
     allocations and needed→obsolete flips): every probe must emit the same
     number of structural events per memory, with integer occupancy deltas
     and access counters whose per-context slopes are exactly integral and
     identical across probe brackets, and zero capacity write-backs;
  3. synthesize every non-probe step by affine interpolation of the probe
     pattern, tile the per-step patterns with cumulative step latencies,
     and bulk-integrate through `OccupancyTrace.extend`.

Capacity-eviction **drop** events (pure obsolete removals, `d_needed == 0,
d_obsolete < 0`) are the one state-dependent part of a step: full-size
models stream more weight bytes per step than the SRAM holds, and the victim
count jumps by one at discrete context thresholds, so drops are only
piecewise constant in count. They cost no simulated time and never touch the
needed curve, so interior steps borrow the bracket-low probe's drop pattern
verbatim (time-scaled); a failing *structural* bracket is adaptively
bisected and re-validated until affine or the probe budget is exhausted
(`fidelity="auto"` then falls back to the exact per-step path,
`fidelity="pss"` raises).

Every step ends with a synthetic **drain** event returning both occupancy
buckets to zero at the step's latency: tiled steps are independent DES runs
of the per-step graph (each re-stages its working set), so without the drain
the horizon baseline would grow by each step's residual resident bytes. The
drain makes the tiled trace the time-resolved sequence of per-step occupancy
humps Stage II expects, in both the exact and the PSS path.

Exactness contract:
  * at probe context lengths the synthesized per-step event stream is the
    probe's own DES output (plus its drain) — bit-exact
    (`DecodeSimResult.step_events`);
  * between probes the **needed** occupancy curve is exact whenever the DES
    is affine in context length (the validated regime): needed deltas are
    all structural. Obsolete occupancy is exact at probes and off between
    them by at most the drop-pattern difference across the bracket (one
    eviction victim, bounded by the largest weight-slab size); each step
    still drains to zero, so the error never accumulates across steps;
  * event *timestamps* are interpolated and may deviate by at most one
    refill-latency charge per transfer per step (`REFILL_BYTES` ceil kinks)
    plus float rounding — asserted at interior probes via `time_rtol`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.workload import build_decode_graph, decode_probe_contexts
from repro.sim.accelerator import AcceleratorConfig
from repro.sim.engine import SimResult, simulate
from repro.sim.trace import AccessStats, OccupancyTrace

FIDELITIES = ("exact", "pss", "auto")

Stream = Tuple[np.ndarray, np.ndarray, np.ndarray]    # times, dn, do


def _split(ev: Stream) -> Tuple[Stream, Stream]:
    """(structural, drops): drops are pure obsolete evictions."""
    t, dn, do = ev
    d = (dn == 0) & (do < 0)
    return (t[~d], dn[~d], do[~d]), (t[d], dn[d], do[d])


def _with_drain(ev: Stream, latency: float) -> Stream:
    """Append the end-of-step event returning occupancy to zero."""
    t, dn, do = ev
    sn, so = int(dn.sum()), int(do.sum())
    if sn == 0 and so == 0:
        return ev
    return (np.append(t, latency), np.append(dn, -sn), np.append(do, -so))


@dataclass
class StepProbe:
    """One exact DES run of the decode-step graph at context length `ctx`."""
    ctx: int
    result: SimResult
    events: Dict[str, Stream]          # raw per-memory streams, DES order
    structural: Dict[str, Stream] = field(default_factory=dict)
    drops: Dict[str, Stream] = field(default_factory=dict)

    @classmethod
    def run(cls, cfg, accel: AcceleratorConfig, ctx: int, *, batch: int,
            subops: int, byte: int, policy: str,
            memoize_layers: bool) -> "StepProbe":
        g = build_decode_graph(cfg, context_len=ctx, batch=batch,
                               subops=subops, byte=byte)
        res = simulate(g, accel, policy=policy,
                       memoize_layers=memoize_layers)
        ev = {m: (np.asarray(tr.ev_times, np.float64),
                  np.asarray(tr.ev_dneeded, np.int64),
                  np.asarray(tr.ev_dobsolete, np.int64))
              for m, tr in res.traces.items()}
        p = cls(ctx, res, ev)
        for m, e in ev.items():
            p.structural[m], p.drops[m] = _split(e)
        return p

    def step_stream(self, m: str) -> Stream:
        """The step's full event stream as it enters the tiled horizon."""
        return _with_drain(self.events[m], self.result.total_time)


@dataclass
class DecodeSimResult:
    """Full decode-horizon Stage-I artifact (Stage-II `TraceSource`).

    `traces`/`access`/`total_time`/`graph_name` satisfy the Stage-II input
    contract, so `core.explorer.sweep` and the gating evaluators run on a
    synthesized horizon unchanged. Per-step views are kept in step-major
    order: `step_events(mem, i)` recovers step i's relative event stream
    bit-exactly for probe steps."""
    graph_name: str
    accel_name: str
    fidelity: str                       # "exact" | "pss" (as executed)
    start_ctx: int
    steps: int
    batch: int
    total_time: float
    traces: Dict[str, OccupancyTrace]
    access: AccessStats
    step_latency: np.ndarray            # (steps,) seconds
    step_offsets: np.ndarray            # (steps,) absolute start offsets
    probes: Tuple[int, ...]             # context lengths simulated exactly
    writebacks: int
    total_macs: int
    total_vector_ops: int
    dram_traffic_bytes: int
    fallback_reason: str = ""           # set when auto fell back to exact
    replayed_layers: int = 0
    # step-major flattened per-step relative event times + counts per memory
    _step_rel: Dict[str, np.ndarray] = field(default_factory=dict, repr=False)
    _step_counts: Dict[str, np.ndarray] = field(default_factory=dict,
                                                repr=False)

    def peak_needed(self, mem: str = "sram") -> int:
        return self.traces[mem].peak_needed()

    def step_ctx(self, i: int) -> int:
        return self.start_ctx + i

    def step_events(self, mem: str, i: int):
        """(rel_times, d_needed, d_obsolete) of step i for one memory."""
        counts = self._step_counts[mem]
        tr = self.traces[mem]
        s = int(counts[:i].sum())
        e = s + int(counts[i])
        return (self._step_rel[mem][s:e],
                np.asarray(tr.ev_dneeded[s:e], np.int64),
                np.asarray(tr.ev_dobsolete[s:e], np.int64))


# ---------------------------------------------------------------------------
# Affinity validation
# ---------------------------------------------------------------------------

def _affine_check(values: np.ndarray, probes: Sequence[int]):
    """values[j] per probe -> (ok, uniform): slopes must be exactly
    integral in every probe bracket; `uniform` adds cross-bracket equality
    (true affinity over the whole span, not just piecewise)."""
    v = np.asarray(values)
    slopes = []
    for j in range(len(probes) - 1):
        span = probes[j + 1] - probes[j]
        diff = v[j + 1] - v[j]
        if np.any(diff % span != 0):
            return False, False
        slopes.append(diff // span)
    uniform = all(np.array_equal(slopes[0], s) for s in slopes[1:])
    return True, uniform


def _validate_probes(probes: List[StepProbe], time_rtol: float
                     ) -> Tuple[bool, str]:
    """The affinity contract that makes PSS synthesis exact-or-boundable."""
    base = probes[0]
    ctxs = [p.ctx for p in probes]
    for p in probes:
        if p.result.writebacks:
            return False, f"write-backs at probe ctx={p.ctx}"
    for m in base.events:
        counts = [len(p.structural[m][0]) for p in probes]
        if len(set(counts)) != 1:
            return False, f"structural event-count mismatch in {m}: {counts}"
        if counts[0] == 0:
            continue
        for comp, name in ((1, "d_needed"), (2, "d_obsolete")):
            ok, uniform = _affine_check(
                np.stack([p.structural[m][comp] for p in probes]), ctxs)
            if not ok:
                return False, f"non-integral {name} slope in {m}"
            if not uniform:
                return False, f"{name} slope kink across brackets in {m}"
        if np.any((np.stack([p.structural[m][1] for p in probes]) == 0)
                  & (np.stack([p.structural[m][2] for p in probes]) == 0)):
            return False, f"degenerate zero event in {m}"
    mems = set()
    for p in probes:
        mems |= set(p.result.access.reads_bytes) | \
            set(p.result.access.writes_bytes)
    for getter, name in (
            (lambda p, m: p.result.access.reads_bytes.get(m, 0), "reads"),
            (lambda p, m: p.result.access.writes_bytes.get(m, 0), "writes")):
        for m in mems:
            ok, uniform = _affine_check(
                np.array([getter(p, m) for p in probes], np.int64), ctxs)
            if not (ok and uniform):
                return False, f"non-affine access {name} in {m}"
    for attr in ("total_macs", "total_vector_ops", "dram_traffic_bytes"):
        ok, uniform = _affine_check(
            np.array([getattr(p.result, attr) for p in probes], np.int64),
            ctxs)
        if not (ok and uniform):
            return False, f"non-affine {attr}"
    # timing: affine up to the refill-chunk kinks; check the prediction of
    # every interior probe from the bracket's outer probes
    if len(probes) >= 3:
        lat = np.array([p.result.total_time for p in probes])
        for j in range(1, len(probes) - 1):
            w = (ctxs[j] - ctxs[0]) / (ctxs[-1] - ctxs[0])
            pred = lat[0] + (lat[-1] - lat[0]) * w
            if abs(pred - lat[j]) > time_rtol * max(lat[j], 1e-12):
                return False, (f"step latency deviates {abs(pred-lat[j]):.3e}s"
                               f" from affine at ctx={ctxs[j]}")
    return True, ""


# ---------------------------------------------------------------------------
# Planning (adaptive probe refinement)
# ---------------------------------------------------------------------------

class _ProbeBudget(Exception):
    pass


def _refine_plan(cfg, accel, cache: Dict[int, StepProbe],
                 probe_ctxs: List[int], kw, time_rtol: float,
                 max_probes: int) -> List[StepProbe]:
    """Bisect non-affine brackets until every consecutive probe pair spans a
    validated affine segment (span-1 brackets are trivially exact). Every
    simulated context becomes a probe boundary of the synthesis plan.
    Raises `_ProbeBudget` when the horizon is too irregular to beat the
    exact path."""

    def get(c: int) -> StepProbe:
        if c not in cache:
            if len(cache) >= max_probes:
                raise _ProbeBudget
            cache[c] = StepProbe.run(cfg, accel, c, **kw)
        return cache[c]

    def refine(lo: int, hi: int) -> None:
        if hi - lo <= 1:
            return
        m = (lo + hi) // 2
        ok, _ = _validate_probes([get(lo), get(m), get(hi)], time_rtol)
        if not ok:
            refine(lo, m)
            refine(m, hi)

    for a, b in zip(probe_ctxs, probe_ctxs[1:]):
        refine(a, b)
    return [cache[c] for c in sorted(cache)]


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def simulate_decode(cfg, accel: AcceleratorConfig, *, start_ctx: int = 1,
                    steps: int = 64, batch: int = 16, subops: int = 4,
                    byte: int = 1, policy: str = "fifo",
                    fidelity: str = "auto", n_probes: int = 3,
                    probes: Optional[Sequence[int]] = None,
                    memoize_layers: bool = False,
                    time_rtol: float = 5e-3,
                    max_probes: Optional[int] = None) -> DecodeSimResult:
    """Simulate a decode phase of `steps` steps starting at context
    `start_ctx` (each step runs the per-step decode graph — the regime of
    the paper's Fig. 1 — back-to-back).

    fidelity:
      * "exact" — step-by-step DES for every context length (O(steps)).
      * "pss"   — probe + synthesize (O(probes)); failing brackets are
                  adaptively bisected; raises ValueError if the probe budget
                  is exhausted before every bracket validates.
      * "auto"  — "pss" when planning succeeds within the probe budget,
                  transparent fallback to "exact" otherwise
                  (`fallback_reason` records why).
    """
    if fidelity not in FIDELITIES:
        raise ValueError(f"fidelity must be one of {FIDELITIES}")
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    kw = dict(batch=batch, subops=subops, byte=byte, policy=policy,
              memoize_layers=memoize_layers)
    name = f"{cfg.name}@decode[{start_ctx}..{start_ctx + steps - 1}]x{batch}"

    probe_ctxs = (sorted({int(c) for c in probes}) if probes is not None
                  else decode_probe_contexts(start_ctx, steps, n_probes))
    last = start_ctx + steps - 1
    if probes is not None:
        if any(c < start_ctx or c > last for c in probe_ctxs):
            raise ValueError(f"probes {probe_ctxs} outside horizon "
                             f"[{start_ctx}, {last}]")
        probe_ctxs = sorted(set(probe_ctxs) | {start_ctx, last})
    if max_probes is None:
        # refinement must stay well below the exact path's cost
        max_probes = max(16, min(64, steps // 4))

    if fidelity == "exact" or steps <= len(probe_ctxs):
        return _record_decode_sim(
            _simulate_exact(cfg, accel, name, start_ctx, steps, kw), steps, 0)

    cache = {c: StepProbe.run(cfg, accel, c, **kw) for c in probe_ctxs}
    runs = [cache[c] for c in probe_ctxs]
    ok, reason = _validate_probes(runs, time_rtol)
    if not ok:
        try:
            runs = _refine_plan(cfg, accel, cache, probe_ctxs, kw,
                                time_rtol, max_probes)
        except _ProbeBudget:
            reason = (f"probe budget {max_probes} exhausted refining "
                      f"non-affine horizon ({reason})")
            if fidelity == "pss":
                raise ValueError(
                    f"PSS planning failed: {reason}; use fidelity='auto' "
                    f"or 'exact', or raise max_probes")
            res = _simulate_exact(cfg, accel, name, start_ctx, steps, kw)
            res.fallback_reason = reason
            return _record_decode_sim(res, steps, len(cache))
    return _record_decode_sim(
        _synthesize(accel, name, start_ctx, steps, kw["batch"], runs),
        steps, len(runs))


def _record_decode_sim(res: "DecodeSimResult", steps: int,
                       n_probes: int) -> "DecodeSimResult":
    """Fold one simulate_decode outcome into the process-wide registry:
    how often PSS ran, how many probe DES runs it spent, how many steps it
    synthesized vs simulated exactly, and the fallbacks it took."""
    from repro.obs.telemetry import default_registry
    tel = default_registry()
    tel.counter("sim.pss.decode_sims").inc()
    tel.counter("sim.pss.probes").inc(n_probes)
    if n_probes:
        tel.counter("sim.pss.brackets").inc(max(n_probes - 1, 0))
    if res.fallback_reason:
        tel.counter("sim.pss.fallbacks").inc()
    if n_probes and not res.fallback_reason:
        tel.counter("sim.pss.synthesized_steps").inc(steps - n_probes)
    else:
        tel.counter("sim.pss.exact_steps").inc(steps)
    return res


def _simulate_exact(cfg, accel: AcceleratorConfig, name: str, start_ctx: int,
                    steps: int, kw) -> DecodeSimResult:
    access = AccessStats()
    traces: Dict[str, OccupancyTrace] = {}
    rel: Dict[str, List[np.ndarray]] = {}
    counts: Dict[str, List[int]] = {}
    lat = np.zeros(steps)
    offsets = np.zeros(steps)
    wb = macs = vops = dram = 0
    replayed = 0
    t_cursor = 0.0
    for i in range(steps):
        p = StepProbe.run(cfg, accel, start_ctx + i, **kw)
        offsets[i] = t_cursor
        lat[i] = p.result.total_time
        t_cursor += p.result.total_time
        for m in p.events:
            t, dn, do = p.step_stream(m)
            if m not in traces:
                traces[m] = OccupancyTrace(m, p.result.traces[m].capacity)
                rel[m], counts[m] = [], []
            traces[m].extend(t + offsets[i], dn, do)
            rel[m].append(t)
            counts[m].append(len(t))
        for m, b in p.result.access.reads_bytes.items():
            access.add_read(m, b)
        for m, b in p.result.access.writes_bytes.items():
            access.add_write(m, b)
        wb += p.result.writebacks
        macs += p.result.total_macs
        vops += p.result.total_vector_ops
        dram += p.result.dram_traffic_bytes
        replayed += p.result.replayed_layers
    return DecodeSimResult(
        graph_name=name, accel_name=accel.name, fidelity="exact",
        start_ctx=start_ctx, steps=steps, batch=kw["batch"],
        total_time=float(t_cursor), traces=traces, access=access,
        step_latency=lat, step_offsets=offsets,
        probes=tuple(range(start_ctx, start_ctx + steps)),
        writebacks=wb, total_macs=macs, total_vector_ops=vops,
        dram_traffic_bytes=dram, replayed_layers=replayed,
        _step_rel={m: (np.concatenate(v) if v else np.zeros(0))
                   for m, v in rel.items()},
        _step_counts={m: np.asarray(v, np.int64)
                      for m, v in counts.items()})


def _interp_int(v0: np.ndarray, v1: np.ndarray, span: int,
                crel: np.ndarray) -> np.ndarray:
    """Exact integer affine interpolation (validated divisible slopes)."""
    slope = (v1 - v0) // span
    return v0[None, :] + slope[None, :] * crel[:, None]


def _scalar_series(runs: List[StepProbe], getter, ctxs: np.ndarray,
                   bracket: np.ndarray) -> np.ndarray:
    """Per-step integer series from per-probe scalars (piecewise affine)."""
    pv = np.array([getter(p) for p in runs], np.int64)
    pc = np.array([p.ctx for p in runs], np.int64)
    out = np.empty(len(ctxs), np.int64)
    for j in range(len(runs) - 1):
        mask = bracket == j
        if not mask.any():
            continue
        span = int(pc[j + 1] - pc[j])
        out[mask] = pv[j] + (pv[j + 1] - pv[j]) // span * (ctxs[mask] - pc[j])
    return out


def _synthesize(accel: AcceleratorConfig, name: str, start_ctx: int,
                steps: int, batch: int,
                runs: List[StepProbe]) -> DecodeSimResult:
    """Tile the validated probe patterns across the whole horizon.

    Brackets may carry different drop counts (capacity-eviction thresholds
    found by refinement), so per-step streams are assembled bracket-major
    (= step-major, since brackets partition the horizon)."""
    pc = np.array([p.ctx for p in runs], np.int64)
    ctxs = start_ctx + np.arange(steps, dtype=np.int64)
    # bracket[i] = probe interval of step i: [pc[j], pc[j+1]]
    bracket = np.clip(np.searchsorted(pc, ctxs, side="right") - 1,
                      0, len(pc) - 2)
    probe_row = {int(c): j for j, c in enumerate(pc)}

    # per-step latencies (float affine interp), then cumulative offsets
    plat = np.array([p.result.total_time for p in runs])
    lat = np.empty(steps)
    for j in range(len(pc) - 1):
        mask = bracket == j
        if not mask.any():
            continue
        span = float(pc[j + 1] - pc[j])
        w = (ctxs[mask] - pc[j]) / span
        lat[mask] = plat[j] + (plat[j + 1] - plat[j]) * w
    for c, j in probe_row.items():
        lat[c - start_ctx] = plat[j]
    offsets = np.concatenate([[0.0], np.cumsum(lat[:-1])])

    traces: Dict[str, OccupancyTrace] = {}
    step_rel: Dict[str, np.ndarray] = {}
    step_counts: Dict[str, np.ndarray] = {}
    for m in runs[0].events:
        blk_t: List[np.ndarray] = []
        blk_dn: List[np.ndarray] = []
        blk_do: List[np.ndarray] = []
        counts = np.zeros(steps, np.int64)
        for j, run in enumerate(runs):
            t_p, dn_p, do_p = run.step_stream(m)
            counts[run.ctx - start_ctx] = len(t_p)
            blk_t.append(t_p)
            blk_dn.append(dn_p)
            blk_do.append(do_p)
            if j == len(runs) - 1:
                break
            span = int(pc[j + 1] - pc[j])
            if span <= 1:
                continue
            # interior steps of a validated bracket: structural events are
            # exactly affine; drops borrow this probe's pattern (time-scaled
            # to the step latency); the drain keeps each step zero-balanced
            crel = np.arange(1, span, dtype=np.int64)
            n_int = span - 1
            ts, dns, dos = run.structural[m]
            tn, dnn, don = runs[j + 1].structural[m]
            td, dnd, dod = run.drops[m]
            ilat = lat[run.ctx - start_ctx + 1:run.ctx - start_ctx + span]
            parts_t, parts_dn, parts_do = [], [], []
            if len(ts):
                parts_t.append(ts[None, :]
                               + (tn - ts)[None, :] * (crel / span)[:, None])
                parts_dn.append(_interp_int(dns, dnn, span, crel))
                parts_do.append(_interp_int(dos, don, span, crel))
            if len(td):
                scale = ilat / max(plat[j], 1e-30)
                parts_t.append(td[None, :] * scale[:, None])
                parts_dn.append(np.broadcast_to(dnd, (n_int, len(td))))
                parts_do.append(np.broadcast_to(dod, (n_int, len(td))))
            if not parts_t:
                continue
            it = np.concatenate(parts_t, axis=1)
            idn = np.concatenate(parts_dn, axis=1)
            ido = np.concatenate(parts_do, axis=1)
            sn, so = idn.sum(axis=1), ido.sum(axis=1)
            drained = (sn != 0) | (so != 0)
            if drained.any():
                it = np.concatenate([it, ilat[:, None]], axis=1)
                idn = np.concatenate([idn, -sn[:, None]], axis=1)
                ido = np.concatenate([ido, -so[:, None]], axis=1)
            counts[run.ctx - start_ctx + 1:
                   run.ctx - start_ctx + span] = it.shape[1]
            blk_t.append(it.reshape(-1))
            blk_dn.append(idn.reshape(-1))
            blk_do.append(ido.reshape(-1))
        rel = np.concatenate(blk_t) if blk_t else np.zeros(0)
        dn = np.concatenate(blk_dn) if blk_dn else np.zeros(0, np.int64)
        do = np.concatenate(blk_do) if blk_do else np.zeros(0, np.int64)
        tr = OccupancyTrace(m, runs[0].result.traces[m].capacity)
        tr.extend(rel + np.repeat(offsets, counts), dn, do)
        assert tr.n_events == int(counts.sum()), \
            "degenerate synthesized event dropped (validation gap)"
        traces[m] = tr
        step_rel[m] = rel
        step_counts[m] = counts

    access = AccessStats()
    mems = set()
    for p in runs:
        mems |= set(p.result.access.reads_bytes) | \
            set(p.result.access.writes_bytes)
    for m in sorted(mems):
        r = _scalar_series(
            runs, lambda p: p.result.access.reads_bytes.get(m, 0),
            ctxs, bracket)
        w = _scalar_series(
            runs, lambda p: p.result.access.writes_bytes.get(m, 0),
            ctxs, bracket)
        if r.sum():
            access.add_read(m, int(r.sum()))
        if w.sum():
            access.add_write(m, int(w.sum()))

    totals = {attr: int(_scalar_series(
        runs, lambda p, a=attr: getattr(p.result, a), ctxs, bracket).sum())
        for attr in ("total_macs", "total_vector_ops", "dram_traffic_bytes")}

    return DecodeSimResult(
        graph_name=name, accel_name=accel.name, fidelity="pss",
        start_ctx=start_ctx, steps=steps, batch=batch,
        total_time=float(offsets[-1] + lat[-1]), traces=traces,
        access=access, step_latency=lat, step_offsets=offsets,
        probes=tuple(int(c) for c in pc),
        writebacks=sum(p.result.writebacks for p in runs),
        total_macs=totals["total_macs"],
        total_vector_ops=totals["total_vector_ops"],
        dram_traffic_bytes=totals["dram_traffic_bytes"],
        replayed_layers=sum(p.result.replayed_layers for p in runs),
        _step_rel=step_rel, _step_counts=step_counts)


# ---------------------------------------------------------------------------
# Causal affine extrapolation (the forecast leg of the online controller)
# ---------------------------------------------------------------------------

class AffineForecaster:
    """Causal trailing-window affine extrapolator over an irregular series.

    The PSS machinery above exploits that Stage-I decode is affine in
    context length; this is the same trick pointed at *time*: inside a
    traffic ramp the occupancy series is locally affine, so a least-squares
    line over the trailing `window_s` of samples extrapolates the demand a
    gating controller is about to see. All window sums come from prefix
    sums, so a query costs O(log n) (two searchsorted calls); the fit is
    re-centered on the window's first sample to keep the normal equations
    well-conditioned at large absolute times.

    Strictly causal: a query at time `t` only sees samples with
    ``time <= t``.
    """

    def __init__(self, times: np.ndarray, values: np.ndarray,
                 window_s: float):
        t = np.asarray(times, np.float64)
        y = np.asarray(values, np.float64)
        if t.ndim != 1 or t.shape != y.shape:
            raise ValueError("times/values must be equal-length 1-D arrays")
        if len(t) > 1 and np.any(np.diff(t) < 0):
            raise ValueError("times must be non-decreasing")
        if window_s <= 0:
            raise ValueError("window_s must be positive")
        self.window_s = float(window_s)
        self._t = t
        self._y = y
        z = np.zeros(1)
        self._ct = np.concatenate([z, np.cumsum(t)])
        self._cy = np.concatenate([z, np.cumsum(y)])
        self._ctt = np.concatenate([z, np.cumsum(t * t)])
        self._cty = np.concatenate([z, np.cumsum(t * y)])

    def _window(self, now_s: float) -> Tuple[int, int]:
        hi = int(np.searchsorted(self._t, now_s, side="right"))
        lo = int(np.searchsorted(self._t, now_s - self.window_s,
                                 side="left"))
        return lo, hi

    def fit(self, now_s: float) -> Tuple[float, float]:
        """(intercept-at-now, slope) of the trailing-window least-squares
        line. Empty window → (0, 0); degenerate (single sample or zero
        time spread) → (window mean, 0)."""
        lo, hi = self._window(now_s)
        n = hi - lo
        if n == 0:
            # nothing in the window: hold the last value seen before it
            return (float(self._y[hi - 1]), 0.0) if hi else (0.0, 0.0)
        sy = self._cy[hi] - self._cy[lo]
        if n == 1:
            return float(sy), 0.0
        c = float(self._t[lo])            # re-center for conditioning
        st = self._ct[hi] - self._ct[lo] - n * c
        stt = (self._ctt[hi] - self._ctt[lo]
               - 2.0 * c * (self._ct[hi] - self._ct[lo]) + n * c * c)
        sty = self._cty[hi] - self._cty[lo] - c * sy
        det = n * stt - st * st
        if det <= 0 or not np.isfinite(det):
            return float(sy / n), 0.0
        b = (n * sty - st * sy) / det
        a = (sy - b * st) / n             # intercept at t = c
        return float(a + b * (now_s - c)), float(b)

    def slope(self, now_s: float) -> float:
        return self.fit(now_s)[1]

    def forecast(self, now_s: float, horizon_s: float) -> float:
        """Extrapolated value at ``now_s + horizon_s`` (clamped at 0 —
        occupancies cannot go negative)."""
        v, b = self.fit(now_s)
        return max(0.0, v + b * horizon_s)


def affine_forecast(times: np.ndarray, values: np.ndarray, now_s: float,
                    horizon_s: float, window_s: float) -> float:
    """One-shot convenience wrapper over :class:`AffineForecaster`."""
    return AffineForecaster(times, values, window_s).forecast(
        now_s, horizon_s)
