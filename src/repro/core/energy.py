"""On-chip energy assembly (paper Fig. 7): PE dynamic/static, SRAM dynamic +
leakage (unbanked baseline, consistent with Stage II's B=1 candidate), DRAM."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.cacti import characterize
from repro.sim.accelerator import AcceleratorConfig
from repro.sim.engine import SimResult


@dataclass
class EnergyBreakdown:
    pe_dynamic: float
    pe_static: float
    sram_dynamic: float
    sram_leakage: float
    dram: float

    @property
    def total(self) -> float:
        return (self.pe_dynamic + self.pe_static + self.sram_dynamic
                + self.sram_leakage + self.dram)

    def as_dict(self) -> Dict[str, float]:
        return {"pe_dynamic": self.pe_dynamic, "pe_static": self.pe_static,
                "sram_dynamic": self.sram_dynamic,
                "sram_leakage": self.sram_leakage, "dram": self.dram,
                "total": self.total}


def assemble_energy(sim: SimResult, accel: AcceleratorConfig) -> EnergyBreakdown:
    T = sim.total_time
    pe_dyn = (sim.total_macs * accel.e_mac_pj
              + sim.total_vector_ops * accel.e_vop_pj) * 1e-12
    pe_static = accel.pe_static_w * T

    sram_dyn = 0.0
    sram_leak = 0.0
    for m in accel.memories:
        if m.name == accel.dram_name:
            continue
        ch = characterize(m.capacity, 1)
        sram_dyn += (sim.access.n_reads(m.name) * ch.e_read_j
                     + sim.access.n_writes(m.name) * ch.e_write_j)
        sram_leak += ch.leak_w_total * T

    dram_bytes = (sim.access.reads_bytes.get(accel.dram_name, 0)
                  + sim.access.writes_bytes.get(accel.dram_name, 0))
    dram = dram_bytes * accel.e_dram_pj_per_byte * 1e-12
    return EnergyBreakdown(pe_dyn, pe_static, sram_dyn, sram_leak, dram)
