"""Stage II: offline SRAM banking + power-gating design-space exploration.

Reuses a Stage-I occupancy trace (fixed execution schedule) to sweep
(capacity C, bank count B, headroom alpha, policy) and emit the paper's
artifacts: Table II/III banking tables, Fig 8 bank-activity timelines, and
the Fig 9 energy-area Pareto scatter.

Sweeps are thin wrappers over the batched candidate-evaluation engine
(`core.candidates.evaluate_candidates`): the whole grid is evaluated in one
vectorized call, optionally prune-then-exact (`prune=True`), on the numpy /
jnp / Pallas backend selected by `backend`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

from repro.core.candidates import Candidate, evaluate_candidates
from repro.core.gating import GatingResult, Policy
from repro.sim.engine import SimResult
from repro.sim.trace import TraceBundle

MIB = 2**20
DEFAULT_BANKS = (1, 2, 4, 8, 16, 32)

# Anything exposing .graph_name / .total_time / .traces / .access satisfies
# Stage II's input contract: the cycle-level SimResult, or an externally built
# TraceBundle (serving-traffic simulator, instrumented ContinuousBatcher,
# replayed production logs).
TraceSource = Union[SimResult, TraceBundle]


@dataclass
class SweepRow:
    capacity_mib: int
    banks: int
    result: GatingResult
    delta_e_pct: float = 0.0      # vs B=1 at same capacity
    delta_a_pct: float = 0.0


@dataclass
class SweepTable:
    workload: str
    mem_name: str
    alpha: float
    rows: List[SweepRow] = field(default_factory=list)

    def best(self) -> SweepRow:
        return min(self.rows, key=lambda r: r.result.e_total)

    def by_capacity(self) -> Dict[int, List[SweepRow]]:
        out: Dict[int, List[SweepRow]] = {}
        for r in self.rows:
            out.setdefault(r.capacity_mib, []).append(r)
        return out

    def format(self) -> str:
        lines = [f"# {self.workload} / {self.mem_name}  (alpha={self.alpha})",
                 f"{'C[MiB]':>7} {'B':>3} {'E[mJ]':>12} {'A[mm2]':>9} "
                 f"{'dE%':>7} {'dA%':>7} {'E_dyn':>9} {'E_leak':>9} "
                 f"{'E_sw':>9} {'Nsw':>6}"]
        for r in self.rows:
            g = r.result
            lines.append(
                f"{r.capacity_mib:>7} {r.banks:>3} {g.e_total*1e3:>12.1f} "
                f"{g.area_mm2:>9.2f} {r.delta_e_pct:>+7.1f} "
                f"{r.delta_a_pct:>+7.1f} {g.e_dyn*1e3:>9.1f} "
                f"{g.e_leak*1e3:>9.1f} {g.e_sw*1e3:>9.3f} "
                f"{g.n_transitions:>6}")
        return "\n".join(lines)


def min_capacity_mib(peak_needed_bytes: int, step_mib: int = 16) -> int:
    """Paper's rounding: peak requirement rounded up to the 16 MiB grid."""
    return step_mib * math.ceil(peak_needed_bytes / (step_mib * MIB))


def _policy_candidate(cap: int, b: int, policy: Policy) -> Candidate:
    """Stage-II convention: B=1 cannot gate, so it runs the no-gating
    baseline at the sweep's alpha."""
    pol = policy if b > 1 else Policy.none(policy.alpha)
    return Candidate(cap, b, pol.alpha, "gate" if pol.gate else "none",
                     pol.min_gate_multiple, label=pol.name)


def sweep(sim: TraceSource, *, mem_name: str = "sram",
          capacities_mib: Optional[Sequence[int]] = None,
          banks: Sequence[int] = DEFAULT_BANKS,
          policy: Optional[Policy] = None,
          max_capacity_mib: int = 128,
          occupancy_kind: str = "needed",
          backend: str = "auto", prune: bool = False) -> SweepTable:
    """Sweep (C, B) for one memory of one Stage-I run (or any TraceSource —
    e.g. a traffic-generated TraceBundle with mem_name="kv").

    `occupancy_kind="needed"`: only retention-required bytes pin banks —
    obsolete data needs no retention, so its banks are gate-eligible (this is
    the reading under which the paper's Fig. 8 occupancy curve fluctuates
    well below capacity).

    The whole grid is one `evaluate_candidates` call; with `prune=True` only
    the lower-bound survivors (plus each capacity's delta baseline) are
    evaluated exactly, and pruned rows are omitted from the table.
    """
    policy = policy or Policy.conservative()
    trace = sim.traces[mem_name]
    dur, occ = trace.occupancy_series(sim.total_time, use=occupancy_kind)
    n_r = sim.access.n_reads(mem_name)
    n_w = sim.access.n_writes(mem_name)

    if capacities_mib is None:
        lo = min_capacity_mib(trace.peak_needed())
        capacities_mib = list(range(lo, max_capacity_mib + 1, 16)) or [lo]
    caps_kept = [c for c in capacities_mib if c * MIB >= trace.peak_needed()]
    if not caps_kept:
        return SweepTable(sim.graph_name, mem_name, policy.alpha)

    base_b = min(banks)
    cands, meta, baselines = [], [], []
    for c_mib in caps_kept:
        for b in banks:
            if b == base_b:
                baselines.append(len(cands))
            meta.append((c_mib, b))
            cands.append(_policy_candidate(c_mib * MIB, b, policy))
    res = evaluate_candidates(dur, occ, cands, n_reads=n_r, n_writes=n_w,
                              backend=backend, prune=prune,
                              always_evaluate=baselines)

    table = SweepTable(sim.graph_name, mem_name, policy.alpha)
    # delta baseline: the smallest bank count present (B=1 when swept; the
    # smallest banked config otherwise — never a silent 0.0)
    base_by_cap: Dict[int, GatingResult] = {
        meta[i][0]: res.gating_result(i) for i in baselines}
    for i, (c_mib, b) in enumerate(meta):
        if not res.evaluated[i]:
            continue
        g = res.gating_result(i)
        row = SweepRow(c_mib, b, g)
        base = base_by_cap[c_mib]
        if base.e_total > 0:
            row.delta_e_pct = 100.0 * (g.e_total / base.e_total - 1.0)
            row.delta_a_pct = 100.0 * (g.area_mm2 / base.area_mm2 - 1.0)
        table.rows.append(row)
    return table


def pareto_points(tables: Sequence[SweepTable]):
    """Fig.-9 scatter: (area, energy, label) for every (C,B) candidate."""
    pts = []
    for t in tables:
        for r in t.rows:
            pts.append((r.result.area_mm2, r.result.e_total, t.workload,
                        r.capacity_mib, r.banks))
    return pts


def alpha_sensitivity(sim: TraceSource, *, capacity_mib: int, banks: int,
                      alphas: Sequence[float] = (1.0, 0.9, 0.75, 0.5),
                      mem_name: str = "sram",
                      backend: str = "auto") -> Dict[float, GatingResult]:
    """Fig.-8 support: how alpha moves bank activity / energy at fixed (C,B).
    One batched call over the alpha axis."""
    trace = sim.traces[mem_name]
    dur, occ = trace.occupancy_series(sim.total_time, use="needed")
    n_r = sim.access.n_reads(mem_name)
    n_w = sim.access.n_writes(mem_name)
    cands = [Candidate(capacity_mib * MIB, banks, a, "gate", 5.0,
                       label="conservative") for a in alphas]
    res = evaluate_candidates(dur, occ, cands, n_reads=n_r, n_writes=n_w,
                              backend=backend)
    return {a: res.gating_result(i) for i, a in enumerate(alphas)}
