"""Stage II: offline SRAM banking + power-gating design-space exploration.

Reuses a Stage-I occupancy trace (fixed execution schedule) to sweep
(capacity C, bank count B, headroom alpha, policy) and emit the paper's
artifacts: Table II/III banking tables, Fig 8 bank-activity timelines, and
the Fig 9 energy-area Pareto scatter.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.gating import GatingResult, Policy, evaluate
from repro.sim.engine import SimResult
from repro.sim.trace import TraceBundle

MIB = 2**20
DEFAULT_BANKS = (1, 2, 4, 8, 16, 32)

# Anything exposing .graph_name / .total_time / .traces / .access satisfies
# Stage II's input contract: the cycle-level SimResult, or an externally built
# TraceBundle (serving-traffic simulator, instrumented ContinuousBatcher,
# replayed production logs).
TraceSource = Union[SimResult, TraceBundle]


@dataclass
class SweepRow:
    capacity_mib: int
    banks: int
    result: GatingResult
    delta_e_pct: float = 0.0      # vs B=1 at same capacity
    delta_a_pct: float = 0.0


@dataclass
class SweepTable:
    workload: str
    mem_name: str
    alpha: float
    rows: List[SweepRow] = field(default_factory=list)

    def best(self) -> SweepRow:
        return min(self.rows, key=lambda r: r.result.e_total)

    def by_capacity(self) -> Dict[int, List[SweepRow]]:
        out: Dict[int, List[SweepRow]] = {}
        for r in self.rows:
            out.setdefault(r.capacity_mib, []).append(r)
        return out

    def format(self) -> str:
        lines = [f"# {self.workload} / {self.mem_name}  (alpha={self.alpha})",
                 f"{'C[MiB]':>7} {'B':>3} {'E[mJ]':>12} {'A[mm2]':>9} "
                 f"{'dE%':>7} {'dA%':>7} {'E_dyn':>9} {'E_leak':>9} "
                 f"{'E_sw':>9} {'Nsw':>6}"]
        for r in self.rows:
            g = r.result
            lines.append(
                f"{r.capacity_mib:>7} {r.banks:>3} {g.e_total*1e3:>12.1f} "
                f"{g.area_mm2:>9.2f} {r.delta_e_pct:>+7.1f} "
                f"{r.delta_a_pct:>+7.1f} {g.e_dyn*1e3:>9.1f} "
                f"{g.e_leak*1e3:>9.1f} {g.e_sw*1e3:>9.3f} "
                f"{g.n_transitions:>6}")
        return "\n".join(lines)


def min_capacity_mib(peak_needed_bytes: int, step_mib: int = 16) -> int:
    """Paper's rounding: peak requirement rounded up to the 16 MiB grid."""
    return step_mib * math.ceil(peak_needed_bytes / (step_mib * MIB))


def sweep(sim: TraceSource, *, mem_name: str = "sram",
          capacities_mib: Optional[Sequence[int]] = None,
          banks: Sequence[int] = DEFAULT_BANKS,
          policy: Optional[Policy] = None,
          max_capacity_mib: int = 128,
          occupancy_kind: str = "needed") -> SweepTable:
    """Sweep (C, B) for one memory of one Stage-I run (or any TraceSource —
    e.g. a traffic-generated TraceBundle with mem_name="kv").

    `occupancy_kind="needed"`: only retention-required bytes pin banks —
    obsolete data needs no retention, so its banks are gate-eligible (this is
    the reading under which the paper's Fig. 8 occupancy curve fluctuates
    well below capacity).
    """
    policy = policy or Policy.conservative()
    trace = sim.traces[mem_name]
    dur, occ = trace.occupancy_series(sim.total_time, use=occupancy_kind)
    n_r = sim.access.n_reads(mem_name)
    n_w = sim.access.n_writes(mem_name)

    if capacities_mib is None:
        lo = min_capacity_mib(trace.peak_needed())
        capacities_mib = list(range(lo, max_capacity_mib + 1, 16)) or [lo]

    table = SweepTable(sim.graph_name, mem_name, policy.alpha)
    for c_mib in capacities_mib:
        cap = c_mib * MIB
        if cap < trace.peak_needed():
            continue
        base: Optional[GatingResult] = None
        for b in banks:
            pol = policy if b > 1 else Policy.none(policy.alpha)
            res = evaluate(dur, occ, capacity=cap, banks=b, policy=pol,
                           n_reads=n_r, n_writes=n_w)
            row = SweepRow(c_mib, b, res)
            if b == 1:
                base = res
            if base is not None and base.e_total > 0:
                row.delta_e_pct = 100.0 * (res.e_total / base.e_total - 1.0)
                row.delta_a_pct = 100.0 * (res.area_mm2 / base.area_mm2 - 1.0)
            table.rows.append(row)
    return table


def pareto_points(tables: Sequence[SweepTable]):
    """Fig.-9 scatter: (area, energy, label) for every (C,B) candidate."""
    pts = []
    for t in tables:
        for r in t.rows:
            pts.append((r.result.area_mm2, r.result.e_total, t.workload,
                        r.capacity_mib, r.banks))
    return pts


def alpha_sensitivity(sim: TraceSource, *, capacity_mib: int, banks: int,
                      alphas: Sequence[float] = (1.0, 0.9, 0.75, 0.5),
                      mem_name: str = "sram") -> Dict[float, GatingResult]:
    """Fig.-8 support: how alpha moves bank activity / energy at fixed (C,B)."""
    trace = sim.traces[mem_name]
    dur, occ = trace.occupancy_series(sim.total_time, use="needed")
    n_r = sim.access.n_reads(mem_name)
    n_w = sim.access.n_writes(mem_name)
    out = {}
    for a in alphas:
        pol = Policy("conservative", a, gate=True, min_gate_multiple=5.0)
        out[a] = evaluate(dur, occ, capacity=capacity_mib * MIB, banks=banks,
                          policy=pol, n_reads=n_r, n_writes=n_w)
    return out
