"""Eq. (1) of the paper: map an occupancy trace to bank-level activity.

    B_act(t) = ceil( o(t) / (alpha * C / B) ),  0 <= B_act(t) <= B

Occupied data is assumed packed contiguously across banks; alpha in (0, 1]
reserves per-bank headroom for non-ideal placement (0.9 = the paper's
conservative guardband, 1.0 = aggressive).

Vectorized in numpy/jnp over trace segments; the Pallas kernel in
repro.kernels.bank_energy implements the same computation blocked into VMEM
tiles for TPU-scale sweeps (millions of segments x many (C, B, alpha)
candidates) and is tested against this reference.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def bank_activity(occ_bytes: np.ndarray, alpha: float, capacity: int,
                  banks: int) -> np.ndarray:
    """Per-segment number of banks that must stay powered. occ: int64 bytes."""
    if not (0.0 < alpha <= 1.0):
        raise ValueError(f"alpha must be in (0,1], got {alpha}")
    usable = alpha * (capacity / banks)
    act = np.ceil(np.asarray(occ_bytes, np.float64) / usable)
    return np.clip(act, 0, banks).astype(np.int32)


def active_bank_seconds(durations: np.ndarray, activity: np.ndarray) -> float:
    """Integral of B_act(t) dt — the Eq. (4) kernel."""
    return float(np.sum(np.asarray(durations, np.float64)
                        * np.asarray(activity, np.float64)))


def bank_on_matrix(activity: np.ndarray, banks: int) -> np.ndarray:
    """(n_segments, banks) boolean — bank b is required iff B_act > b
    (banks fill lowest-first under contiguous packing)."""
    return activity[:, None] > np.arange(banks)[None, :]


def idle_runs(durations: np.ndarray, on: np.ndarray):
    """Idle intervals of one bank: on is a boolean per-segment series.

    Returns (run_durations, run_start_idx, run_end_idx) for maximal runs of
    False."""
    on = np.asarray(on, bool)
    d = np.asarray(durations, np.float64)
    n = len(on)
    if n == 0:
        return np.zeros(0), np.zeros(0, np.int64), np.zeros(0, np.int64)
    idle = ~on
    # boundaries of idle runs
    diff = np.diff(idle.astype(np.int8))
    starts = np.flatnonzero(diff == 1) + 1
    ends = np.flatnonzero(diff == -1) + 1
    if idle[0]:
        starts = np.r_[0, starts]
    if idle[-1]:
        ends = np.r_[ends, n]
    cum = np.r_[0.0, np.cumsum(d)]
    run_d = cum[ends] - cum[starts]
    return run_d, starts, ends
