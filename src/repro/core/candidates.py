"""Batched Stage-II candidate-evaluation engine.

One vectorized call computes the **exact** Eq. (2)-(5) energy for a full
(capacity C x banks B x headroom alpha x policy) candidate grid against one
occupancy trace — including threshold gating and the three-state drowsy
policy — replacing the per-candidate / per-bank Python loops in
`core.gating.evaluate` and `core.sensitivity.evaluate_drowsy` (which remain
as the scalar references this engine is property-tested against).

The heavy lifting is segment-parallel idle-run extraction in
`kernels.bank_energy` (numpy float64 on CPU — bit-exact vs the scalar
reference; jnp jit or the Pallas TPU kernel elsewhere). On top of the exact
path, `evaluate_candidates(prune=True)` runs a two-phase flow: the cheap
per-candidate energy lower bound (required-bank leakage + dynamic energy,
no idle-run extraction) cuts the grid first, and only survivors — those
whose lower bound does not exceed the incumbent's exact energy — are
evaluated exactly. Since bound <= exact under every policy, the true argmin
is never dropped.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cacti import characterize
from repro.core.gating import GatingResult
from repro.core.sensitivity import (DROWSY_LEAK_FRACTION,
                                    DROWSY_SWITCH_FRACTION, DrowsyResult)

POLICIES = ("none", "gate", "drowsy")

# exact_bank_stats columns
_ACT_S, _N_LONG, _LONG_S, _N_SHORT, _SHORT_S = range(5)


@dataclass(frozen=True)
class Candidate:
    """One cell of the Stage-II grid.

    policy: "none" (no gating), "gate" (two-state threshold gating — the
    paper's conservative/aggressive policies are alpha/threshold settings of
    this), "drowsy" (three-state ON/DROWSY/OFF retention policy).
    `min_gate_multiple` is the gate threshold (or drowsy off-threshold) in
    units of the break-even time; `e_switch_scale` is the sensitivity hook
    scaling transition energy and break-even together."""
    capacity: int
    banks: int
    alpha: float = 0.9
    policy: str = "gate"
    min_gate_multiple: float = 1.0
    e_switch_scale: float = 1.0
    label: str = ""

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")
        if not (0.0 < self.alpha <= 1.0):
            raise ValueError(f"alpha must be in (0,1], got {self.alpha}")

    @property
    def usable_bytes(self) -> float:
        # same op order as banking.bank_activity, for bit-equal ceil()
        return self.alpha * (self.capacity / self.banks)


def make_grid(capacities_bytes: Sequence[int], banks: Sequence[int],
              alphas: Sequence[float] = (0.9,),
              policies: Sequence[str] = ("gate",),
              min_gate_multiple: float = 1.0) -> List[Candidate]:
    """Dense (C x B x alpha x policy) grid, C-major like `candidate_grid`."""
    return [Candidate(int(c), int(b), float(a), p, min_gate_multiple)
            for c in capacities_bytes for b in banks
            for a in alphas for p in policies]


@dataclass
class CandidateEnergies:
    """Column-per-observable result of one batched evaluation.

    For pruned-out candidates (`evaluated[i] == False`) `e_total[i]` holds
    the energy *lower bound*, not the exact energy; `best()`/`argmin()` only
    rank exactly-evaluated candidates."""
    candidates: List[Candidate]
    e_dyn: np.ndarray
    e_leak: np.ndarray               # total leakage (ON + drowsy retention)
    e_sw: np.ndarray
    e_leak_on: np.ndarray
    e_leak_drowsy: np.ndarray
    n_off: np.ndarray                # full power-gate transitions
    n_drowsy: np.ndarray             # drowsy transitions (drowsy policy only)
    gated_bank_seconds: np.ndarray
    total_bank_seconds: np.ndarray
    area_mm2: np.ndarray
    evaluated: np.ndarray            # bool; False -> e_total is a lower bound
    lower_bound: np.ndarray
    e_total: np.ndarray = field(init=False)

    def __post_init__(self):
        self.e_total = self.e_dyn + self.e_leak + self.e_sw

    def __len__(self) -> int:
        return len(self.candidates)

    def argmin(self) -> int:
        masked = np.where(self.evaluated, self.e_total, np.inf)
        if not self.evaluated.any():
            raise ValueError("no exactly-evaluated candidates")
        return int(np.argmin(masked))

    def best(self) -> Tuple[Candidate, float]:
        i = self.argmin()
        return self.candidates[i], float(self.e_total[i])

    # ------------------------------------------------- scalar-result views
    def _require_evaluated(self, i: int) -> None:
        if not self.evaluated[i]:
            raise ValueError(
                f"candidate {i} was pruned by the lower bound; only "
                f"e_total[{i}] (the bound itself) is meaningful")

    def gating_result(self, i: int) -> GatingResult:
        self._require_evaluated(i)
        c = self.candidates[i]
        return GatingResult(
            policy=c.label or c.policy, alpha=c.alpha, capacity=c.capacity,
            banks=c.banks, e_dyn=float(self.e_dyn[i]),
            e_leak=float(self.e_leak[i]), e_sw=float(self.e_sw[i]),
            n_transitions=int(self.n_off[i]),
            gated_bank_seconds=float(self.gated_bank_seconds[i]),
            total_bank_seconds=float(self.total_bank_seconds[i]),
            area_mm2=float(self.area_mm2[i]))

    def drowsy_result(self, i: int) -> DrowsyResult:
        self._require_evaluated(i)
        return DrowsyResult(
            e_dyn=float(self.e_dyn[i]), e_leak_on=float(self.e_leak_on[i]),
            e_leak_drowsy=float(self.e_leak_drowsy[i]),
            e_sw=float(self.e_sw[i]), n_off=int(self.n_off[i]),
            n_drowsy=int(self.n_drowsy[i]))


def _characteristics(cands: Sequence[Candidate]):
    """Per-candidate device constants, via the memoized CACTI surrogate."""
    chs = [characterize(c.capacity, c.banks, c.e_switch_scale) for c in cands]
    return (np.array([ch.leak_w_per_bank for ch in chs]),
            np.array([ch.e_read_j for ch in chs]),
            np.array([ch.e_write_j for ch in chs]),
            np.array([ch.e_switch_j for ch in chs]),
            np.array([ch.break_even_s for ch in chs]),
            np.array([ch.area_mm2 for ch in chs]))


def lower_bound_energies(durations, occupancy, cands: Sequence[Candidate], *,
                         n_reads: int, n_writes: int,
                         backend: str = "auto") -> np.ndarray:
    """Per-candidate energy lower bound in one cheap vectorized call:
    dynamic energy + leakage of the banks the occupancy *requires*. Valid
    under every policy (required leakage and accesses are unavoidable;
    switching and timer/retention leakage are >= 0), which makes it safe
    for pruning."""
    import jax

    from repro.kernels.bank_energy import bank_activity_stats, bank_energy_np
    p_leak, e_r, e_w, _, _, _ = _characteristics(cands)
    usable = np.array([c.usable_bytes for c in cands])
    nbanks = np.array([float(c.banks) for c in cands])
    d = np.asarray(durations, np.float64)
    o = np.asarray(occupancy, np.float64)
    if backend == "numpy" or (backend == "auto"
                              and jax.default_backend() != "tpu"):
        # toggles are dead weight here — bank-seconds only
        seconds = bank_energy_np(d, o, usable, nbanks, toggles=False)[:, 0]
    else:
        seconds = np.asarray(bank_activity_stats(
            d, o, usable, nbanks, backend=backend), np.float64)[:, 0]
    return n_reads * e_r + n_writes * e_w + p_leak * seconds


def evaluate_candidates(durations, occupancy, cands: Sequence[Candidate], *,
                        n_reads: int, n_writes: int, backend: str = "auto",
                        prune: bool = False, prune_margin: float = 1e-3,
                        always_evaluate: Optional[Sequence[int]] = None,
                        block_s: int = 2048) -> CandidateEnergies:
    """Exact batched Stage-II evaluation of every candidate.

    With `prune=True`, candidates whose lower bound exceeds the incumbent's
    exact energy (best-lower-bound candidate, evaluated exactly first) by
    more than `prune_margin` (relative — absorbs f32 backend rounding) are
    skipped; their rows carry the lower bound and `evaluated=False`.
    `always_evaluate` lists indices exempt from pruning (e.g. a sweep's
    delta baselines)."""
    from repro.kernels.bank_energy import exact_bank_stats
    cands = list(cands)
    n = len(cands)
    d = np.asarray(durations, np.float64)
    occ = np.asarray(occupancy, np.float64)
    total_time = float(d.sum())

    p_leak, e_r, e_w, e_sw_j, break_even, area = _characteristics(cands)
    e_dyn = n_reads * e_r + n_writes * e_w
    nbanks_f = np.array([float(c.banks) for c in cands])
    total_bank_seconds = nbanks_f * total_time

    lb = np.full(n, -np.inf)
    evaluated = np.ones(n, bool)
    if prune and n > 1:
        lb = lower_bound_energies(d, occ, cands, n_reads=n_reads,
                                  n_writes=n_writes, backend=backend)
        incumbent_i = int(np.argmin(lb))
        inc = evaluate_candidates(d, occ, [cands[incumbent_i]],
                                  n_reads=n_reads, n_writes=n_writes,
                                  backend=backend, block_s=block_s)
        cutoff = float(inc.e_total[0]) * (1.0 + prune_margin)
        evaluated = lb <= cutoff
        evaluated[incumbent_i] = True
        for i in (always_evaluate or ()):
            evaluated[i] = True

    need = [i for i in range(n)
            if evaluated[i] and cands[i].policy != "none"]
    stats = np.zeros((n, 5))
    if need and len(d):
        usable = np.array([cands[i].usable_bytes for i in need])
        nb = np.array([float(cands[i].banks) for i in need])
        th = np.array([cands[i].min_gate_multiple for i in need]) \
            * break_even[need]
        stats[need] = np.asarray(
            exact_bank_stats(d, occ, usable, nb, th, backend=backend,
                             block_s=block_s), np.float64)

    pol = np.array([POLICIES.index(c.policy) for c in cands])
    is_none, is_gate, is_drowsy = pol == 0, pol == 1, pol == 2

    act_s = stats[:, _ACT_S]
    n_off = np.where(is_none, 0.0, stats[:, _N_LONG])
    off_s = stats[:, _LONG_S]
    n_short = stats[:, _N_SHORT]
    short_s = stats[:, _SHORT_S]

    # leakage: none -> all banks all the time; gate -> everything except
    # gated (long-idle) runs; drowsy -> ON while required + retention
    # fraction during short idles
    e_leak_on = np.where(
        is_none, p_leak * total_bank_seconds,
        np.where(is_gate, p_leak * (total_bank_seconds - off_s),
                 p_leak * act_s))
    e_leak_drowsy = np.where(is_drowsy,
                             p_leak * DROWSY_LEAK_FRACTION * short_s, 0.0)
    e_sw = np.where(
        is_none, 0.0,
        n_off * e_sw_j + np.where(
            is_drowsy, n_short * e_sw_j * DROWSY_SWITCH_FRACTION, 0.0))
    n_drowsy = np.where(is_drowsy, n_short, 0.0)
    gated = np.where(is_none, 0.0, off_s)

    out = CandidateEnergies(
        candidates=cands, e_dyn=e_dyn, e_leak=e_leak_on + e_leak_drowsy,
        e_sw=e_sw, e_leak_on=e_leak_on, e_leak_drowsy=e_leak_drowsy,
        n_off=n_off.astype(np.int64), n_drowsy=n_drowsy.astype(np.int64),
        gated_bank_seconds=gated, total_bank_seconds=total_bank_seconds,
        area_mm2=area, evaluated=evaluated, lower_bound=lb)
    # pruned rows report their lower bound so ranking stays informative
    pruned = ~evaluated
    if pruned.any():
        out.e_leak[pruned] = 0.0
        out.e_sw[pruned] = 0.0
        out.e_total = np.where(pruned, lb, out.e_total)
    return out
