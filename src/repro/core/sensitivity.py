"""Policy sensitivity studies + drowsy (multi-state) retention — the paper's
stated future work ("more detailed transition overhead models and policy
sensitivity studies", Sec. V).

Drowsy mode (Flautner et al., ISCA'02 — the paper's ref [12]): instead of
fully gating a bank (state lost, wake-up latency ~1 us), drop it to a
retention voltage: ~70-85% leakage reduction, data retained, ~2-cycle wake.
For banks holding *obsolete* data full gating is free; for banks that will be
needed again soon, drowsy avoids the refetch/wake cost. We model a three-state
policy: ON / DROWSY (short idle) / OFF (idle >= break-even).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

import numpy as np

from repro.core.banking import bank_activity, bank_on_matrix, idle_runs
from repro.core.cacti import characterize

DROWSY_LEAK_FRACTION = 0.25          # retention-voltage leakage vs ON
DROWSY_SWITCH_FRACTION = 0.02        # transition energy vs full PG pair


@dataclass
class DrowsyResult:
    e_dyn: float
    e_leak_on: float
    e_leak_drowsy: float
    e_sw: float
    n_off: int
    n_drowsy: int

    @property
    def e_total(self) -> float:
        return self.e_dyn + self.e_leak_on + self.e_leak_drowsy + self.e_sw


def evaluate_drowsy(durations: np.ndarray, occupancy: np.ndarray, *,
                    capacity: int, banks: int, alpha: float = 0.9,
                    n_reads: int = 0, n_writes: int = 0,
                    off_multiple: float = 1.0,
                    e_switch_scale: float = 1.0) -> DrowsyResult:
    """Three-state policy: idle interval < break-even -> DROWSY; otherwise
    OFF. Active segments are ON.

    This is the *scalar reference* implementation (per-bank Python loops);
    the batched engine (`core.candidates.evaluate_candidates` with
    policy="drowsy") is property-tested against it and is what sweeps and
    CLIs use. `e_switch_scale` mirrors the `characterize` sensitivity hook
    so scaled-transition candidates keep a scalar reference too."""
    ch = characterize(capacity, banks, e_switch_scale)
    d = np.asarray(durations, np.float64)
    act = bank_activity(occupancy, alpha, capacity, banks)
    on = bank_on_matrix(act, banks)
    threshold = off_multiple * ch.break_even_s

    e_dyn = n_reads * ch.e_read_j + n_writes * ch.e_write_j
    on_seconds = float((on * d[:, None]).sum())
    drowsy_seconds = 0.0
    off_seconds = 0.0
    n_off = 0
    n_drowsy = 0
    for b in range(banks):
        run_d, starts, ends = idle_runs(d, on[:, b])
        off = run_d >= threshold
        n_off += int(off.sum())
        n_drowsy += int((~off).sum())
        off_seconds += float(run_d[off].sum())
        drowsy_seconds += float(run_d[~off].sum())

    p = ch.leak_w_per_bank
    return DrowsyResult(
        e_dyn=e_dyn,
        e_leak_on=p * on_seconds,
        e_leak_drowsy=p * DROWSY_LEAK_FRACTION * drowsy_seconds,
        e_sw=(n_off * ch.e_switch_j
              + n_drowsy * ch.e_switch_j * DROWSY_SWITCH_FRACTION),
        n_off=n_off, n_drowsy=n_drowsy)


def policy_sensitivity(durations: np.ndarray, occupancy: np.ndarray, *,
                       capacity: int, banks: int,
                       n_reads: int, n_writes: int,
                       multiples: Sequence[float] = (1.0, 1e2, 1e3, 1e4, 1e5),
                       sw_scales: Sequence[float] = (0.1, 1.0, 10.0, 100.0),
                       backend: str = "auto") -> Dict[str, Dict[float, float]]:
    """How robust are Stage-II conclusions to (a) the gating threshold and
    (b) the per-transition energy assumption? Returns E_tot per setting.

    The threshold grid, the transition-energy grid (via the
    `characterize(..., e_switch_scale=)` hook, which scales E_sw and the
    implied break-even together) and the drowsy grid are one batched
    `evaluate_candidates` call."""
    from repro.core.candidates import Candidate, evaluate_candidates
    cap, b = int(capacity), int(banks)
    cands = (
        [Candidate(cap, b, 0.9, "gate", m, label="sens") for m in multiples]
        + [Candidate(cap, b, 0.9, "gate", 1.0, e_switch_scale=s,
                     label="sens") for s in sw_scales]
        + [Candidate(cap, b, 0.9, "drowsy", m) for m in multiples])
    res = evaluate_candidates(durations, occupancy, cands, n_reads=n_reads,
                              n_writes=n_writes, backend=backend)
    n_m, n_s = len(multiples), len(sw_scales)
    return {
        "threshold": {m: float(res.e_total[i])
                      for i, m in enumerate(multiples)},
        "sw_scale": {s: float(res.e_total[n_m + i])
                     for i, s in enumerate(sw_scales)},
        "drowsy": {m: float(res.e_total[n_m + n_s + i])
                   for i, m in enumerate(multiples)},
    }
