"""Policy sensitivity studies + drowsy (multi-state) retention — the paper's
stated future work ("more detailed transition overhead models and policy
sensitivity studies", Sec. V).

Drowsy mode (Flautner et al., ISCA'02 — the paper's ref [12]): instead of
fully gating a bank (state lost, wake-up latency ~1 us), drop it to a
retention voltage: ~70-85% leakage reduction, data retained, ~2-cycle wake.
For banks holding *obsolete* data full gating is free; for banks that will be
needed again soon, drowsy avoids the refetch/wake cost. We model a three-state
policy: ON / DROWSY (short idle) / OFF (idle >= break-even).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

import numpy as np

from repro.core.banking import bank_activity, bank_on_matrix, idle_runs
from repro.core.cacti import SramCharacterization, characterize
from repro.core.gating import GatingResult, Policy, evaluate

DROWSY_LEAK_FRACTION = 0.25          # retention-voltage leakage vs ON
DROWSY_SWITCH_FRACTION = 0.02        # transition energy vs full PG pair


@dataclass
class DrowsyResult:
    e_dyn: float
    e_leak_on: float
    e_leak_drowsy: float
    e_sw: float
    n_off: int
    n_drowsy: int

    @property
    def e_total(self) -> float:
        return self.e_dyn + self.e_leak_on + self.e_leak_drowsy + self.e_sw


def evaluate_drowsy(durations: np.ndarray, occupancy: np.ndarray, *,
                    capacity: int, banks: int, alpha: float = 0.9,
                    n_reads: int = 0, n_writes: int = 0,
                    off_multiple: float = 1.0) -> DrowsyResult:
    """Three-state policy: idle interval < break-even -> DROWSY; otherwise
    OFF. Active segments are ON."""
    ch = characterize(capacity, banks)
    d = np.asarray(durations, np.float64)
    act = bank_activity(occupancy, alpha, capacity, banks)
    on = bank_on_matrix(act, banks)
    threshold = off_multiple * ch.break_even_s

    e_dyn = n_reads * ch.e_read_j + n_writes * ch.e_write_j
    on_seconds = float((on * d[:, None]).sum())
    drowsy_seconds = 0.0
    off_seconds = 0.0
    n_off = 0
    n_drowsy = 0
    for b in range(banks):
        run_d, starts, ends = idle_runs(d, on[:, b])
        off = run_d >= threshold
        n_off += int(off.sum())
        n_drowsy += int((~off).sum())
        off_seconds += float(run_d[off].sum())
        drowsy_seconds += float(run_d[~off].sum())

    p = ch.leak_w_per_bank
    return DrowsyResult(
        e_dyn=e_dyn,
        e_leak_on=p * on_seconds,
        e_leak_drowsy=p * DROWSY_LEAK_FRACTION * drowsy_seconds,
        e_sw=(n_off * ch.e_switch_j
              + n_drowsy * ch.e_switch_j * DROWSY_SWITCH_FRACTION),
        n_off=n_off, n_drowsy=n_drowsy)


def policy_sensitivity(durations: np.ndarray, occupancy: np.ndarray, *,
                       capacity: int, banks: int,
                       n_reads: int, n_writes: int,
                       multiples: Sequence[float] = (1.0, 1e2, 1e3, 1e4, 1e5),
                       sw_scales: Sequence[float] = (0.1, 1.0, 10.0, 100.0),
                       ) -> Dict[str, Dict[float, float]]:
    """How robust are Stage-II conclusions to (a) the gating threshold and
    (b) the per-transition energy assumption? Returns E_tot per setting."""
    out: Dict[str, Dict[float, float]] = {"threshold": {}, "sw_scale": {},
                                          "drowsy": {}}
    for m in multiples:
        pol = Policy("sens", 0.9, gate=True, min_gate_multiple=m)
        r = evaluate(durations, occupancy, capacity=capacity, banks=banks,
                     policy=pol, n_reads=n_reads, n_writes=n_writes)
        out["threshold"][m] = r.e_total

    # transition-energy scaling: scale both E_sw and the implied break-even
    base = characterize(capacity, banks)
    for s in sw_scales:
        class _Scaled(SramCharacterization):
            @property
            def e_switch_j(self):  # noqa: D401
                return SramCharacterization.e_switch_j.fget(self) * s
        ch = _Scaled(int(capacity), int(banks))
        pol = Policy("sens", 0.9, gate=True, min_gate_multiple=1.0)
        r = evaluate(durations, occupancy, capacity=capacity, banks=banks,
                     policy=pol, n_reads=n_reads, n_writes=n_writes, char=ch)
        out["sw_scale"][s] = r.e_total

    for m in multiples:
        r = evaluate_drowsy(durations, occupancy, capacity=capacity,
                            banks=banks, n_reads=n_reads, n_writes=n_writes,
                            off_multiple=m)
        out["drowsy"][m] = r.e_total
    return out
